"""Table 3: compression ratio + speed, ZipNN vs the LZ+entropy baseline vs
EE+baseline, on the paper's three representative models (regular BF16,
regular FP32, clean FP32).

Baselines: zlib stands in for the zstd-class LZ+entropy family (DESIGN.md
deviation 1).  Default speeds are single-core host numbers, like the
paper's M1 measurements (absolute GB/s differ — C vs Python host — the
*ordering* and ratio deltas are the reproduced claims).

``--threads N`` (paper §5.2: independent chunks compress in parallel)
additionally runs the ZipNN rows through the engine's thread pool and
reports the multi-thread sweep: blobs are asserted byte-identical to the
single-thread run (the engine's determinism contract) and ratios are
therefore identical by construction; only throughput changes.

``--backend device|both`` additionally runs the ZipNN rows through the
device plane-producer backend (fused Pallas dispatch, see
core/device_plane.py) and **asserts byte-parity** against the host blobs —
the backend knob's contract.  The same rows sweep the *decode* side
through the device plane-consumer backend (core/device_unplane.py):
decompress throughput is reported for both backends and the decoded bytes
are asserted bit-identical to the raw input, without touching the host
rows' compress numbers.  On a CPU-only host the kernels run in interpret
mode, so device-row throughput is a correctness artifact, not a speed
claim (flagged in the row).  The device sweep also runs the **full-device
compress path** (fused plane producer + fused Huffman bit-pack entropy
stage, ``core/device_entropy.py``) under the canonical ``huffman`` coder
and asserts those blobs byte-identical to the host canonical coder's.

The **component rows** (``component_rows``) run the host ZipNN path over
the component corpus — KV-cache-like BF16, AdamW moments FP32, fp8
e4m3/e5m2, int8 — the payloads the KV tier, the moment chains and the
sub-byte/integer bit layouts compress.  Their ratios are deterministic
(numpy-seeded corpus) and pinned exactly by the bench gate.

The run ends with the **compressed-resident serving rows** (``serve_rows``,
skip with ``--no-serve``): the per-layer prefetch/decode ring
(``repro/serve/compressed.py``) vs the plain jitted decode step — logits
asserted bit-identical in lockstep, peak decoded residency asserted ≤ 2
layers, and tokens/sec × HBM weight footprint reported side by side.
The **payload-feed rows** (``serve_feed_rows``) rerun the ring with the
store's compressed payloads resident in device memory
(``payload_feed=True``), once whole-layer and once per-tile (``tiles=2``):
logits stay bit-identical, zero per-token payload uploads after warmup
are asserted via the transfer counters, and per-tile residency is capped
at ring × tiles tile slots.  Then the **KV-tier row** (``kv_serve_rows``): a greedy decode
through ``make_kv_tiered_serve_step`` over a ``KVCacheStore``, logits
asserted bit-identical to the untiered ``decode_step`` at every step and
live hot positions asserted ≤ hot_window + block_len.
Results are written to ``BENCH_table3.json``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List, Sequence

import numpy as np

from repro.core import baselines, engine, zipnn
from repro.core.options import CodecOptions

from . import corpus

N = 8_000_000


def _timed(fn, *args, reps: int = 1):
    """Best-of-``reps`` wall time (first result is returned)."""
    out, best = None, float("inf")
    for i in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        best = min(best, time.perf_counter() - t0)
        if i == 0:
            out = r
    return out, best


# Component payloads: (row name, corpus generator, dtype name).  Host
# ZipNN only — the backend × threads parity matrix already runs on the
# three model rows; these rows pin the *component* ratios (KV tier,
# moment chains, fp8/int8 layouts) under the bench gate.
COMPONENT_MODELS = (
    ("KV-cache-like BF16", corpus.kv_cache_bf16, "bfloat16"),
    ("Adam-moments FP32", corpus.adam_moments_fp32, "float32"),
    ("fp8-E4M3 weights", corpus.fp8_e4m3, "float8_e4m3fn"),
    ("fp8-E5M2 weights", corpus.fp8_e5m2, "float8_e5m2"),
    ("int8 per-channel weights", corpus.int8_quantized, "int8"),
)


def component_rows(n: int, reps: int = 1) -> List[dict]:
    """Ratio + host speed for the component corpus (decode round-trips)."""
    rows = []
    for name, gen, dtype in COMPONENT_MODELS:
        raw = corpus.as_bytes(gen(n))
        nb = len(raw)
        blob, t_c = _timed(
            lambda: zipnn.compress_bytes(raw, dtype), reps=reps
        )
        back, t_d = _timed(lambda: zipnn.decompress_bytes(blob), reps=reps)
        assert back == raw, f"{name}: decode != raw bytes"
        rows.append(
            {"model": name, "method": "ZipNN",
             "comp_pct": round(100 * len(blob) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3),
             "decomp_gbps": round(nb / t_d / 1e9, 3)}
        )
    return rows


def _serve_params(model, rng):
    """Fill abstract params from a numpy PCG64 stream (jax-version-stable
    bytes ⇒ stable store ratios for the gated rows)."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(model.abstract_params())
    params = jax.tree_util.tree_unflatten(
        treedef,
        [
            (rng.standard_normal(l.shape) * 0.02).astype(np.dtype(l.dtype))
            for l in leaves
        ],
    )
    return params, leaves


def kv_serve_rows(
    steps: int = 10, hot_window: int = 3, block_len: int = 2
) -> List[dict]:
    """KV-cache tiering row: bit-identity smoke + residency accounting.

    Greedy-decodes ``steps`` tokens through ``make_kv_tiered_serve_step``
    over a ``KVCacheStore`` (cold cache blocks as ZNN1 payloads) in
    lockstep with the plain jitted ``decode_step`` and asserts the logits
    byte-identical at every step — the KV bit-identity contract — plus
    live hot positions ≤ hot_window + block_len.  Cache bytes are jax
    activations (not numpy-seeded), so the compressed-cold ratio is
    reported, not gated (``comp_pct`` stays None).
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import KVCacheStore, make_kv_tiered_serve_step

    cfg = get_config("repro_gpt_100m").reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params, _ = _serve_params(model, rng)
    B = 2
    toks = [
        jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(steps)
    ]

    step = jax.jit(model.decode_step)
    state = model.init_decode_state(B, steps, start_pos=0)
    kv_store = KVCacheStore(
        model.init_decode_state(B, steps, start_pos=0),
        hot_window=hot_window, block_len=block_len,
    )
    tstep = make_kv_tiered_serve_step(model, params, kv_store)

    t0 = time.perf_counter()
    for t in toks:
        la, state = step(params, state, t)
        lb = tstep(t)
        if np.asarray(la).tobytes() != np.asarray(lb).tobytes():
            raise AssertionError("kv-tiered logits != untiered logits")
    t_kv = time.perf_counter() - t0
    cap = kv_store.hot_window + kv_store.block_len
    if kv_store.peak_hot_positions > cap:
        raise AssertionError(
            f"hot residency {kv_store.peak_hot_positions} > {cap}"
        )
    if kv_store.n_cold_blocks == 0:
        raise AssertionError("kv smoke never evicted a block")

    return [
        {"model": "repro-gpt-100m reduced (kv-tier)",
         "method": "ZipNN(kv-tier)",
         "comp_pct": None,
         "tok_per_s": round(B * steps / t_kv, 1),
         "kv_full_kb": round(kv_store.full_cache_bytes / 1e3, 3),
         "kv_resident_kb": round(kv_store.resident_bytes(1) / 1e3, 3),
         "kv_cold_pct": round(
             100 * kv_store.cold_comp_bytes
             / max(kv_store.cold_raw_bytes, 1), 1
         ),
         "comp_gbps": None, "decomp_gbps": None,
         "parity": "bit-identical logits",
         "note": (
             f"lockstep vs decode_step over {steps} tokens; hot positions "
             f"<= hot_window+block_len asserted; cache bytes are jax "
             "activations, so the cold ratio is reported, not gated "
             "(smoke-sized cache: the resident-vs-full win needs "
             "length >> hot_window, like the serve-ring footprint)"
         )},
    ]


def serve_rows(steps: int = 8) -> List[dict]:
    """Compressed-resident serving row: tokens/sec × HBM weight footprint.

    Drives the prefetch/decode ring (``serve.make_compressed_serve_step``
    over a ``CompressedParamStore``) against the plain jitted decode step
    on a reduced dense model, in lockstep on the same tokens.  Logits are
    asserted **bit-identical** at every step and peak decoded-weight
    residency is asserted ≤ 2 layers — the double-buffer claim.  Params
    are filled from a numpy PCG64 stream (not ``jax.random``) so the
    store's ratio — the gated ``comp_pct`` — is stable across jax
    versions; the ring runs the host decode backend here, so tokens/sec is
    a real host number, but it is reported, not gated (timing fields are
    machine-dependent; only the ratio must match the baseline exactly).
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import CompressedParamStore, make_compressed_serve_step

    cfg = get_config("repro_gpt_100m").reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params, leaves = _serve_params(model, rng)
    raw_mb = sum(
        int(np.size(l)) * np.dtype(l.dtype).itemsize for l in leaves
    ) / 1e6

    step = jax.jit(model.decode_step)
    store = CompressedParamStore.from_params(params)
    cstep = make_compressed_serve_step(model, store, ring=2)

    B = 2
    toks = [
        jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(steps)
    ]

    # Lockstep parity pass (doubles as compile warmup for both paths).
    sa = model.init_decode_state(B, steps, start_pos=0)
    sb = model.init_decode_state(B, steps, start_pos=0)
    for t in toks:
        la, sa = step(params, sa, t)
        lb, sb = cstep(sb, t)
        if np.asarray(la).tobytes() != np.asarray(lb).tobytes():
            raise AssertionError("serve-ring logits != uncompressed logits")
    if store.peak_resident > 2:
        raise AssertionError(
            f"ring residency {store.peak_resident} layers > 2"
        )

    def drive(fn, state):
        logits = None
        for t in toks:
            logits, state = fn(state, t)
        jax.block_until_ready(logits)

    s0 = model.init_decode_state(B, steps, start_pos=0)
    _, t_u = _timed(lambda: drive(lambda s, t: step(params, s, t), s0))
    s1 = model.init_decode_state(B, steps, start_pos=0)
    _, t_c = _timed(lambda: drive(cstep, s1))

    name = "repro-gpt-100m reduced (serve)"
    return [
        {"model": name, "method": "serve_step",
         "comp_pct": 100.0,
         "tok_per_s": round(B * steps / t_u, 1),
         "hbm_weights_mb": round(raw_mb, 3),
         "comp_gbps": None, "decomp_gbps": None},
        {"model": name, "method": "ZipNN(serve-ring)",
         "comp_pct": round(store.ratio_pct, 1),
         "tok_per_s": round(B * steps / t_c, 1),
         "hbm_weights_mb": round(store.footprint_bytes(2) / 1e6, 3),
         "comp_gbps": None, "decomp_gbps": None,
         "parity": "bit-identical logits",
         "note": "host-ring decode; peak decoded residency asserted <= 2 "
                 "layers (2-layer reduced model: the footprint win "
                 "comp*N + 2 slots < raw*N needs N >> ring)"},
    ]


def serve_feed_rows(steps: int = 8) -> List[dict]:
    """Device-resident payload feed rows: the ring with payloads in HBM.

    Same lockstep/bit-identity drill as :func:`serve_rows`, but the store
    is built with ``payload_feed=True`` under the canonical coder: every
    layer's packed HUFF words upload to device memory once at build, and
    each token's decodes re-run the fused Huffman kernel from those
    resident buffers.  Asserted per row: logits bit-identical, **zero**
    payload host→device uploads after the warmup token (the module's
    transfer counters), and — for the ``tiles=2`` row — peak decoded
    residency ≤ ring × tiles tile slots.  ``comp_pct`` is gated (numpy-
    seeded params); timings and the resident-payload HBM megabytes are
    reported only.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import device_entropy
    from repro.models import build_model
    from repro.serve import CompressedParamStore, make_compressed_serve_step

    cfg = get_config("repro_gpt_100m").reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params, _ = _serve_params(model, rng)
    zcfg = zipnn.ZipNNConfig(backend="huffman")
    step = jax.jit(model.decode_step)
    B = 2
    toks = [
        jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(steps)
    ]

    rows = []
    for ring, tiles in ((2, 1), (2, 2)):
        store = CompressedParamStore.from_params(
            params, zcfg, payload_feed=True
        )
        if store.device_payload_bytes == 0:
            raise AssertionError("payload feed resident bytes == 0")
        cstep = make_compressed_serve_step(model, store, ring=ring, tiles=tiles)
        sa = model.init_decode_state(B, steps, start_pos=0)
        sb = model.init_decode_state(B, steps, start_pos=0)
        for i, t in enumerate(toks):
            if i == 1:          # token 0 is compile warmup; count after it
                device_entropy.reset_transfer_stats()
            la, sa = step(params, sa, t)
            lb, sb = cstep(sb, t)
            if np.asarray(la).tobytes() != np.asarray(lb).tobytes():
                raise AssertionError(
                    f"feed-ring logits != uncompressed logits (tiles={tiles})"
                )
        stats = device_entropy.transfer_stats()
        if stats["payload_uploads"]:
            raise AssertionError(
                f"feed ring moved {stats['payload_bytes']} payload bytes "
                f"host->device after warmup (tiles={tiles})"
            )
        if store.peak_resident > ring * tiles:
            raise AssertionError(
                f"tile residency {store.peak_resident} > ring*tiles "
                f"{ring * tiles}"
            )

        def drive(state):
            logits = None
            for t in toks:
                logits, state = cstep(state, t)
            jax.block_until_ready(logits)

        s1 = model.init_decode_state(B, steps, start_pos=0)
        _, t_c = _timed(lambda: drive(s1))
        rows.append(
            {"model": "repro-gpt-100m reduced (serve)",
             "method": "ZipNN(serve-feed)" if tiles == 1
             else f"ZipNN(serve-feed, tiles={tiles})",
             "comp_pct": round(store.ratio_pct, 1),
             "tok_per_s": round(B * steps / t_c, 1),
             "hbm_weights_mb": round(store.footprint_bytes(ring) / 1e6, 3),
             "payload_hbm_mb": round(store.device_payload_bytes / 1e6, 3),
             "comp_gbps": None, "decomp_gbps": None,
             "parity": "bit-identical logits",
             "note": "payloads resident in device memory; zero per-token "
                     "payload uploads after warmup asserted"
             + ("" if tiles == 1 else
                f"; peak residency <= ring*tiles = {ring * tiles} tile "
                "slots asserted")},
        )
    return rows


def run(
    threads: int = 1, backends: Sequence[str] = ("host",), n: int = N,
    serve: bool = True,
) -> List[dict]:
    rows = []
    models = [
        ("Llama-3.1-like BF16", corpus.regular_bf16(n), "bfloat16"),
        ("Olmo-like FP32", corpus.regular_fp32(n), "float32"),
        ("xlm-RoBERTa-like FP32", corpus.clean_fp32(n), "float32"),
    ]
    threads = engine.resolve_threads(threads)    # -1 → all cores, cap at cores
    sweep = [1] if threads <= 1 else [1, threads]
    reps = 1 if len(sweep) == 1 else 3         # sweep mode: denoise timings
    for name, w, dtype in models:
        raw = corpus.as_bytes(w)
        nb = len(raw)

        comp, t_c = _timed(baselines.zlib6, raw, reps=reps)
        _, t_d = _timed(lambda: __import__("zlib").decompress(comp), reps=reps)
        rows.append(
            {"model": name, "method": "zlib(LZ+entropy)",
             "comp_pct": round(100 * len(comp) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3),
             "decomp_gbps": round(nb / t_d / 1e9, 3)}
        )

        ee, t_c = _timed(baselines.ee_zlib, raw, dtype, reps=reps)
        rows.append(
            {"model": name, "method": "EE+zlib",
             "comp_pct": round(100 * len(ee) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3), "decomp_gbps": None}
        )

        blob_1t = None
        for nt in sweep:
            opts = CodecOptions(threads=nt)
            blob, t_c = _timed(
                lambda: zipnn.compress_bytes(raw, dtype, options=opts),
                reps=reps,
            )
            back, t_d = _timed(
                lambda: zipnn.decompress_bytes(blob, options=opts), reps=reps
            )
            assert back == raw
            if nt == 1:
                blob_1t = blob
            else:
                # engine contract: threads change wall-clock, never bytes
                assert blob == blob_1t, "parallel blob != single-thread blob"
            rows.append(
                {"model": name,
                 "method": "ZipNN" if nt == 1 else f"ZipNN(threads={nt})",
                 "comp_pct": round(100 * len(blob) / nb, 1),
                 "comp_gbps": round(nb / t_c / 1e9, 3),
                 "decomp_gbps": round(nb / t_d / 1e9, 3)}
            )

        if "device" in backends:
            import jax

            for nt in sweep:
                dev_opts = CodecOptions(threads=nt, backend="device")
                dev_blob, t_c = _timed(
                    lambda: zipnn.compress_bytes(raw, dtype, options=dev_opts),
                    reps=reps,
                )
                # backend contract: device blobs byte-identical to host
                assert dev_blob == blob_1t, "device blob != host blob"
                dev_back, t_d = _timed(
                    lambda: zipnn.decompress_bytes(dev_blob, options=dev_opts),
                    reps=reps,
                )
                # decode contract: device-decoded bytes bit-identical
                assert dev_back == raw, "device decode != raw bytes"
                rows.append(
                    {"model": name,
                     "method": f"ZipNN(device, threads={nt})",
                     "comp_pct": round(100 * len(dev_blob) / nb, 1),
                     "comp_gbps": round(nb / t_c / 1e9, 3),
                     "decomp_gbps": round(nb / t_d / 1e9, 3),
                     "parity": "byte-identical",
                     "note": (
                         "interpret-mode kernels (no TPU): parity check, "
                         "not a speed claim"
                     ) if jax.default_backend() != "tpu" else None}
                )

            # Full-device compress path: fused plane producer AND fused
            # Huffman bit-pack entropy stage (core/device_entropy.py) under
            # the canonical 'huffman' coder; blobs asserted byte-identical
            # to the host canonical coder's.
            cfg_h = zipnn.ZipNNConfig(backend="huffman")
            host_opts = CodecOptions(backend="host")
            huff_host, t_hc = _timed(
                lambda: zipnn.compress_bytes(
                    raw, dtype, cfg_h, options=host_opts
                ),
                reps=reps,
            )
            huff_back, t_hd = _timed(
                lambda: zipnn.decompress_bytes(
                    huff_host, cfg_h, options=host_opts
                ),
                reps=reps,
            )
            assert huff_back == raw, "host huffman decode != raw bytes"
            rows.append(
                {"model": name, "method": "ZipNN(huffman)",
                 "comp_pct": round(100 * len(huff_host) / nb, 1),
                 "comp_gbps": round(nb / t_hc / 1e9, 3),
                 "decomp_gbps": round(nb / t_hd / 1e9, 3)}
            )
            full_dev = CodecOptions(backend="device", entropy_backend="device")
            dev_h, t_c = _timed(
                lambda: zipnn.compress_bytes(
                    raw, dtype, cfg_h, options=full_dev
                ),
                reps=reps,
            )
            assert dev_h == huff_host, "device-entropy blob != host blob"
            # Full-device decode: the device Huffman decoder kernel feeds
            # the fused un-plane consumer — only compressed bytes cross
            # host→device, and output is asserted bit-identical to raw.
            dev_back, t_d = _timed(
                lambda: zipnn.decompress_bytes(dev_h, cfg_h, options=full_dev),
                reps=reps,
            )
            assert dev_back == raw, "device-entropy decode != raw bytes"
            rows.append(
                {"model": name, "method": "ZipNN(device+entropy)",
                 "comp_pct": round(100 * len(dev_h) / nb, 1),
                 "comp_gbps": round(nb / t_c / 1e9, 3),
                 "decomp_gbps": round(nb / t_d / 1e9, 3),
                 "parity": "byte-identical",
                 "note": (
                     "interpret-mode kernels (no TPU): parity check, "
                     "not a speed claim"
                 ) if jax.default_backend() != "tpu" else None}
            )
    rows += component_rows(n, reps=reps)
    if serve:
        rows += serve_rows()
        rows += serve_feed_rows()
        rows += kv_serve_rows()
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--threads", type=int, default=1,
        help="engine pool size for the ZipNN sweep (-1 = all cores)",
    )
    ap.add_argument(
        "--backend", choices=["host", "device", "both"], default="host",
        help="plane producer/consumer backends to sweep; device rows assert "
             "byte-parity of blobs AND bit-exact device decode",
    )
    ap.add_argument(
        "--n", type=int, default=N,
        help="elements per synthetic model (shrink for the CI parity smoke)",
    )
    ap.add_argument(
        "--json", default="BENCH_table3.json",
        help="result file (written on every run)",
    )
    ap.add_argument(
        "--no-serve", action="store_true",
        help="skip the serving rows (ring parity + tokens/sec × HBM "
             "footprint, and the KV-tier bit-identity smoke)",
    )
    args = ap.parse_args()
    backends = {
        "host": ("host",), "device": ("host", "device"),
        "both": ("host", "device"),
    }[args.backend]
    rows = run(
        threads=args.threads, backends=backends, n=args.n,
        serve=not args.no_serve,
    )
    for r in rows:
        print(r)
    with open(args.json, "w") as f:
        json.dump(
            {
                "bench": "table3_speed",
                "n_elements": args.n,
                "threads": engine.resolve_threads(args.threads),
                "backends": list(backends),
                "parity": "asserted" if "device" in backends else "n/a",
                "rows": rows,
            },
            f,
            indent=2,
        )
    print(f"wrote {args.json}")
    n_threads = engine.resolve_threads(args.threads)
    if n_threads > 1:
        for model in {r["model"] for r in rows}:
            one = next(r for r in rows if r["model"] == model and r["method"] == "ZipNN")
            par = next(
                (r for r in rows if r["model"] == model
                 and r["method"].startswith("ZipNN(threads")), None,
            )
            if par:
                print(
                    f"{model}: threads={n_threads} speedup "
                    f"compress {par['comp_gbps']/one['comp_gbps']:.2f}x "
                    f"decompress {par['decomp_gbps']/one['decomp_gbps']:.2f}x "
                    f"(ratios identical, blobs byte-identical)"
                )


if __name__ == "__main__":
    main()
