"""Table 3: compression ratio + speed, ZipNN vs the LZ+entropy baseline vs
EE+baseline, on the paper's three representative models (regular BF16,
regular FP32, clean FP32).

Baselines: zlib stands in for the zstd-class LZ+entropy family (DESIGN.md
deviation 1).  Speeds are single-core host numbers, like the paper's M1
measurements (absolute GB/s differ — C vs Python host — the *ordering*
and ratio deltas are the reproduced claims)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import baselines, zipnn

from . import corpus

N = 8_000_000


def _timed(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    return out, time.perf_counter() - t0


def run() -> List[dict]:
    rows = []
    models = [
        ("Llama-3.1-like BF16", corpus.regular_bf16(N), "bfloat16"),
        ("Olmo-like FP32", corpus.regular_fp32(N), "float32"),
        ("xlm-RoBERTa-like FP32", corpus.clean_fp32(N), "float32"),
    ]
    for name, w, dtype in models:
        raw = corpus.as_bytes(w)
        nb = len(raw)

        comp, t_c = _timed(baselines.zlib6, raw)
        _, t_d = _timed(lambda: __import__("zlib").decompress(comp))
        rows.append(
            {"model": name, "method": "zlib(LZ+entropy)",
             "comp_pct": round(100 * len(comp) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3),
             "decomp_gbps": round(nb / t_d / 1e9, 3)}
        )

        ee, t_c = _timed(baselines.ee_zlib, raw, dtype)
        rows.append(
            {"model": name, "method": "EE+zlib",
             "comp_pct": round(100 * len(ee) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3), "decomp_gbps": None}
        )

        blob, t_c = _timed(zipnn.compress_bytes, raw, dtype)
        back, t_d = _timed(zipnn.decompress_bytes, blob)
        assert back == raw
        rows.append(
            {"model": name, "method": "ZipNN",
             "comp_pct": round(100 * len(blob) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3),
             "decomp_gbps": round(nb / t_d / 1e9, 3)}
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
