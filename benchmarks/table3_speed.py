"""Table 3: compression ratio + speed, ZipNN vs the LZ+entropy baseline vs
EE+baseline, on the paper's three representative models (regular BF16,
regular FP32, clean FP32).

Baselines: zlib stands in for the zstd-class LZ+entropy family (DESIGN.md
deviation 1).  Default speeds are single-core host numbers, like the
paper's M1 measurements (absolute GB/s differ — C vs Python host — the
*ordering* and ratio deltas are the reproduced claims).

``--threads N`` (paper §5.2: independent chunks compress in parallel)
additionally runs the ZipNN rows through the engine's thread pool and
reports the multi-thread sweep: blobs are asserted byte-identical to the
single-thread run (the engine's determinism contract) and ratios are
therefore identical by construction; only throughput changes.

``--backend device|both`` additionally runs the ZipNN rows through the
device plane-producer backend (fused Pallas dispatch, see
core/device_plane.py) and **asserts byte-parity** against the host blobs —
the backend knob's contract.  The same rows sweep the *decode* side
through the device plane-consumer backend (core/device_unplane.py):
decompress throughput is reported for both backends and the decoded bytes
are asserted bit-identical to the raw input, without touching the host
rows' compress numbers.  On a CPU-only host the kernels run in interpret
mode, so device-row throughput is a correctness artifact, not a speed
claim (flagged in the row).  The device sweep also runs the **full-device
compress path** (fused plane producer + fused Huffman bit-pack entropy
stage, ``core/device_entropy.py``) under the canonical ``huffman`` coder
and asserts those blobs byte-identical to the host canonical coder's.
Results are written to ``BENCH_table3.json``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List, Sequence

import numpy as np

from repro.core import baselines, engine, zipnn

from . import corpus

N = 8_000_000


def _timed(fn, *args, reps: int = 1):
    """Best-of-``reps`` wall time (first result is returned)."""
    out, best = None, float("inf")
    for i in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        best = min(best, time.perf_counter() - t0)
        if i == 0:
            out = r
    return out, best


def run(
    threads: int = 1, backends: Sequence[str] = ("host",), n: int = N
) -> List[dict]:
    rows = []
    models = [
        ("Llama-3.1-like BF16", corpus.regular_bf16(n), "bfloat16"),
        ("Olmo-like FP32", corpus.regular_fp32(n), "float32"),
        ("xlm-RoBERTa-like FP32", corpus.clean_fp32(n), "float32"),
    ]
    threads = engine.resolve_threads(threads)    # -1 → all cores, cap at cores
    sweep = [1] if threads <= 1 else [1, threads]
    reps = 1 if len(sweep) == 1 else 3         # sweep mode: denoise timings
    for name, w, dtype in models:
        raw = corpus.as_bytes(w)
        nb = len(raw)

        comp, t_c = _timed(baselines.zlib6, raw, reps=reps)
        _, t_d = _timed(lambda: __import__("zlib").decompress(comp), reps=reps)
        rows.append(
            {"model": name, "method": "zlib(LZ+entropy)",
             "comp_pct": round(100 * len(comp) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3),
             "decomp_gbps": round(nb / t_d / 1e9, 3)}
        )

        ee, t_c = _timed(baselines.ee_zlib, raw, dtype, reps=reps)
        rows.append(
            {"model": name, "method": "EE+zlib",
             "comp_pct": round(100 * len(ee) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3), "decomp_gbps": None}
        )

        blob_1t = None
        for nt in sweep:
            blob, t_c = _timed(
                lambda: zipnn.compress_bytes(raw, dtype, threads=nt), reps=reps
            )
            back, t_d = _timed(
                lambda: zipnn.decompress_bytes(blob, threads=nt), reps=reps
            )
            assert back == raw
            if nt == 1:
                blob_1t = blob
            else:
                # engine contract: threads change wall-clock, never bytes
                assert blob == blob_1t, "parallel blob != single-thread blob"
            rows.append(
                {"model": name,
                 "method": "ZipNN" if nt == 1 else f"ZipNN(threads={nt})",
                 "comp_pct": round(100 * len(blob) / nb, 1),
                 "comp_gbps": round(nb / t_c / 1e9, 3),
                 "decomp_gbps": round(nb / t_d / 1e9, 3)}
            )

        if "device" in backends:
            import jax

            for nt in sweep:
                dev_blob, t_c = _timed(
                    lambda: zipnn.compress_bytes(
                        raw, dtype, threads=nt, backend="device"
                    ),
                    reps=reps,
                )
                # backend contract: device blobs byte-identical to host
                assert dev_blob == blob_1t, "device blob != host blob"
                dev_back, t_d = _timed(
                    lambda: zipnn.decompress_bytes(
                        dev_blob, threads=nt, backend="device"
                    ),
                    reps=reps,
                )
                # decode contract: device-decoded bytes bit-identical
                assert dev_back == raw, "device decode != raw bytes"
                rows.append(
                    {"model": name,
                     "method": f"ZipNN(device, threads={nt})",
                     "comp_pct": round(100 * len(dev_blob) / nb, 1),
                     "comp_gbps": round(nb / t_c / 1e9, 3),
                     "decomp_gbps": round(nb / t_d / 1e9, 3),
                     "parity": "byte-identical",
                     "note": (
                         "interpret-mode kernels (no TPU): parity check, "
                         "not a speed claim"
                     ) if jax.default_backend() != "tpu" else None}
                )

            # Full-device compress path: fused plane producer AND fused
            # Huffman bit-pack entropy stage (core/device_entropy.py) under
            # the canonical 'huffman' coder; blobs asserted byte-identical
            # to the host canonical coder's.
            cfg_h = zipnn.ZipNNConfig(backend="huffman")
            huff_host, t_hc = _timed(
                lambda: zipnn.compress_bytes(raw, dtype, cfg_h, backend="host"),
                reps=reps,
            )
            huff_back, t_hd = _timed(
                lambda: zipnn.decompress_bytes(huff_host, cfg_h, backend="host"),
                reps=reps,
            )
            assert huff_back == raw, "host huffman decode != raw bytes"
            rows.append(
                {"model": name, "method": "ZipNN(huffman)",
                 "comp_pct": round(100 * len(huff_host) / nb, 1),
                 "comp_gbps": round(nb / t_hc / 1e9, 3),
                 "decomp_gbps": round(nb / t_hd / 1e9, 3)}
            )
            dev_h, t_c = _timed(
                lambda: zipnn.compress_bytes(
                    raw, dtype, cfg_h, backend="device", entropy_backend="device"
                ),
                reps=reps,
            )
            assert dev_h == huff_host, "device-entropy blob != host blob"
            # Full-device decode: the device Huffman decoder kernel feeds
            # the fused un-plane consumer — only compressed bytes cross
            # host→device, and output is asserted bit-identical to raw.
            dev_back, t_d = _timed(
                lambda: zipnn.decompress_bytes(
                    dev_h, cfg_h, backend="device", entropy_backend="device"
                ),
                reps=reps,
            )
            assert dev_back == raw, "device-entropy decode != raw bytes"
            rows.append(
                {"model": name, "method": "ZipNN(device+entropy)",
                 "comp_pct": round(100 * len(dev_h) / nb, 1),
                 "comp_gbps": round(nb / t_c / 1e9, 3),
                 "decomp_gbps": round(nb / t_d / 1e9, 3),
                 "parity": "byte-identical",
                 "note": (
                     "interpret-mode kernels (no TPU): parity check, "
                     "not a speed claim"
                 ) if jax.default_backend() != "tpu" else None}
            )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--threads", type=int, default=1,
        help="engine pool size for the ZipNN sweep (-1 = all cores)",
    )
    ap.add_argument(
        "--backend", choices=["host", "device", "both"], default="host",
        help="plane producer/consumer backends to sweep; device rows assert "
             "byte-parity of blobs AND bit-exact device decode",
    )
    ap.add_argument(
        "--n", type=int, default=N,
        help="elements per synthetic model (shrink for the CI parity smoke)",
    )
    ap.add_argument(
        "--json", default="BENCH_table3.json",
        help="result file (written on every run)",
    )
    args = ap.parse_args()
    backends = {
        "host": ("host",), "device": ("host", "device"),
        "both": ("host", "device"),
    }[args.backend]
    rows = run(threads=args.threads, backends=backends, n=args.n)
    for r in rows:
        print(r)
    with open(args.json, "w") as f:
        json.dump(
            {
                "bench": "table3_speed",
                "n_elements": args.n,
                "threads": engine.resolve_threads(args.threads),
                "backends": list(backends),
                "parity": "asserted" if "device" in backends else "n/a",
                "rows": rows,
            },
            f,
            indent=2,
        )
    print(f"wrote {args.json}")
    n_threads = engine.resolve_threads(args.threads)
    if n_threads > 1:
        for model in {r["model"] for r in rows}:
            one = next(r for r in rows if r["model"] == model and r["method"] == "ZipNN")
            par = next(
                (r for r in rows if r["model"] == model
                 and r["method"].startswith("ZipNN(threads")), None,
            )
            if par:
                print(
                    f"{model}: threads={n_threads} speedup "
                    f"compress {par['comp_gbps']/one['comp_gbps']:.2f}x "
                    f"decompress {par['decomp_gbps']/one['decomp_gbps']:.2f}x "
                    f"(ratios identical, blobs byte-identical)"
                )


if __name__ == "__main__":
    main()
