"""Table 3: compression ratio + speed, ZipNN vs the LZ+entropy baseline vs
EE+baseline, on the paper's three representative models (regular BF16,
regular FP32, clean FP32).

Baselines: zlib stands in for the zstd-class LZ+entropy family (DESIGN.md
deviation 1).  Default speeds are single-core host numbers, like the
paper's M1 measurements (absolute GB/s differ — C vs Python host — the
*ordering* and ratio deltas are the reproduced claims).

``--threads N`` (paper §5.2: independent chunks compress in parallel)
additionally runs the ZipNN rows through the engine's thread pool and
reports the multi-thread sweep: blobs are asserted byte-identical to the
single-thread run (the engine's determinism contract) and ratios are
therefore identical by construction; only throughput changes.

``--backend device|both`` additionally runs the ZipNN rows through the
device plane-producer backend (fused Pallas dispatch, see
core/device_plane.py) and **asserts byte-parity** against the host blobs —
the backend knob's contract.  The same rows sweep the *decode* side
through the device plane-consumer backend (core/device_unplane.py):
decompress throughput is reported for both backends and the decoded bytes
are asserted bit-identical to the raw input, without touching the host
rows' compress numbers.  On a CPU-only host the kernels run in interpret
mode, so device-row throughput is a correctness artifact, not a speed
claim (flagged in the row).  The device sweep also runs the **full-device
compress path** (fused plane producer + fused Huffman bit-pack entropy
stage, ``core/device_entropy.py``) under the canonical ``huffman`` coder
and asserts those blobs byte-identical to the host canonical coder's.

The run ends with the **compressed-resident serving rows** (``serve_rows``,
skip with ``--no-serve``): the per-layer prefetch/decode ring
(``repro/serve/compressed.py``) vs the plain jitted decode step — logits
asserted bit-identical in lockstep, peak decoded residency asserted ≤ 2
layers, and tokens/sec × HBM weight footprint reported side by side.
Results are written to ``BENCH_table3.json``.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List, Sequence

import numpy as np

from repro.core import baselines, engine, zipnn

from . import corpus

N = 8_000_000


def _timed(fn, *args, reps: int = 1):
    """Best-of-``reps`` wall time (first result is returned)."""
    out, best = None, float("inf")
    for i in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        best = min(best, time.perf_counter() - t0)
        if i == 0:
            out = r
    return out, best


def serve_rows(steps: int = 8) -> List[dict]:
    """Compressed-resident serving row: tokens/sec × HBM weight footprint.

    Drives the prefetch/decode ring (``serve.make_compressed_serve_step``
    over a ``CompressedParamStore``) against the plain jitted decode step
    on a reduced dense model, in lockstep on the same tokens.  Logits are
    asserted **bit-identical** at every step and peak decoded-weight
    residency is asserted ≤ 2 layers — the double-buffer claim.  Params
    are filled from a numpy PCG64 stream (not ``jax.random``) so the
    store's ratio — the gated ``comp_pct`` — is stable across jax
    versions; the ring runs the host decode backend here, so tokens/sec is
    a real host number, but it is reported, not gated (timing fields are
    machine-dependent; only the ratio must match the baseline exactly).
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import CompressedParamStore, make_compressed_serve_step

    cfg = get_config("repro_gpt_100m").reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    leaves, treedef = jax.tree_util.tree_flatten(model.abstract_params())
    params = jax.tree_util.tree_unflatten(
        treedef,
        [
            (rng.standard_normal(l.shape) * 0.02).astype(np.dtype(l.dtype))
            for l in leaves
        ],
    )
    raw_mb = sum(
        int(np.size(l)) * np.dtype(l.dtype).itemsize for l in leaves
    ) / 1e6

    step = jax.jit(model.decode_step)
    store = CompressedParamStore.from_params(params)
    cstep = make_compressed_serve_step(model, store, ring=2)

    B = 2
    toks = [
        jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(steps)
    ]

    # Lockstep parity pass (doubles as compile warmup for both paths).
    sa = model.init_decode_state(B, steps, start_pos=0)
    sb = model.init_decode_state(B, steps, start_pos=0)
    for t in toks:
        la, sa = step(params, sa, t)
        lb, sb = cstep(sb, t)
        if np.asarray(la).tobytes() != np.asarray(lb).tobytes():
            raise AssertionError("serve-ring logits != uncompressed logits")
    if store.peak_resident > 2:
        raise AssertionError(
            f"ring residency {store.peak_resident} layers > 2"
        )

    def drive(fn, state):
        logits = None
        for t in toks:
            logits, state = fn(state, t)
        jax.block_until_ready(logits)

    s0 = model.init_decode_state(B, steps, start_pos=0)
    _, t_u = _timed(lambda: drive(lambda s, t: step(params, s, t), s0))
    s1 = model.init_decode_state(B, steps, start_pos=0)
    _, t_c = _timed(lambda: drive(cstep, s1))

    name = "repro-gpt-100m reduced (serve)"
    return [
        {"model": name, "method": "serve_step",
         "comp_pct": 100.0,
         "tok_per_s": round(B * steps / t_u, 1),
         "hbm_weights_mb": round(raw_mb, 3),
         "comp_gbps": None, "decomp_gbps": None},
        {"model": name, "method": "ZipNN(serve-ring)",
         "comp_pct": round(store.ratio_pct, 1),
         "tok_per_s": round(B * steps / t_c, 1),
         "hbm_weights_mb": round(store.footprint_bytes(2) / 1e6, 3),
         "comp_gbps": None, "decomp_gbps": None,
         "parity": "bit-identical logits",
         "note": "host-ring decode; peak decoded residency asserted <= 2 "
                 "layers (2-layer reduced model: the footprint win "
                 "comp*N + 2 slots < raw*N needs N >> ring)"},
    ]


def run(
    threads: int = 1, backends: Sequence[str] = ("host",), n: int = N,
    serve: bool = True,
) -> List[dict]:
    rows = []
    models = [
        ("Llama-3.1-like BF16", corpus.regular_bf16(n), "bfloat16"),
        ("Olmo-like FP32", corpus.regular_fp32(n), "float32"),
        ("xlm-RoBERTa-like FP32", corpus.clean_fp32(n), "float32"),
    ]
    threads = engine.resolve_threads(threads)    # -1 → all cores, cap at cores
    sweep = [1] if threads <= 1 else [1, threads]
    reps = 1 if len(sweep) == 1 else 3         # sweep mode: denoise timings
    for name, w, dtype in models:
        raw = corpus.as_bytes(w)
        nb = len(raw)

        comp, t_c = _timed(baselines.zlib6, raw, reps=reps)
        _, t_d = _timed(lambda: __import__("zlib").decompress(comp), reps=reps)
        rows.append(
            {"model": name, "method": "zlib(LZ+entropy)",
             "comp_pct": round(100 * len(comp) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3),
             "decomp_gbps": round(nb / t_d / 1e9, 3)}
        )

        ee, t_c = _timed(baselines.ee_zlib, raw, dtype, reps=reps)
        rows.append(
            {"model": name, "method": "EE+zlib",
             "comp_pct": round(100 * len(ee) / nb, 1),
             "comp_gbps": round(nb / t_c / 1e9, 3), "decomp_gbps": None}
        )

        blob_1t = None
        for nt in sweep:
            blob, t_c = _timed(
                lambda: zipnn.compress_bytes(raw, dtype, threads=nt), reps=reps
            )
            back, t_d = _timed(
                lambda: zipnn.decompress_bytes(blob, threads=nt), reps=reps
            )
            assert back == raw
            if nt == 1:
                blob_1t = blob
            else:
                # engine contract: threads change wall-clock, never bytes
                assert blob == blob_1t, "parallel blob != single-thread blob"
            rows.append(
                {"model": name,
                 "method": "ZipNN" if nt == 1 else f"ZipNN(threads={nt})",
                 "comp_pct": round(100 * len(blob) / nb, 1),
                 "comp_gbps": round(nb / t_c / 1e9, 3),
                 "decomp_gbps": round(nb / t_d / 1e9, 3)}
            )

        if "device" in backends:
            import jax

            for nt in sweep:
                dev_blob, t_c = _timed(
                    lambda: zipnn.compress_bytes(
                        raw, dtype, threads=nt, backend="device"
                    ),
                    reps=reps,
                )
                # backend contract: device blobs byte-identical to host
                assert dev_blob == blob_1t, "device blob != host blob"
                dev_back, t_d = _timed(
                    lambda: zipnn.decompress_bytes(
                        dev_blob, threads=nt, backend="device"
                    ),
                    reps=reps,
                )
                # decode contract: device-decoded bytes bit-identical
                assert dev_back == raw, "device decode != raw bytes"
                rows.append(
                    {"model": name,
                     "method": f"ZipNN(device, threads={nt})",
                     "comp_pct": round(100 * len(dev_blob) / nb, 1),
                     "comp_gbps": round(nb / t_c / 1e9, 3),
                     "decomp_gbps": round(nb / t_d / 1e9, 3),
                     "parity": "byte-identical",
                     "note": (
                         "interpret-mode kernels (no TPU): parity check, "
                         "not a speed claim"
                     ) if jax.default_backend() != "tpu" else None}
                )

            # Full-device compress path: fused plane producer AND fused
            # Huffman bit-pack entropy stage (core/device_entropy.py) under
            # the canonical 'huffman' coder; blobs asserted byte-identical
            # to the host canonical coder's.
            cfg_h = zipnn.ZipNNConfig(backend="huffman")
            huff_host, t_hc = _timed(
                lambda: zipnn.compress_bytes(raw, dtype, cfg_h, backend="host"),
                reps=reps,
            )
            huff_back, t_hd = _timed(
                lambda: zipnn.decompress_bytes(huff_host, cfg_h, backend="host"),
                reps=reps,
            )
            assert huff_back == raw, "host huffman decode != raw bytes"
            rows.append(
                {"model": name, "method": "ZipNN(huffman)",
                 "comp_pct": round(100 * len(huff_host) / nb, 1),
                 "comp_gbps": round(nb / t_hc / 1e9, 3),
                 "decomp_gbps": round(nb / t_hd / 1e9, 3)}
            )
            dev_h, t_c = _timed(
                lambda: zipnn.compress_bytes(
                    raw, dtype, cfg_h, backend="device", entropy_backend="device"
                ),
                reps=reps,
            )
            assert dev_h == huff_host, "device-entropy blob != host blob"
            # Full-device decode: the device Huffman decoder kernel feeds
            # the fused un-plane consumer — only compressed bytes cross
            # host→device, and output is asserted bit-identical to raw.
            dev_back, t_d = _timed(
                lambda: zipnn.decompress_bytes(
                    dev_h, cfg_h, backend="device", entropy_backend="device"
                ),
                reps=reps,
            )
            assert dev_back == raw, "device-entropy decode != raw bytes"
            rows.append(
                {"model": name, "method": "ZipNN(device+entropy)",
                 "comp_pct": round(100 * len(dev_h) / nb, 1),
                 "comp_gbps": round(nb / t_c / 1e9, 3),
                 "decomp_gbps": round(nb / t_d / 1e9, 3),
                 "parity": "byte-identical",
                 "note": (
                     "interpret-mode kernels (no TPU): parity check, "
                     "not a speed claim"
                 ) if jax.default_backend() != "tpu" else None}
            )
    if serve:
        rows += serve_rows()
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--threads", type=int, default=1,
        help="engine pool size for the ZipNN sweep (-1 = all cores)",
    )
    ap.add_argument(
        "--backend", choices=["host", "device", "both"], default="host",
        help="plane producer/consumer backends to sweep; device rows assert "
             "byte-parity of blobs AND bit-exact device decode",
    )
    ap.add_argument(
        "--n", type=int, default=N,
        help="elements per synthetic model (shrink for the CI parity smoke)",
    )
    ap.add_argument(
        "--json", default="BENCH_table3.json",
        help="result file (written on every run)",
    )
    ap.add_argument(
        "--no-serve", action="store_true",
        help="skip the compressed-resident serving rows (ring parity + "
             "tokens/sec × HBM footprint)",
    )
    args = ap.parse_args()
    backends = {
        "host": ("host",), "device": ("host", "device"),
        "both": ("host", "device"),
    }[args.backend]
    rows = run(
        threads=args.threads, backends=backends, n=args.n,
        serve=not args.no_serve,
    )
    for r in rows:
        print(r)
    with open(args.json, "w") as f:
        json.dump(
            {
                "bench": "table3_speed",
                "n_elements": args.n,
                "threads": engine.resolve_threads(args.threads),
                "backends": list(backends),
                "parity": "asserted" if "device" in backends else "n/a",
                "rows": rows,
            },
            f,
            indent=2,
        )
    print(f"wrote {args.json}")
    n_threads = engine.resolve_threads(args.threads)
    if n_threads > 1:
        for model in {r["model"] for r in rows}:
            one = next(r for r in rows if r["model"] == model and r["method"] == "ZipNN")
            par = next(
                (r for r in rows if r["model"] == model
                 and r["method"].startswith("ZipNN(threads")), None,
            )
            if par:
                print(
                    f"{model}: threads={n_threads} speedup "
                    f"compress {par['comp_gbps']/one['comp_gbps']:.2f}x "
                    f"decompress {par['decomp_gbps']/one['decomp_gbps']:.2f}x "
                    f"(ratios identical, blobs byte-identical)"
                )


if __name__ == "__main__":
    main()
