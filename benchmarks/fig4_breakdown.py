"""Fig. 4: contribution breakdown — vanilla LZ+entropy vs exponent
extraction vs Huffman-only — on BF16 LM-like weights."""

from __future__ import annotations

import zlib
from typing import List

import numpy as np

from repro.core import baselines, bitlayout, zipnn

from . import corpus

N = 6_000_000


def run() -> List[dict]:
    rows = []
    for name, seed in [("llama3-like", 0), ("granite-like", 21), ("olmo-like", 22)]:
        w = corpus.regular_bf16(N, seed=seed)
        raw = corpus.as_bytes(w)
        nb = len(raw)

        zl = len(baselines.zlib6(raw))
        # Huffman-only, no exponent extraction (paper: speed-only win)
        huff_raw = len(baselines.huffman_only(raw))
        ee = len(baselines.ee_zlib(raw, "bfloat16"))
        znn = len(zipnn.compress_bytes(raw, "bfloat16"))
        rows.append(
            {
                "model": name,
                "zlib_pct": round(100 * zl / nb, 1),
                "huffman_no_EE_pct": round(100 * huff_raw / nb, 1),
                "EE_zlib_pct": round(100 * ee / nb, 1),
                "zipnn_EE_huffman_pct": round(100 * znn / nb, 1),
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
