"""Fig. 7: compressibility of model vs gradients vs optimizer moments
during fine-tuning, with the embedding layer broken out.

Paper findings reproduced: gradients < optimizer < model (compressed size);
the token-embedding layer of gradients/optimizer is extremely compressible
(sparse token usage) and prefers the LZ path (zlib) over Huffman."""

from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.core import zipnn

from . import _train_util


def _ratio_tree(tree, config=zipnn.DEFAULT) -> float:
    man = zipnn.compress_pytree(tree, config)
    return round(100.0 * man["comp_bytes"] / max(man["raw_bytes"], 1), 1)


def _ratio_arr(arr, config=zipnn.DEFAULT) -> float:
    a = np.asarray(arr)
    ct = zipnn.compress_array(a.astype(a.dtype), config)
    return round(zipnn.ratio(a.nbytes, ct.nbytes), 1)


def run() -> List[dict]:
    ckpts, artifacts, _ = _train_util.train_trajectory(epochs=4, steps_per_epoch=2)
    params = ckpts[-1]
    art = artifacts[-1]
    # bf16 view to match the paper's BF16-RoBERTa setting
    import ml_dtypes

    def to_bf16(tree):
        return jax.tree_util.tree_map(
            lambda a: np.asarray(a, np.float32).astype(ml_dtypes.bfloat16), tree
        )

    model_r = _ratio_tree(to_bf16(params))
    grad_r = _ratio_tree(to_bf16(art["grads"]))
    opt_r = _ratio_tree(to_bf16(art["m"]))

    emb_grad = to_bf16(art["grads"])["embed"]["table"]
    delta_cfg = zipnn.ZipNNConfig()          # auto Huffman/LZ per chunk
    emb_grad_zipnn = _ratio_arr(emb_grad)
    blob_lz = zipnn.compress_bytes(
        np.ascontiguousarray(emb_grad).reshape(-1).view(np.uint8),
        "bfloat16", delta_cfg, delta=True,   # delta-mode enables LZ criteria
    )
    emb_grad_lz = round(100.0 * len(blob_lz) / emb_grad.nbytes, 1)

    return [
        {
            "model_pct": model_r,           # paper ≈ 66
            "gradients_pct": grad_r,        # paper ≈ 47
            "optimizer_m_pct": opt_r,       # paper ≈ 54
            "embedding_grad_huffman_pct": emb_grad_zipnn,
            "embedding_grad_lz_pct": emb_grad_lz,   # paper: zstd ≪ huffman here
            "ordering_ok": bool(grad_r < model_r and opt_r < model_r),
        }
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
