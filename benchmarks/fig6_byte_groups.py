"""Fig. 6: clean FP32 model with vs without byte grouping, with per-fraction
-byte compressibility breakdown."""

from __future__ import annotations

import zlib
from typing import List

import numpy as np

from repro.core import zipnn

from . import corpus, table2_ratios

N = 4_000_000


def run() -> List[dict]:
    w = corpus.clean_fp32(N)
    raw = corpus.as_bytes(w)
    nb = len(raw)
    no_bg = len(zlib.compress(raw, 6))                      # no grouping
    znn = len(zipnn.compress_bytes(raw, "float32"))         # EE + byte groups
    planes = table2_ratios.plane_breakdown(w)
    return [
        {
            "model": "xlm-roberta-like (clean FP32)",
            "no_byte_grouping_pct": round(100 * no_bg / nb, 1),
            "zipnn_byte_grouping_pct": round(100 * znn / nb, 1),
            "exponent_plane_pct": planes[0],
            "frac_byte1_pct": planes[1],
            "frac_byte2_pct": planes[2],
            "frac_byte3_pct": planes[3],
        }
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
