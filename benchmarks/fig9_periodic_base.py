"""Fig. 9: periodic-base checkpointing — consecutive deltas vs delta against
a base 5 or 10 epochs back vs standalone compression."""

from __future__ import annotations

from typing import List

from . import _train_util, fig8_delta
from repro.core import zipnn


def run() -> List[dict]:
    ckpts, _, _ = _train_util.train_trajectory(epochs=12, steps_per_epoch=2)
    flats = [fig8_delta._flat_bf16(c) for c in ckpts]
    rows = []
    for ep in range(1, len(flats)):
        cur = flats[ep]
        standalone = zipnn.compress_array(cur).nbytes
        consec = zipnn.delta_compress(cur, flats[ep - 1]).nbytes
        base5 = zipnn.delta_compress(cur, flats[(ep // 5) * 5]).nbytes
        base10 = zipnn.delta_compress(cur, flats[(ep // 10) * 10]).nbytes
        nb = cur.nbytes
        rows.append(
            {
                "epoch": ep,
                "standalone_pct": round(100 * standalone / nb, 1),
                "consecutive_delta_pct": round(100 * consec / nb, 1),
                "base5_delta_pct": round(100 * base5 / nb, 1),
                "base10_delta_pct": round(100 * base10 / nb, 1),
            }
        )
    # paper: periodic-base deltas sit between consecutive and standalone
    last = rows[-1]
    assert last["consecutive_delta_pct"] <= last["base5_delta_pct"] + 1.0
    assert last["base10_delta_pct"] <= last["standalone_pct"] + 1.0
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
