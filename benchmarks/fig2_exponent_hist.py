"""Fig. 2: exponent-value histograms — skew statistics across model types.

Paper: ~40 live exponent values for LMs (~50 for image models); top-12
values ≈ 99.9 % of parameters; distribution nearly identical across models.
"""

from __future__ import annotations

from typing import List

from repro.core import stats

from . import corpus

N = 4_000_000


def run() -> List[dict]:
    rows = []
    for name, gen in [
        ("qwen2-vl-like", corpus.regular_bf16),
        ("llama3-like", lambda n: corpus.regular_bf16(n, seed=11)),
        ("granite-like", lambda n: corpus.regular_fp32(n, seed=12)),
        ("resnet-like", corpus.image_model_fp32),
    ]:
        h = stats.exponent_histogram(gen(N))
        rows.append(
            {
                "model": name,
                "distinct_exponents": h["distinct_values"],
                "top12_mass_pct": round(100 * h["top12_mass"], 2),
                "exp_range": [h["min_exp"], h["max_exp"]],
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
