"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark (derived = the
headline reproduced number), then a detail block per table/figure.
"""

from __future__ import annotations

import argparse
import json
import time


def _runner(name, fn, derive):
    t0 = time.perf_counter()
    rows = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(rows)}", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--no-details", action="store_true")
    args = ap.parse_args()

    from benchmarks import (fig2_exponent_hist, fig4_breakdown,
                            fig6_byte_groups, fig7_grad_optim, fig8_delta,
                            fig9_periodic_base, fig10_end2end, roofline,
                            table1_models, table2_ratios, table3_speed)

    benches = {
        "table1_hub_models": (
            table1_models.run,
            lambda rows: f"mean_abs_err_pct={sum(r['abs_err'] for r in rows)/len(rows):.1f}",
        ),
        "table2_categories": (
            table2_ratios.run,
            lambda rows: "bf16_regular_pct="
            + str(next(r['ours_pct'] for r in rows if 'BF16 regular' in r['category'])),
        ),
        "table3_speed": (
            table3_speed.run,
            lambda rows: "zipnn_beats_zlib_ratio_everywhere="
            + str(all(
                z["comp_pct"] <= l["comp_pct"]
                for z, l in zip(
                    [r for r in rows if r["method"] == "ZipNN"],
                    [r for r in rows if r["method"] == "zlib(LZ+entropy)"],
                )
            )),
        ),
        "fig2_exponent_hist": (
            fig2_exponent_hist.run,
            lambda rows: f"max_distinct_exponents={max(r['distinct_exponents'] for r in rows)}",
        ),
        "fig4_breakdown": (
            fig4_breakdown.run,
            lambda rows: f"zipnn_vs_zlib_gain_pct={rows[0]['zlib_pct'] - rows[0]['zipnn_EE_huffman_pct']:.1f}",
        ),
        "fig6_byte_groups": (
            fig6_byte_groups.run,
            lambda rows: f"bg_gain_pct={rows[0]['no_byte_grouping_pct'] - rows[0]['zipnn_byte_grouping_pct']:.1f}",
        ),
        "fig7_grad_optim": (
            fig7_grad_optim.run,
            lambda rows: f"ordering_ok={rows[0]['ordering_ok']}",
        ),
        "fig8_delta": (
            fig8_delta.run,
            lambda rows: f"final_delta_auto_pct={rows[-1]['delta_auto_pct']}",
        ),
        "fig9_periodic_base": (
            fig9_periodic_base.run,
            lambda rows: f"final_base5_pct={rows[-1]['base5_delta_pct']}",
        ),
        "fig10_end2end": (
            fig10_end2end.run,
            lambda rows: f"max_speedup={max(r['speedup'] for r in rows):.2f}x",
        ),
        "roofline": (
            roofline.run,
            lambda rows: f"cells={len(rows)}",
        ),
    }

    only = set(args.only.split(",")) if args.only else None
    all_rows = {}
    for name, (fn, derive) in benches.items():
        if only and name not in only:
            continue
        try:
            all_rows[name] = _runner(name, fn, derive)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)

    if not args.no_details:
        for name, rows in all_rows.items():
            print(f"\n== {name} ==")
            for r in rows:
                print("  " + json.dumps(r))


if __name__ == "__main__":
    main()
