"""Fig. 8: delta compression across training epochs.

(a) changed parameters vs changed *bytes* per epoch; (b) change rate per
byte group (exponent changes least, low fraction bytes most); (c) delta
compressed size under Huffman vs LZ vs the §4.2 auto-detector (auto must
match the better of the two everywhere)."""

from __future__ import annotations

from typing import List

import jax
import ml_dtypes
import numpy as np

from repro.core import bitlayout, zipnn

from . import _train_util


def _flat_bf16(tree) -> np.ndarray:
    leaves = [
        np.asarray(l, np.float32).astype(ml_dtypes.bfloat16).reshape(-1)
        for l in jax.tree_util.tree_leaves(tree)
    ]
    return np.concatenate(leaves)


def run() -> List[dict]:
    ckpts, _, _ = _train_util.train_trajectory(epochs=8, steps_per_epoch=2)
    layout = bitlayout.layout_for("bfloat16")
    rows = []
    prev = _flat_bf16(ckpts[0])
    for ep in range(1, len(ckpts)):
        cur = _flat_bf16(ckpts[ep])
        xor = np.bitwise_xor(
            cur.view(np.uint16), prev.view(np.uint16)
        )
        changed_params = float((xor != 0).mean())
        xb = xor.view(np.uint8)
        changed_bytes = float((xb != 0).mean())
        planes = bitlayout.to_planes(xb, layout)
        per_group = [round(float((p != 0).mean()) * 100, 1) for p in planes]

        raw = cur.view(np.uint8)
        huff = zipnn.compress_bytes(
            np.bitwise_xor(raw, prev.view(np.uint8)), "bfloat16",
            zipnn.ZipNNConfig(), delta=False,       # force entropy path
        )
        import zlib as _z

        lz = _z.compress(np.bitwise_xor(raw, prev.view(np.uint8)).tobytes(), 6)
        auto = zipnn.delta_compress(cur, prev)
        rows.append(
            {
                "epoch": ep,
                "changed_params_pct": round(changed_params * 100, 1),
                "changed_bytes_pct": round(changed_bytes * 100, 1),
                "per_group_changed_pct": per_group,   # [exp, frac]
                "delta_huffman_pct": round(100 * len(huff) / raw.nbytes, 1),
                "delta_lz_pct": round(100 * len(lz) / raw.nbytes, 1),
                "delta_auto_pct": round(100 * auto.nbytes / raw.nbytes, 1),
            }
        )
        prev = cur
    # auto must track the better method (±1.5 % codec overhead tolerance)
    for r in rows:
        assert r["delta_auto_pct"] <= min(r["delta_huffman_pct"], r["delta_lz_pct"]) + 1.5
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
