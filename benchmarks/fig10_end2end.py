"""Fig. 10: end-to-end hub upload/download times with vs without ZipNN,
across the paper's measured channel classes."""

from __future__ import annotations

from typing import List

from repro.checkpoint.hub import CHANNELS, simulate_transfer

from . import corpus

N = 6_000_000


def run() -> List[dict]:
    rows = []
    models = [
        ("Llama3-like BF16", corpus.regular_bf16(N), "bfloat16"),
        ("Olmo-like FP32", corpus.regular_fp32(N), "float32"),
        ("xlmR-like clean FP32", corpus.clean_fp32(N), "float32"),
    ]
    for name, w, dtype in models:
        raw = corpus.as_bytes(w)
        for channel in CHANNELS:
            direction = "upload" if channel.startswith("upload") else "download"
            rep = simulate_transfer(raw, dtype, channel, direction=direction)
            rows.append(
                {
                    "model": name,
                    "channel": channel,
                    "raw_s": round(rep.total_raw_s, 2),
                    "zipnn_s": round(rep.total_comp_s, 2),
                    "speedup": round(rep.speedup, 2),
                }
            )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
