"""Table 1: compressed size of top-downloaded-hub-model stand-ins.

Synthetic category stand-ins (no network, see corpus.py): Bge/Whisper/
xlm-RoBERTa/Clip are 'clean' categories, Mpnet/Bert regular FP32,
Qwen/Llama-3.1 regular BF16.
"""

from __future__ import annotations

from typing import List

from repro.core import zipnn

from . import corpus

N = 4_000_000

ROWS = [
    # (hub name, generator, dtype, paper compressed %)
    ("Bge", corpus.clean_fp32, "float32", 42.1),
    ("Mpnet", corpus.regular_fp32, "float32", 82.9),
    ("Bert", corpus.regular_fp32, "float32", 83.9),
    ("Qwen", corpus.regular_bf16, "bfloat16", 66.9),
    ("Whisper", corpus.clean_fp32, "float32", 42.7),
    ("xlm-RoBERTa", corpus.clean_fp32, "float32", 42.3),
    ("Clip", corpus.clean_fp32, "float32", 49.7),
    ("Llama-3.1", corpus.regular_bf16, "bfloat16", 67.2),
]


def run() -> List[dict]:
    out = []
    for name, gen, dtype, paper in ROWS:
        w = gen(N)
        ct = zipnn.compress_array(w)
        ratio = zipnn.ratio(w.nbytes, ct.nbytes)
        out.append(
            {"model": name, "ours_pct": round(ratio, 1), "paper_pct": paper,
             "abs_err": round(abs(ratio - paper), 1)}
        )
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
