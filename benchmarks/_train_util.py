"""Shared tiny-training harness for the training-artifact benchmarks
(Fig. 7/8/9): a small in-repo LM fine-tuned for a few steps per 'epoch',
capturing params / grads / optimizer moments checkpoints."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, make_batch
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import init_train_state, make_train_step


def tiny_lm(d_model=192, n_layers=4, vocab=2048):
    cfg = get_config("repro_gpt_100m").reduced()
    return dataclasses.replace(
        cfg, d_model=d_model, n_layers=n_layers, n_heads=4, n_kv_heads=4,
        head_dim=d_model // 4, d_ff=4 * d_model, vocab_size=vocab,
    )


def train_trajectory(
    epochs: int = 10, steps_per_epoch: int = 2, seed: int = 0
) -> Tuple[List[Dict], List[Dict], object]:
    """Returns (checkpoints, grad_snapshots, model). Each checkpoint is the
    host pytree of params; grads/moments captured at epoch boundaries."""
    cfg = tiny_lm()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(seed))
    dc = DataConfig(seq_len=128, global_batch=4, seed=seed)
    # decaying LR like the paper's fine-tuning runs (drives Fig. 8 steps)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=2,
                       total_steps=epochs * steps_per_epoch, min_lr_frac=0.05)
    step_fn = jax.jit(make_train_step(model, ocfg))

    def grab(tree):
        return jax.tree_util.tree_map(lambda a: np.asarray(jax.device_get(a)), tree)

    ckpts, grads = [], []
    k = 0
    for ep in range(epochs):
        for _ in range(steps_per_epoch):
            batch = make_batch(cfg, dc, k)
            state, metrics = step_fn(state, batch)
            k += 1
        ckpts.append(grab(state["params"]))
        # gradient snapshot: fresh grad at current params
        batch = make_batch(cfg, dc, k)
        g = jax.grad(lambda p: model.loss(p, batch)[0])(state["params"])
        grads.append(
            {"grads": grab(g), "m": grab(state["opt"]["m"]), "v": grab(state["opt"]["v"])}
        )
    return ckpts, grads, model
