"""Table 2: ZipNN compressed size per model category with per-byte-group
breakdown (plane 0 = exponent)."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import bitlayout, codec, zipnn

from . import corpus

N = 4_000_000


def plane_breakdown(arr: np.ndarray) -> List[float]:
    """Compressed % per byte-group plane (ZipNN chunked codec per plane)."""
    layout = bitlayout.layout_for(arr.dtype.name)
    planes = bitlayout.to_planes(
        np.ascontiguousarray(arr).reshape(-1).view(np.uint8), layout
    )
    params = zipnn.DEFAULT.plane_params(layout.itemsize)
    out = []
    for p in planes:
        entries, payloads, _ = codec.compress_plane(p, params)
        comp = sum(e.comp_len for e in entries)
        out.append(round(100.0 * comp / max(p.size, 1), 1))
    return out


def run() -> List[dict]:
    rows = []
    for name, (gen, dtype, paper) in corpus.CATEGORIES.items():
        w = gen(N)
        ct = zipnn.compress_array(w)
        rows.append(
            {
                "category": name,
                "dtype": dtype,
                "ours_pct": round(zipnn.ratio(w.nbytes, ct.nbytes), 1),
                "paper_pct": paper,
                "plane_breakdown_pct": plane_breakdown(w),
            }
        )
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
