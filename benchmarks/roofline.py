"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape) on the single-pod 16×16 mesh, from the
trip-count-correct accounting numbers (per-device):

    compute    = flops_dev / peak_flops          (197 TF/s bf16, v5e)
    memory     = bytes_dev / hbm_bw              (819 GB/s)
    collective = coll_bytes_dev / ici_bw         (3 links × ~50 GB/s ≈ 150)

Dominant term = bottleneck; roofline fraction = compute / max(all terms);
useful-compute ratio = MODEL_FLOPS / HLO_FLOPS (catches remat/capacity/
masked-attention overheads)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_PER_LINK = 50e9       # bytes/s per link
ICI_LINKS = 3                # v5e: 3 usable ICI links per chip (2D torus + pod)


def load(dry_dir: str = "experiments/dryrun", mesh: str = "16x16") -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dry_dir, f"*.{mesh}.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("status") != "ok" or not r.get("per_device_accounting"):
            continue
        rows.append(r)
    return rows


def terms(r: Dict) -> Dict:
    acct = r["per_device_accounting"]
    flops = acct["flops"]
    byts = acct["bytes_accessed"]
    coll = sum(v for k, v in acct.items() if k.startswith("coll_") and k != "coll_count")
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_n = coll / (ICI_BW_PER_LINK * ICI_LINKS)
    bound = max(t_c, t_m, t_n)
    dom = {t_c: "compute", t_m: "memory", t_n: "collective"}[bound]
    useful = r["model_flops"] / r["chips"] / max(flops, 1.0)
    mem_gib = r["per_device_memory"]["peak_hint_bytes"] / 2**30
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "kind": r["kind"],
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        "dominant": dom,
        "roofline_frac": t_c / bound if bound else 0.0,
        "useful_flops_ratio": useful,
        "hbm_gib_per_dev": mem_gib,
        "fits_16gib": mem_gib <= 16.0,
        "compile_s": r["compile_s"],
    }


def run(dry_dir: str = "experiments/dryrun") -> List[Dict]:
    rows = [terms(r) for r in load(dry_dir)]
    for row in rows:
        for k in ("compute_s", "memory_s", "collective_s"):
            row[k] = float(f"{row[k]:.4g}")
        row["roofline_frac"] = round(row["roofline_frac"], 3)
        row["useful_flops_ratio"] = round(row["useful_flops_ratio"], 3)
        row["hbm_gib_per_dev"] = round(row["hbm_gib_per_dev"], 2)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "roofline frac | useful ratio | HBM GiB | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | {r['dominant']} | "
            f"{r['roofline_frac']:.2f} | {r['useful_flops_ratio']:.2f} | "
            f"{r['hbm_gib_per_dev']:.2f} | {'y' if r['fits_16gib'] else 'N'} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table(run()))
