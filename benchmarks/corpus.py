"""Synthetic weight corpus matching the paper's model categories.

No network access ⇒ no Hugging Face downloads.  Each generator reproduces
the *bit-level statistics* that drive ZipNN (§3): trained-weight exponent
skew (Gaussian-ish scale mixture ⇒ ~25–45 live exponent values, top-12 ≈
99.9 % mass — validated against paper Fig. 2 in tests/benchmarks), plus the
category transformations (rounding, dtype conversion) that create "clean"
models.  Categories map to the paper's Table 1/2 rows.

Beyond the paper's checkpoint-weight rows, the *component* generators
model the other tensor populations the repo compresses: KV-cache entries
(activations-at-rest, ``serve/kvcache.py``), AdamW optimizer moments
(``checkpoint/manager.py`` moment chains), and fp8/int8 quantized weights
(the sub-byte / integer bit layouts in ``core/bitlayout.py``).  These rows
have no paper Table 2 column (``paper_ratio_pct`` is None) — their ratios
are pinned by the bench-regression gate instead.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import ml_dtypes
import numpy as np


def _trained_like(n: int, seed: int, layers: int = 8) -> np.ndarray:
    """Scale-mixture Gaussian: different tensors have different init scales
    (1/sqrt(fan_in)), matching real checkpoints' exponent spread."""
    rng = np.random.default_rng(seed)
    parts = []
    sizes = rng.multinomial(n, np.ones(layers) / layers)
    for i, sz in enumerate(sizes):
        scale = float(rng.choice([0.5, 0.1, 0.05, 0.02, 0.01, 0.005]))
        parts.append(rng.standard_normal(sz).astype(np.float32) * scale)
    return np.concatenate(parts)


def regular_bf16(n: int, seed: int = 0) -> np.ndarray:
    return _trained_like(n, seed).astype(ml_dtypes.bfloat16)


def regular_fp32(n: int, seed: int = 1) -> np.ndarray:
    return _trained_like(n, seed)


def regular_fp16(n: int, seed: int = 2) -> np.ndarray:
    """llama2-13B-fp16 style: full-precision fp16 weights."""
    return _trained_like(n, seed).astype(np.float16)


def clean_fp32(n: int, seed: int = 3, keep_frac_bits: int = 9) -> np.ndarray:
    """xlm-roberta style: mantissa truncated after training ⇒ low fraction
    bytes zero.  Binary truncation (not decimal rounding — decimal snapping
    collapses values onto a tiny grid and creates whole-float repeats that
    LZ exploits, which real clean checkpoints don't exhibit)."""
    w = _trained_like(n, seed)
    u = w.view(np.uint32)
    mask = np.uint32(0xFFFFFFFF) << np.uint32(23 - keep_frac_bits)
    return (u & mask).view(np.float32).copy()


def very_clean_fp32(n: int, seed: int = 4) -> np.ndarray:
    """t5-base style: fp32 upcast from a half-precision original ⇒ the low
    16 fraction bits are exactly zero."""
    w = _trained_like(n, seed).astype(ml_dtypes.bfloat16)
    return np.asarray(w, dtype=np.float32)


def clean_fp16(n: int, seed: int = 5) -> np.ndarray:
    """stable-video-diffusion style: fp16 converted from BF16 ⇒ trailing
    fraction zeros."""
    w = _trained_like(n, seed).astype(ml_dtypes.bfloat16)
    return np.asarray(w, dtype=np.float16)


def image_model_fp32(n: int, seed: int = 6) -> np.ndarray:
    """resnet-like: BN scales/conv filters widen the exponent range a bit
    (paper Fig. 2: ~50 live exponents vs ~40 for LMs)."""
    rng = np.random.default_rng(seed)
    w = _trained_like(n, seed)
    boost = rng.standard_normal(n // 20).astype(np.float32) * 4.0
    w[: boost.size] = boost
    return w


def kv_cache_bf16(n: int, seed: int = 7, heads: int = 16) -> np.ndarray:
    """KV-cache-like BF16: attention keys/values at rest.  Post-norm
    activations sit at O(1) scale with per-head spread — a narrower, hotter
    exponent band than weights, still exponent-skewed enough for the
    byte-group pipeline (the ``serve/kvcache.py`` cold-tier payload)."""
    rng = np.random.default_rng(seed)
    parts = []
    for sz in rng.multinomial(n, np.ones(heads) / heads):
        scale = float(rng.choice([2.0, 1.0, 0.7, 0.5, 0.3]))
        parts.append(rng.standard_normal(sz).astype(np.float32) * scale)
    return np.concatenate(parts).astype(ml_dtypes.bfloat16)


def adam_moments_fp32(n: int, seed: int = 8) -> np.ndarray:
    """AdamW optimizer moments: first half ``m`` (EMA of gradients —
    signed, gradient-scale), second half ``v`` (EMA of squared gradients —
    positive, tiny, heavy-tailed).  The ``CheckpointManager`` moment-chain
    payload: wide negative exponents, no sign bit entropy in ``v``."""
    half = n // 2
    m = _trained_like(half, seed) * 1e-2
    v = np.square(_trained_like(n - half, seed + 1) * 1e-2)
    return np.concatenate([m, v]).astype(np.float32)


def fp8_e4m3(n: int, seed: int = 9) -> np.ndarray:
    """fp8-quantized weights (e4m3): trained-weight distribution cast down
    — 4 exponent bits still dominate the high nibble plane."""
    return _trained_like(n, seed).astype(ml_dtypes.float8_e4m3fn)


def fp8_e5m2(n: int, seed: int = 10) -> np.ndarray:
    """fp8-quantized weights (e5m2): wider exponent, 2-bit fraction."""
    return _trained_like(n, seed).astype(ml_dtypes.float8_e5m2)


def int8_quantized(n: int, seed: int = 11, channels: int = 64) -> np.ndarray:
    """int8 weights under symmetric per-channel quantization: each channel
    rescaled to the full [-127, 127] range (absmax), so the byte histogram
    is the bell the ``i8`` whole-byte layout order-0 codes."""
    w = _trained_like(n, seed)
    out = np.empty(n, dtype=np.int8)
    for idx in np.array_split(np.arange(n), channels):
        scale = max(float(np.abs(w[idx]).max()) / 127.0, 1e-12)
        out[idx] = np.clip(np.rint(w[idx] / scale), -127, 127).astype(np.int8)
    return out


CATEGORIES: Dict[str, Tuple[Callable[[int], np.ndarray], str, Optional[float]]] = {
    # name: (generator, dtype_name, paper_ratio_pct)
    "llama3-like (BF16 regular)": (regular_bf16, "bfloat16", 66.4),
    "olmo-like (FP32 regular)": (regular_fp32, "float32", 83.1),
    "llama2-like (FP16 regular)": (regular_fp16, "float16", 66.6),
    "xlm-roberta-like (FP32 clean)": (clean_fp32, "float32", 41.8),
    "t5-like (FP32 upcast)": (very_clean_fp32, "float32", 33.7),
    "svd-like (FP16 from BF16)": (clean_fp16, "float16", 84.8),
    "resnet-like (FP32 image)": (image_model_fp32, "float32", 83.3),
    # Component payloads (no paper column — gated by the bench baseline).
    "kv-cache-like (BF16 activations)": (kv_cache_bf16, "bfloat16", None),
    "adam-moments (FP32 m+v)": (adam_moments_fp32, "float32", None),
    "fp8-quantized (E4M3)": (fp8_e4m3, "float8_e4m3fn", None),
    "fp8-quantized (E5M2)": (fp8_e5m2, "float8_e5m2", None),
    "int8-quantized (per-channel)": (int8_quantized, "int8", None),
}


def as_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8).tobytes()
