"""Synthetic weight corpus matching the paper's model categories.

No network access ⇒ no Hugging Face downloads.  Each generator reproduces
the *bit-level statistics* that drive ZipNN (§3): trained-weight exponent
skew (Gaussian-ish scale mixture ⇒ ~25–45 live exponent values, top-12 ≈
99.9 % mass — validated against paper Fig. 2 in tests/benchmarks), plus the
category transformations (rounding, dtype conversion) that create "clean"
models.  Categories map to the paper's Table 1/2 rows.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import ml_dtypes
import numpy as np


def _trained_like(n: int, seed: int, layers: int = 8) -> np.ndarray:
    """Scale-mixture Gaussian: different tensors have different init scales
    (1/sqrt(fan_in)), matching real checkpoints' exponent spread."""
    rng = np.random.default_rng(seed)
    parts = []
    sizes = rng.multinomial(n, np.ones(layers) / layers)
    for i, sz in enumerate(sizes):
        scale = float(rng.choice([0.5, 0.1, 0.05, 0.02, 0.01, 0.005]))
        parts.append(rng.standard_normal(sz).astype(np.float32) * scale)
    return np.concatenate(parts)


def regular_bf16(n: int, seed: int = 0) -> np.ndarray:
    return _trained_like(n, seed).astype(ml_dtypes.bfloat16)


def regular_fp32(n: int, seed: int = 1) -> np.ndarray:
    return _trained_like(n, seed)


def regular_fp16(n: int, seed: int = 2) -> np.ndarray:
    """llama2-13B-fp16 style: full-precision fp16 weights."""
    return _trained_like(n, seed).astype(np.float16)


def clean_fp32(n: int, seed: int = 3, keep_frac_bits: int = 9) -> np.ndarray:
    """xlm-roberta style: mantissa truncated after training ⇒ low fraction
    bytes zero.  Binary truncation (not decimal rounding — decimal snapping
    collapses values onto a tiny grid and creates whole-float repeats that
    LZ exploits, which real clean checkpoints don't exhibit)."""
    w = _trained_like(n, seed)
    u = w.view(np.uint32)
    mask = np.uint32(0xFFFFFFFF) << np.uint32(23 - keep_frac_bits)
    return (u & mask).view(np.float32).copy()


def very_clean_fp32(n: int, seed: int = 4) -> np.ndarray:
    """t5-base style: fp32 upcast from a half-precision original ⇒ the low
    16 fraction bits are exactly zero."""
    w = _trained_like(n, seed).astype(ml_dtypes.bfloat16)
    return np.asarray(w, dtype=np.float32)


def clean_fp16(n: int, seed: int = 5) -> np.ndarray:
    """stable-video-diffusion style: fp16 converted from BF16 ⇒ trailing
    fraction zeros."""
    w = _trained_like(n, seed).astype(ml_dtypes.bfloat16)
    return np.asarray(w, dtype=np.float16)


def image_model_fp32(n: int, seed: int = 6) -> np.ndarray:
    """resnet-like: BN scales/conv filters widen the exponent range a bit
    (paper Fig. 2: ~50 live exponents vs ~40 for LMs)."""
    rng = np.random.default_rng(seed)
    w = _trained_like(n, seed)
    boost = rng.standard_normal(n // 20).astype(np.float32) * 4.0
    w[: boost.size] = boost
    return w


CATEGORIES: Dict[str, Tuple[Callable[[int], np.ndarray], str, float]] = {
    # name: (generator, dtype_name, paper_ratio_pct)
    "llama3-like (BF16 regular)": (regular_bf16, "bfloat16", 66.4),
    "olmo-like (FP32 regular)": (regular_fp32, "float32", 83.1),
    "llama2-like (FP16 regular)": (regular_fp16, "float16", 66.6),
    "xlm-roberta-like (FP32 clean)": (clean_fp32, "float32", 41.8),
    "t5-like (FP32 upcast)": (very_clean_fp32, "float32", 33.7),
    "svd-like (FP16 from BF16)": (clean_fp16, "float16", 84.8),
    "resnet-like (FP32 image)": (image_model_fp32, "float32", 83.3),
}


def as_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).reshape(-1).view(np.uint8).tobytes()
