"""Property/fuzz round-trip tests: compress_bytes → decompress_bytes is the
identity for arbitrary payloads, across backend × threads (ISSUE 3
satellite).

Strategies run through ``tests/_hyp_compat.py`` (real hypothesis when
installed, a seeded fallback otherwise).  Coverage axes: every registered
dtype layout, odd/empty/huge-tail lengths, NaN/Inf/denormal payloads, both
entropy coders, backend ∈ {host, device, auto} × threads ∈ {1, 4}.
"""

import ml_dtypes
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

import parity
from repro.core import bitlayout, zipnn

ALL_DTYPES = sorted(bitlayout.LAYOUTS)          # includes int/uint/bool
SMALL_CFG = zipnn.ZipNNConfig(chunk_param_bytes=1 << 14)


def _roundtrip(raw: bytes, dtype_name: str, backend: str, threads: int) -> None:
    blob = zipnn.compress_bytes(raw, dtype_name, SMALL_CFG, backend=backend)
    ref = zipnn.compress_bytes(raw, dtype_name, SMALL_CFG, backend="host")
    assert blob == ref, f"{dtype_name}/{backend}: encode bytes differ from host"
    for be in ("host", backend):
        out = zipnn.decompress_bytes(blob, SMALL_CFG, threads=threads, backend=be)
        assert out == raw, f"{dtype_name}/{be}×{threads}: round-trip not identity"


@given(
    st.sampled_from(ALL_DTYPES),
    st.integers(min_value=0, max_value=40_000),
    st.sampled_from(["host", "device", "auto"]),
    st.sampled_from([1, 4]),
    st.integers(min_value=0, max_value=1 << 30),
)
@settings(max_examples=40, deadline=None)
def test_random_bytes_roundtrip(dtype_name, n_bytes, backend, threads, seed):
    """Arbitrary byte streams (any length, any dtype interpretation, any
    backend × threads) round-trip bit-exactly — lengths are deliberately
    NOT aligned to the itemsize, so TAIL frames fuzz too."""
    rng = np.random.default_rng(seed)
    raw = rng.integers(0, 256, n_bytes, dtype=np.uint8).tobytes()
    _roundtrip(raw, dtype_name, backend, threads)


@given(
    st.sampled_from(list(parity.DTYPES)),
    st.sampled_from(list(parity.PAYLOAD_KINDS)),
    st.integers(min_value=0, max_value=30_000),
    st.integers(min_value=0, max_value=1 << 20),
)
@settings(max_examples=30, deadline=None)
def test_float_payloads_roundtrip(dtype_name, kind, n, seed):
    """Weight-like, NaN/Inf, denormal, zero and uniform-bit float payloads
    round-trip across every backend (device sweep via the shared harness)."""
    arr = parity.make_array(dtype_name, n, seed=seed, kind=kind)
    parity.assert_decode_parity(
        parity.as_bytes(arr), dtype_name, config=SMALL_CFG,
        label=f"{dtype_name}/{kind}/n={n}",
    )


@pytest.mark.parametrize("dtype", parity.DTYPES)
@pytest.mark.parametrize("kind", ["nan_inf", "denormal"])
def test_special_values_exact(dtype, kind):
    """Deterministic NaN/Inf/denormal coverage: the bit patterns survive
    rotate/un-rotate on both backends exactly (no canonicalization)."""
    arr = parity.make_array(dtype, 20_000, seed=99, kind=kind)
    raw = parity.as_bytes(arr)
    npdt = np.dtype(parity.NP_DTYPES[dtype])
    if kind == "nan_inf":
        assert np.isnan(np.asarray(arr, np.float32)).any()
    blob = zipnn.compress_bytes(raw, dtype)
    for be in ("host", "device"):
        out = zipnn.decompress_bytes(blob, backend=be)
        np.testing.assert_array_equal(
            np.frombuffer(out, npdt).view(np.uint8),
            np.frombuffer(raw, np.uint8),
        )


@pytest.mark.parametrize("dtype", ["bfloat16", "float32"])
def test_huge_tail_shapes(dtype):
    """Every possible remainder r in [1, itemsize) rides the TAIL frame."""
    itemsize = np.dtype(parity.NP_DTYPES[dtype]).itemsize
    body = parity.as_bytes(parity.make_array(dtype, 9_001, seed=7))
    for r in range(1, itemsize):
        raw = body + bytes(range(1, r + 1))
        parity.assert_decode_parity(
            raw, dtype, backends=("host", "device"), threads=(1, 4),
            label=f"{dtype} tail r={r}",
        )


@given(
    st.sampled_from(["bfloat16", "float32", "float16"]),
    st.integers(min_value=1, max_value=20_000),
    st.floats(min_value=0.0, max_value=0.2),
    st.integers(min_value=0, max_value=1 << 20),
)
@settings(max_examples=15, deadline=None)
def test_delta_roundtrip_fuzz(dtype_name, n, change_frac, seed):
    """Random (new, base) pairs with a random changed fraction round-trip
    through the delta path across backend × threads."""
    base = parity.make_array(dtype_name, n, seed=seed)
    new = np.asarray(base).copy()
    n_changed = int(n * change_frac)
    if n_changed:
        rng = np.random.default_rng(seed + 1)
        idx = rng.integers(0, n, n_changed)
        new[idx] = parity.make_array(dtype_name, n_changed, seed=seed + 2, kind="bits")
    parity.assert_delta_parity(
        new, base, backends=("host", "device"), threads=(1, 4),
        label=f"{dtype_name} delta n={n}",
    )


@pytest.mark.slow
def test_full_parity_sweep():
    """The complete dtype × shape × payload × delta × backend × threads
    sweep from the shared harness — the heavyweight version of the CI
    smoke (`python tests/parity.py --smoke`)."""
    cases = parity.sweep()
    assert cases >= 100
