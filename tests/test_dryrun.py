"""Dry-run machinery tests: collective parser, input specs, shape-cell
applicability, and one real (subprocess) lower+compile on the production
mesh."""

import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_cells

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestCollectiveParser:
    def _parse(self, text):
        from repro.launch import dryrun

        return dryrun.collective_bytes(text)

    def test_basic_ops(self):
        hlo = """
  %all-reduce.1 = f32[128,64]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  %ag = bf16[2048,512]{1,0} all-gather(%p), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[16,8]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[4,4]<=[16], to_apply=%add
  %cp = u8[1000]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
"""
        out = self._parse(hlo)
        assert out["all-reduce"] == 2 * 128 * 64 * 4
        assert out["all-gather"] == 2048 * 512 * 2
        assert out["reduce-scatter"] == 16 * 8 * 4 * 3     # gs=4 → ×3
        assert out["collective-permute"] == 1000
        assert out["count"] == 4

    def test_async_pairs_count_once(self):
        hlo = """
  %ags = (f32[8]{0}, f32[128]{0}) all-gather-start(%p), channel_id=1, replica_groups=[1,16]<=[16], dimensions={0}
  %agd = f32[128]{0} all-gather-done(%ags)
"""
        out = self._parse(hlo)
        assert out["all-gather"] == 128 * 4
        assert out["count"] == 1

    def test_non_collectives_ignored(self):
        out = self._parse("  %f = f32[10]{0} fusion(%a), kind=kLoop\n")
        assert out["count"] == 0


class TestCellApplicability:
    def test_encoder_skips_decode(self):
        cells = [c.name for c in shape_cells(get_config("hubert_xlarge"))]
        assert cells == ["train_4k", "prefill_32k"]

    def test_full_attention_skips_500k(self):
        for arch in ["yi_6b", "granite_20b", "qwen15_4b", "olmoe_1b_7b",
                     "deepseek_v2_236b", "qwen2_vl_2b"]:
            cells = [c.name for c in shape_cells(get_config(arch))]
            assert "long_500k" not in cells, arch
            assert "decode_32k" in cells, arch

    def test_subquadratic_runs_500k(self):
        for arch in ["mamba2_130m", "zamba2_7b", "h2o_danube3_4b"]:
            cells = [c.name for c in shape_cells(get_config(arch))]
            assert "long_500k" in cells, arch

    def test_total_cell_count(self):
        total = sum(len(shape_cells(get_config(a))) for a in list_archs())
        assert total == 32          # 40 nominal − 6 long_500k − 2 encoder decode


class TestInputSpecs:
    def test_train_specs_shapes(self):
        from repro.launch.dryrun import input_specs

        specs = input_specs("yi_6b", "train_4k")
        assert specs["batch"]["tokens"].shape == (256, 4096)
        n_params = sum(
            int(__import__("math").prod(l.shape))
            for l in jax.tree_util.tree_leaves(specs["state"]["params"])
        )
        assert 5.5e9 < n_params < 7.5e9

    def test_decode_specs_cache(self):
        from repro.launch.dryrun import input_specs

        specs = input_specs("yi_6b", "decode_32k")
        assert specs["tokens"].shape == (128, 1)
        assert specs["dstate"]["kv_k"].shape == (32, 128, 32768, 4, 128)

    def test_swa_decode_cache_is_window_bounded(self):
        from repro.launch.dryrun import input_specs

        specs = input_specs("h2o_danube3_4b", "long_500k")
        # SWA ⇒ ring cache of window size, not 524288
        assert specs["dstate"]["kv_k"].shape[2] == 8192

    def test_mla_decode_caches_latent(self):
        from repro.launch.dryrun import input_specs

        specs = input_specs("deepseek_v2_236b", "decode_32k")
        assert specs["dstate"]["mla_ckv"].shape == (60, 128, 32768, 512)
        assert specs["dstate"]["mla_kr"].shape == (60, 128, 32768, 64)


@pytest.mark.slow
def test_one_real_dryrun_cell(tmp_path):
    """End-to-end: lower+compile mamba2 decode on the 16×16 production mesh
    in a subprocess (the only place 512 placeholder devices exist)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2_130m",
         "--shape", "decode_32k", "--mesh", "single", "--out", str(tmp_path)],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    fn = tmp_path / "mamba2_130m.decode_32k.16x16.json"
    data = json.loads(fn.read_text())
    assert data["status"] == "ok"
    assert data["chips"] == 256
    assert data["per_device_accounting"]["flops"] > 0
