"""Unified codec-options API: the bag, the deprecation shim, the session.

The api_redesign contract (docs/INVARIANTS.md): ``CodecOptions`` routes the
same values the legacy ``threads=``/``backend=``/``entropy_backend=``
kwargs did — bytes identical on every combination — with precedence

    explicit legacy kwarg  >  options field  >  ZipNNConfig field

and a DeprecationWarning on the legacy codec knobs only
(``device_resident`` is a semantic flag, never deprecated).
"""

import contextlib
import dataclasses
import warnings

import ml_dtypes
import numpy as np
import pytest

from repro.core import zipnn
from repro.core.options import (
    CodecOptions,
    DEFAULT_OPTIONS,
    ZipNNSession,
    resolve_options,
)


def _payload(n=20_000, seed=0):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal(n) * 0.02).astype(ml_dtypes.bfloat16)
    return np.ascontiguousarray(w).reshape(-1).view(np.uint8).tobytes()


@contextlib.contextmanager
def _no_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        yield


# --- the bag ---------------------------------------------------------------


def test_options_frozen_and_hashable():
    opts = CodecOptions(threads=4, backend="device")
    with pytest.raises(dataclasses.FrozenInstanceError):
        opts.threads = 8
    assert hash(opts) == hash(CodecOptions(threads=4, backend="device"))
    assert opts.replace(threads=1) == CodecOptions(threads=1, backend="device")
    assert opts.replace(threads=1) is not opts


def test_default_options_is_all_defer():
    assert DEFAULT_OPTIONS == CodecOptions()
    assert DEFAULT_OPTIONS.threads is None
    assert DEFAULT_OPTIONS.backend is None
    assert DEFAULT_OPTIONS.entropy_backend is None
    assert DEFAULT_OPTIONS.device_resident is False


# --- the shim --------------------------------------------------------------


def test_resolve_precedence_kwarg_over_field():
    opts = CodecOptions(threads=4, backend="device", entropy_backend="device")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        merged = resolve_options(opts, threads=1, backend="host")
    assert merged.threads == 1            # explicit kwarg wins
    assert merged.backend == "host"       # explicit kwarg wins
    assert merged.entropy_backend == "device"  # untouched field survives


def test_resolve_options_passthrough_no_warning():
    opts = CodecOptions(threads=2)
    with _no_warnings():
        assert resolve_options(opts) is opts
        assert resolve_options(None) is DEFAULT_OPTIONS


def test_legacy_codec_kwargs_warn():
    for kw in ({"threads": 2}, {"backend": "host"}, {"entropy_backend": "host"}):
        with pytest.warns(DeprecationWarning):
            resolve_options(None, **kw)


def test_device_resident_kwarg_does_not_warn():
    with _no_warnings():
        merged = resolve_options(CodecOptions(), device_resident=True)
    assert merged.device_resident is True


def test_entry_points_warn_on_legacy_not_on_options():
    raw = _payload(4096)
    with pytest.warns(DeprecationWarning):
        legacy = zipnn.compress_bytes(raw, "bfloat16", threads=2)
    with _no_warnings():
        bagged = zipnn.compress_bytes(
            raw, "bfloat16", options=CodecOptions(threads=2)
        )
    assert legacy == bagged
    with _no_warnings():
        assert zipnn.decompress_bytes(bagged, options=CodecOptions()) == raw


def test_explicit_none_options_is_default():
    raw = _payload(4096)
    with _no_warnings():
        assert zipnn.compress_bytes(raw, "bfloat16", options=None) == (
            zipnn.compress_bytes(raw, "bfloat16")
        )


# --- byte-identity across the knob matrix ----------------------------------


def test_session_bytes_identical_across_knob_matrix():
    """The bag only routes values: session blobs must be byte-identical to
    the legacy per-kwarg calls AND across every knob combination."""
    raw = _payload(8192)
    combos = [
        CodecOptions(),
        CodecOptions(threads=1),
        CodecOptions(threads=4),
        CodecOptions(backend="device"),
        CodecOptions(threads=4, backend="device"),
    ]
    blobs = []
    for opts in combos:
        with _no_warnings():
            blobs.append(ZipNNSession(options=opts).compress_bytes(raw, "bfloat16"))
    assert all(b == blobs[0] for b in blobs), "knobs changed bytes"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = zipnn.compress_bytes(raw, "bfloat16", threads=4, backend="device")
    assert legacy == blobs[0]
    for opts in combos:
        with _no_warnings():
            assert ZipNNSession(options=opts).decompress_bytes(blobs[0]) == raw


def test_session_huffman_entropy_backend_matrix():
    raw = _payload(4096, seed=3)
    cfg = zipnn.ZipNNConfig(backend="huffman")
    host = ZipNNSession(cfg, CodecOptions(backend="host")).compress_bytes(
        raw, "bfloat16"
    )
    dev = ZipNNSession(
        cfg, CodecOptions(backend="device", entropy_backend="device")
    ).compress_bytes(raw, "bfloat16")
    assert host == dev
    assert (
        ZipNNSession(
            cfg, CodecOptions(backend="device", entropy_backend="device")
        ).decompress_bytes(host)
        == raw
    )


def test_session_array_pytree_and_delta_route():
    rng = np.random.default_rng(4)
    arr = (rng.standard_normal(5000) * 0.02).astype(ml_dtypes.bfloat16)
    sess = ZipNNSession(options=CodecOptions(threads=2))
    with _no_warnings():
        ct = sess.compress_array(arr)
        back = sess.decompress_array(ct)
    assert back.tobytes() == arr.tobytes()
    assert zipnn.compress_array(arr).blob == ct.blob

    tree = {"wte": arr.reshape(50, 100), "step": np.asarray(3, np.int32)}
    with _no_warnings():
        manifest = sess.compress_pytree(tree)
        rt = sess.decompress_pytree(manifest)
    assert rt["wte"].tobytes() == tree["wte"].tobytes()

    base = arr
    new = arr.copy()
    new[:100] = (np.asarray(new[:100], np.float32) * 1.01).astype(arr.dtype)
    with _no_warnings():
        d = sess.delta_compress(new, base)
        restored = sess.delta_decompress(d, base)
    assert restored.tobytes() == new.tobytes()


def test_session_device_resident_override():
    """device_resident keeps leaves on device when the decode backend
    resolves to device; host-resolved leaves stay numpy (documented)."""
    import jax

    arr = (np.random.default_rng(5).standard_normal(2048) * 0.02).astype(
        np.float32
    )
    sess = ZipNNSession(options=CodecOptions(backend="device"))
    ct = sess.compress_array(arr)
    host = sess.decompress_array(ct, device_resident=False)
    assert isinstance(host, np.ndarray)
    dev = sess.decompress_array(ct, device_resident=True)
    assert isinstance(dev, jax.Array)
    assert np.asarray(dev).tobytes() == arr.tobytes()


# --- options follow-through on the plumbing surfaces -----------------------


def test_grad_sync_accepts_options_bag():
    from repro.distributed.grad_sync import GradSync

    grads = {"w": (np.random.default_rng(6).standard_normal(4096) * 1e-3
                   ).astype(np.float32)}
    with _no_warnings():
        gs = GradSync(options=CodecOptions(threads=2))
        manifest, stats = gs.pack(grads)
        back = gs.unpack(manifest)
    assert np.asarray(back["w"]).tobytes() == grads["w"].tobytes()
    with pytest.warns(DeprecationWarning):
        legacy = GradSync(threads=2)
    legacy_manifest, legacy_stats = legacy.pack(grads)
    assert legacy_stats.comp_bytes == stats.comp_bytes


def test_hub_simulate_transfer_accepts_options_bag():
    from repro.checkpoint import hub

    data = _payload(4096, seed=7)
    with _no_warnings():
        rep = hub.simulate_transfer(
            data, "bfloat16", "cached_download_cloud",
            options=CodecOptions(threads=2),
        )
    assert rep.comp_bytes < rep.raw_bytes
    with pytest.warns(DeprecationWarning):
        hub.simulate_transfer(
            data, "bfloat16", "cached_download_cloud", threads=2
        )


def test_checkpoint_config_folds_options(tmp_path):
    from repro.checkpoint.manager import CheckpointConfig

    cfg = CheckpointConfig(
        directory=str(tmp_path),
        options=CodecOptions(threads=3, backend="device",
                             entropy_backend="host"),
    )
    assert cfg.threads == 3
    assert cfg.backend == "device"
    assert cfg.entropy_backend == "host"
    assert cfg.zipnn.threads == 3
    assert cfg.zipnn.plane_backend == "device"
    # explicit legacy fields still win over the bag
    cfg2 = CheckpointConfig(
        directory=str(tmp_path), threads=1,
        options=CodecOptions(threads=8),
    )
    assert cfg2.threads == 1


def test_compressed_param_store_accepts_options_bag():
    from repro.serve.compressed import CompressedParamStore

    params = {
        "wte": (np.random.default_rng(8).standard_normal((64, 32)) * 0.02
                ).astype(ml_dtypes.bfloat16)
    }
    with _no_warnings():
        store = CompressedParamStore.from_params(
            params, options=CodecOptions(threads=2)
        )
    with pytest.warns(DeprecationWarning):
        legacy = CompressedParamStore.from_params(params, threads=2)
    assert store.ratio_pct == legacy.ratio_pct
