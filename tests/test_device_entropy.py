"""Device entropy-stage backend (core/device_entropy.py + kernels/bitpack.py).

Contract under test: with the canonical ``huffman`` coder, blobs produced
with ``entropy_backend="device"`` (fused Pallas bit-pack dispatch) are
**byte-identical** to the host encoder's for every plane backend × thread
count — including the final partial chunk, the expansion-guard raw-chunk
path, and the §4.2 delta mix — and the ``hufflib`` coder silently falls
back to host.
"""

import io

import ml_dtypes
import numpy as np
import pytest

from repro.core import codec, device_entropy, engine, huffman, zipnn
from parity import as_bytes, make_array

HUFF_CFG = zipnn.ZipNNConfig(chunk_param_bytes=1 << 15, backend="huffman")


# ---------------------------------------------------------------------------
# kernel-level parity: fused bit-pack vs the vectorized host encoder
# ---------------------------------------------------------------------------

def _skewed_plane(n: int, seed: int) -> np.ndarray:
    """Exponent-plane-like bytes: a handful of hot values (compressible)."""
    rng = np.random.default_rng(seed)
    p = np.r_[np.full(16, 0.05), np.full(240, 0.2 / 240)]
    return rng.choice(256, p=p, size=n).astype(np.uint8)


@pytest.mark.parametrize("chunk_bytes", [4096, 16384])
@pytest.mark.parametrize(
    "n", [4096, 16384 * 3, 16384 * 2 + 5_001, 1 << 15]
)  # whole chunks, multi-chunk, final partial chunk
def test_encode_planes_matches_compress_plane(chunk_bytes, n):
    params = codec.CodecParams(chunk_bytes=chunk_bytes, backend="huffman")
    plane = _skewed_plane(n, seed=chunk_bytes + n)
    want = codec.compress_plane(plane, params)
    entries, payloads, tables = device_entropy.encode_planes(
        [plane], [None], params
    )
    assert entries[0] == want[0]
    assert payloads[0] == want[1]
    assert tables[0] == want[2]


def test_encode_planes_multi_table_one_dispatch():
    """Planes with different tables (different byte statistics) pack under
    their own table rows of the single stacked dispatch."""
    params = codec.CodecParams(chunk_bytes=4096, backend="huffman")
    planes = [
        _skewed_plane(4096 * 2 + 777, seed=1),
        (np.arange(4096 * 3) % 7).astype(np.uint8),        # very skewed
        _skewed_plane(4096, seed=2)[::-1].copy(),
    ]
    entries, payloads, tables = device_entropy.encode_planes(
        planes, [None] * len(planes), params
    )
    for plane, e, p, t in zip(planes, entries, payloads, tables):
        we, wp, wt = codec.compress_plane(plane, params)
        assert (e, p, t) == (we, wp, wt)


def test_expansion_guard_stores_raw():
    """Chunks whose packed size reaches raw size are stored raw — same
    metadata map as the host path."""
    params = codec.CodecParams(
        chunk_bytes=4096, backend="huffman", incompressible=1.01, skip_chunks=0
    )
    rng = np.random.default_rng(9)
    plane = rng.integers(0, 256, 4096 * 2 + 123, dtype=np.uint8)  # ~8 bits/byte
    want_e, want_p, want_t = codec.compress_plane(plane, params)
    entries, payloads, tables = device_entropy.encode_planes(
        [plane], [None], params
    )
    assert any(e.method == codec.Method.STORE for e in entries[0])
    assert entries[0] == want_e and payloads[0] == want_p and tables[0] == want_t


def test_supports_envelope():
    huff = codec.CodecParams(chunk_bytes=16384, backend="huffman")
    assert device_entropy.supports(None, huff)
    assert not device_entropy.supports(
        None, codec.CodecParams(chunk_bytes=16384, backend="hufflib")
    )
    assert not device_entropy.supports(
        None, codec.CodecParams(chunk_bytes=16385, backend="huffman")
    )
    assert device_entropy.resolve("device", None, huff) == "device"
    assert device_entropy.resolve("auto", None, huff) == "host"  # host leaf
    assert device_entropy.resolve(None, None, huff) == "host"
    with pytest.raises(ValueError):
        device_entropy.resolve("gpu", None, huff)


# ---------------------------------------------------------------------------
# end-to-end: the entropy_backend knob through the public API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["bfloat16", "float32", "float16"])
@pytest.mark.parametrize("n", [3, 40_001])
def test_compress_bytes_parity(dtype, n):
    raw = as_bytes(make_array(dtype, n, seed=n, kind="normal"))
    ref = zipnn.compress_bytes(raw, dtype, HUFF_CFG, backend="host")
    for be, ebe in [
        ("host", "device"),        # mixed: host planes, device bit-pack
        ("device", "host"),        # mixed: device planes, host bit-pack
        ("device", "device"),      # full device
        ("device", None),          # backend="device" implies entropy device
    ]:
        blob = zipnn.compress_bytes(
            raw, dtype, HUFF_CFG, backend=be, entropy_backend=ebe
        )
        assert blob == ref, (be, ebe)
    assert zipnn.decompress_bytes(ref, HUFF_CFG) == raw


def test_hufflib_coder_falls_back_to_host():
    raw = as_bytes(make_array("bfloat16", 30_000, seed=0))
    cfg = zipnn.ZipNNConfig(chunk_param_bytes=1 << 15)      # hufflib coder
    assert zipnn.compress_bytes(
        raw, "bfloat16", cfg, entropy_backend="device"
    ) == zipnn.compress_bytes(raw, "bfloat16", cfg, backend="host")


def test_config_field_and_threads():
    raw = as_bytes(make_array("float32", 50_000, seed=3))
    cfg = zipnn.ZipNNConfig(
        chunk_param_bytes=1 << 15, backend="huffman", entropy_backend="device"
    )
    ref = zipnn.compress_bytes(raw, "float32", HUFF_CFG, backend="host")
    for t in (1, 4):
        assert zipnn.compress_bytes(raw, "float32", cfg, threads=t) == ref


def test_delta_device_entropy():
    base = make_array("bfloat16", 40_001, seed=7)
    new = np.asarray(base).copy()
    rng = np.random.default_rng(8)
    idx = rng.integers(0, new.size, new.size // 50)
    new[idx] = (np.asarray(new[idx], np.float32) * 1.01).astype(ml_dtypes.bfloat16)
    ref = zipnn.delta_compress(new, base, HUFF_CFG, backend="host")
    ct = zipnn.delta_compress(new, base, HUFF_CFG, entropy_backend="device")
    assert ct.blob == ref.blob
    back = zipnn.delta_decompress(ct, base, HUFF_CFG)
    assert as_bytes(back) == as_bytes(np.asarray(new))


def test_pytree_device_entropy():
    tree = {
        "w": make_array("bfloat16", 20_000, seed=1),
        "b": make_array("float32", 513, seed=2),
        "odd": np.arange(7, dtype=np.int64),                # no ZipNN layout
    }
    ref = zipnn.compress_pytree(tree, HUFF_CFG, backend="host")
    man = zipnn.compress_pytree(tree, HUFF_CFG, entropy_backend="device")
    for a, b in zip(ref["leaves"], man["leaves"]):
        assert a.blob == b.blob
    back = zipnn.decompress_pytree(man, HUFF_CFG)
    for k in tree:
        assert np.asarray(back[k]).tobytes() == np.asarray(tree[k]).tobytes()


def test_stream_writer_device_entropy():
    raw = as_bytes(make_array("bfloat16", 60_000, seed=4))
    blobs = {}
    for ebe in (None, "device"):
        sink = io.BytesIO()
        with engine.CompressWriter(
            sink, "bfloat16", HUFF_CFG, window_bytes=1 << 15, entropy_backend=ebe
        ) as w:
            w.write(raw)
        blobs[ebe] = sink.getvalue()
    assert blobs[None] == blobs["device"]
    r = engine.DecompressReader(io.BytesIO(blobs["device"]), HUFF_CFG)
    assert r.read() == raw


def test_checkpoint_entropy_backend(tmp_path):
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

    state = {"w": make_array("bfloat16", 20_000, seed=5)}
    trees = {}
    for name, ebe in [("host", None), ("dev", "device")]:
        cfg = CheckpointConfig(
            directory=str(tmp_path / name),
            async_save=False,
            entropy_backend=ebe,
            zipnn=zipnn.ZipNNConfig(chunk_param_bytes=1 << 15, backend="huffman"),
        )
        mgr = CheckpointManager(cfg)
        mgr.save(0, state, blocking=True)
        step, tree = mgr.restore()
        trees[name] = tree
        with open(tmp_path / name / "step_0" / "data.bin", "rb") as f:
            trees[name + "_bytes"] = f.read()
    assert trees["host_bytes"] == trees["dev_bytes"]
    assert (
        np.asarray(trees["dev"]["w"]).tobytes()
        == np.asarray(state["w"]).tobytes()
    )


def test_grad_sync_entropy_backend():
    from repro.distributed.grad_sync import GradSync

    grads = {"g": make_array("float32", 30_000, seed=6)}
    ref, _ = GradSync(HUFF_CFG, backend="host").pack(grads)
    man, _ = GradSync(HUFF_CFG, entropy_backend="device").pack(grads)
    for a, b in zip(ref["leaves"], man["leaves"]):
        assert a.blob == b.blob
    back = GradSync(HUFF_CFG).unpack(man)
    assert np.asarray(back["g"]).tobytes() == np.asarray(grads["g"]).tobytes()


# ---------------------------------------------------------------------------
# raw kernel: multi-table dispatch vs huffman.encode_chunks
# ---------------------------------------------------------------------------

def test_bitpack_multi_kernel_vs_host_encoder():
    import jax
    import jax.numpy as jnp

    from repro.kernels import bitpack

    chunk = 4096
    planes = [_skewed_plane(chunk * 2, seed=11), (np.arange(chunk) % 5).astype(np.uint8)]
    tabs = []
    for p in planes:
        lens = huffman.code_lengths(np.bincount(p, minlength=256) + 1)
        tabs.append((lens, huffman.canonical_codes(lens)))
    syms = np.concatenate(planes)
    pids = np.asarray([0, 0, 1], dtype=np.int32)
    len_tables = np.stack([t[0] for t in tabs]).astype(np.int32)
    code_tables = np.stack([t[1] for t in tabs]).astype(np.int32)
    words, nbits = bitpack.bitpack_encode_chunks_multi(
        jnp.asarray(syms), jnp.asarray(pids),
        jnp.asarray(len_tables), jnp.asarray(code_tables),
        chunk_syms=chunk, interpret=True,
    )
    words_h, nbits_h = jax.device_get((words, nbits))
    stream = np.frombuffer(words_h.astype(">u4").tobytes(), np.uint8)
    for k, pid in enumerate(pids):
        seg = syms[k * chunk : (k + 1) * chunk]
        want = huffman.encode(seg, *tabs[pid])
        nb = int(nbits_h[k])
        assert nb == sum(int(tabs[pid][0][s]) for s in seg)
        got = stream[k * chunk : k * chunk + (nb + 7) // 8].tobytes()
        assert got == want, f"chunk {k} (table {pid}) differs from host encoder"
