"""Shared hypothesis fallback shim for the property tests.

``from _hyp_compat import given, settings, strategies`` behaves exactly like
the real hypothesis when it is installed.  When it is not, ``@given``
degrades to running the test body over a fixed number of seeded-random
examples (example 0 is always the minimal draw — empty binary/list, lower
integer bound — so edge cases stay covered).  This keeps the property tests
collectable and meaningful in minimal environments; install ``hypothesis``
(see requirements.txt) to get real shrinking and coverage.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import random

    _DEFAULT_EXAMPLES = 20

    class _MinRandom(random.Random):
        """Draw source that always returns the minimal value (edge cases)."""

        def randint(self, a, b):  # noqa: D102 - random.Random signature
            return a

        def randrange(self, start, stop=None, step=1):
            return 0 if stop is None else start

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw_fn(rng)))

        def flatmap(self, fn):
            return _Strategy(lambda rng: fn(self._draw_fn(rng)).draw(rng))

        def filter(self, pred, _tries=100):
            def draw(rng):
                for _ in range(_tries):
                    v = self._draw_fn(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")

            return _Strategy(draw)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(0, 1)))

        @staticmethod
        def binary(min_size=0, max_size=64):
            return _Strategy(
                lambda rng: bytes(
                    rng.randrange(256)
                    for _ in range(rng.randint(min_size, max_size))
                )
            )

        @staticmethod
        def lists(elements, min_size=0, max_size=32):
            return _Strategy(
                lambda rng: [
                    elements.draw(rng)
                    for _ in range(rng.randint(min_size, max_size))
                ]
            )

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        """Accepts (and ignores) hypothesis-only knobs like deadline."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature, or
            # it would try to inject the strategy parameters as fixtures.
            def wrapper():
                n = getattr(
                    wrapper,
                    "_max_examples",
                    getattr(fn, "_max_examples", _DEFAULT_EXAMPLES),
                )
                for i in range(n):
                    rng = (
                        _MinRandom()
                        if i == 0
                        else random.Random(0xC0FFEE + 7919 * i)
                    )
                    vals = [s.draw(rng) for s in strats]
                    fn(*vals)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._hypothesis_fallback = True
            return wrapper

        return deco
