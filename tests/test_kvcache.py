"""KV-cache tiering: cold blocks compressed at rest, decode bit-identical.

The contract under test (docs/INVARIANTS.md): a greedy decode through
``make_kv_tiered_serve_step`` over a ``KVCacheStore`` produces logits
byte-identical to ``model.decode_step`` over the untiered cache — across
GQA and MLA cache families — because every block function receives
byte-identical reassembled caches.  Residency: live hot positions never
exceed ``hot_window + block_len``, and eviction actually happens (cold
chains grow) once positions age past the window.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import zipnn
from repro.core.options import CodecOptions
from repro.models import build_model
from repro.serve import (
    CompressedParamStore,
    KVCacheStore,
    make_compressed_serve_step,
    make_kv_tiered_serve_step,
)

# Small windows so a short decode crosses several eviction boundaries.
HOT, BLK = 3, 2


def _tiny(name: str):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _lockstep_tiered(cfg, model, params, steps, **store_kw):
    """Drive jit(decode_step) and the tiered step on the same tokens.

    Returns the store; asserts logits byte-identical at every step AND the
    reassembled per-layer caches byte-identical to the reference state."""
    step = jax.jit(model.decode_step)
    B = 2
    state = model.init_decode_state(B, steps, start_pos=0)
    store = KVCacheStore(
        model.init_decode_state(B, steps, start_pos=0),
        hot_window=HOT, block_len=BLK, **store_kw,
    )
    tstep = make_kv_tiered_serve_step(model, params, store)
    rng = np.random.default_rng(0)
    for s in range(steps):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        la, state = step(params, state, toks)
        lb = tstep(toks)
        assert (
            np.asarray(la).tobytes() == np.asarray(lb).tobytes()
        ), f"logits diverged at step {s}"
    # The tier must be invisible: every layer's reassembled caches match
    # the untiered stacked cache bit for bit.
    for j in range(store.n_layers):
        ref = tuple(state[k][j] for k in store.keys)
        got = store.layer_caches(j)
        for r, g in zip(ref, got):
            assert np.asarray(r).tobytes() == np.asarray(g).tobytes()
    assert int(state["pos"]) == store.pos
    return store


class TestKVTieredBitIdentity:
    @pytest.mark.parametrize(
        "arch",
        [
            "repro_gpt_100m",      # dense, GQA kv_k/kv_v
            "olmoe_1b_7b",         # moe
            "deepseek_v2_236b",    # MLA latent caches (mla_ckv/mla_kr)
        ],
    )
    def test_bit_identical_per_family(self, arch):
        cfg, model, params = _tiny(arch)
        steps = 12
        store = _lockstep_tiered(cfg, model, params, steps)
        assert store.n_cold_blocks > 0            # eviction actually ran
        assert store.peak_hot_positions <= HOT + BLK
        assert store.cold_comp_bytes > 0

    def test_composes_with_weight_ring(self):
        """KV tier + compressed weight ring: state carries only pos, both
        weights and cold cache live as ZNN1 payloads — still bit-identical."""
        cfg, model, params = _tiny("repro_gpt_100m")
        steps = 10
        step = jax.jit(model.decode_step)
        B = 2
        ref_state = model.init_decode_state(B, steps, start_pos=0)
        kv_store = KVCacheStore(
            model.init_decode_state(B, steps, start_pos=0),
            hot_window=HOT, block_len=BLK,
        )
        wstore = CompressedParamStore.from_params(params)
        cstep = make_compressed_serve_step(model, wstore, kv_store=kv_store)
        state = {"pos": ref_state["pos"]}
        rng = np.random.default_rng(1)
        for _ in range(steps):
            toks = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32
            )
            la, ref_state = step(params, ref_state, toks)
            lb, state = cstep(state, toks)
            assert np.asarray(la).tobytes() == np.asarray(lb).tobytes()
        assert kv_store.n_cold_blocks > 0
        assert wstore.comp_bytes < wstore.raw_bytes

    def test_options_bag_changes_nothing(self):
        """The store is bag-only (no legacy kwargs); knobs never change
        cache bytes, so logits stay identical across options."""
        cfg, model, params = _tiny("repro_gpt_100m")
        a = _lockstep_tiered(cfg, model, params, 8)
        b = _lockstep_tiered(
            cfg, model, params, 8, options=CodecOptions(threads=2)
        )
        assert a.cold_comp_bytes == b.cold_comp_bytes


class TestKVCacheStoreMechanics:
    def _state(self, length=10):
        model = build_model(get_config("repro_gpt_100m").reduced())
        return model, model.init_decode_state(2, length, start_pos=0)

    def test_residency_accounting(self):
        model, state = self._state(length=12)
        params = model.init(jax.random.key(0))
        store = KVCacheStore(state, hot_window=HOT, block_len=BLK)
        tstep = make_kv_tiered_serve_step(model, params, store)
        rng = np.random.default_rng(2)
        for _ in range(12):
            toks = jnp.asarray(rng.integers(0, 100, (2, 1)), jnp.int32)
            tstep(toks)
        assert store.pos == 12
        assert store.cold_len == store.n_cold_blocks * BLK
        assert store.n_cold_blocks >= 3
        assert store.hot_bytes > 0 and store.cold_comp_bytes > 0
        assert store.cold_raw_bytes >= store.n_cold_blocks  # sane scale
        # full-cache baseline matches the untiered stacked cache footprint
        per_key = [
            int(np.prod(state[k].shape)) * state[k].dtype.itemsize
            for k in store.keys
        ]
        assert store.full_cache_bytes == sum(per_key)
        assert store.resident_bytes(0) == (
            store.hot_bytes + store.cold_comp_bytes
        )

    def test_rejects_ssm_state(self):
        model = build_model(get_config("mamba2_130m").reduced())
        state = model.init_decode_state(2, 8, start_pos=0)
        with pytest.raises((NotImplementedError, ValueError)):
            KVCacheStore(state, hot_window=HOT, block_len=BLK)

    def test_rejects_nonempty_start(self):
        model, state = self._state()
        state = dict(state, pos=jnp.asarray(3, jnp.int32))
        with pytest.raises(ValueError, match="start_pos=0"):
            KVCacheStore(state, hot_window=HOT, block_len=BLK)

    def test_rejects_bad_windows(self):
        _, state = self._state()
        with pytest.raises(ValueError):
            KVCacheStore(state, hot_window=0, block_len=BLK)
        with pytest.raises(ValueError):
            KVCacheStore(state, hot_window=HOT, block_len=0)

    def test_no_wraparound_past_length(self):
        model, state = self._state(length=4)
        params = model.init(jax.random.key(0))
        store = KVCacheStore(state, hot_window=HOT, block_len=BLK)
        tstep = make_kv_tiered_serve_step(model, params, store)
        rng = np.random.default_rng(3)
        for _ in range(4):
            tstep(jnp.asarray(rng.integers(0, 100, (2, 1)), jnp.int32))
        with pytest.raises(ValueError, match="full"):
            tstep(jnp.asarray(rng.integers(0, 100, (2, 1)), jnp.int32))

    def test_serve_step_rejects_ssm_kv_store(self):
        model = build_model(get_config("mamba2_130m").reduced())
        params = model.init(jax.random.key(0))
        gpt = build_model(get_config("repro_gpt_100m").reduced())
        kv = KVCacheStore(
            gpt.init_decode_state(2, 8, start_pos=0),
            hot_window=HOT, block_len=BLK,
        )
        with pytest.raises(NotImplementedError):
            make_kv_tiered_serve_step(model, params, kv)
        store = CompressedParamStore.from_params(params)
        with pytest.raises(NotImplementedError):
            make_compressed_serve_step(model, store, kv_store=kv)

    def test_layer_count_mismatch_rejected(self):
        gpt = build_model(get_config("repro_gpt_100m").reduced())
        params = gpt.init(jax.random.key(0))
        other = build_model(get_config("olmoe_1b_7b").reduced())
        mism = KVCacheStore(
            other.init_decode_state(2, 8, start_pos=0),
            hot_window=HOT, block_len=BLK,
        )
        if mism.n_layers != gpt.cfg.n_layers:
            with pytest.raises(ValueError, match="layers"):
                make_kv_tiered_serve_step(gpt, params, mism)
        else:
            pytest.skip("reduced configs share a layer count")

    def test_cold_blocks_individually_decodable(self):
        """Each (key, layer, block) payload is its own ZNN1 container."""
        model, state = self._state(length=12)
        params = model.init(jax.random.key(0))
        store = KVCacheStore(state, hot_window=HOT, block_len=BLK)
        tstep = make_kv_tiered_serve_step(model, params, store)
        rng = np.random.default_rng(4)
        for _ in range(10):
            tstep(jnp.asarray(rng.integers(0, 100, (2, 1)), jnp.int32))
        k = store.keys[0]
        ct = store._cold[k][0][0]
        block = zipnn.decompress_array(ct)
        assert block.shape[1] == BLK  # (B, block_len, ...) slab
