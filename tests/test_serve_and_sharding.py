"""Coverage for serving (generation loop, cache specs), sharding rules,
container format details, and stats/classification."""

import dataclasses

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import container, stats, zipnn
from repro.core.codec import ChunkEntry, Method
from repro.distributed import sharding
from repro.models import build_model
from repro.serve.step import decode_state_specs, greedy_generate, inference_param_specs


class TestGeneration:
    def test_greedy_generate_deterministic(self):
        cfg = get_config("repro_gpt_100m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)), jnp.int32
        )
        out1, _ = greedy_generate(model, params, prompt, 6)
        out2, _ = greedy_generate(model, params, prompt, 6)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert out1.shape == (2, 6)
        assert int(jnp.max(out1)) < cfg.vocab_size

    def test_swa_ring_generation_past_window(self):
        """Generate beyond the SWA window: the ring cache must wrap without
        shape errors and keep producing valid tokens."""
        cfg = dataclasses.replace(
            get_config("h2o_danube3_4b").reduced(), window=16
        )
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        B, S, gen = 1, 8, 16                   # prompt+gen > window
        state = model.init_decode_state(B, S + gen, start_pos=0)
        assert state["kv_k"].shape[2] == 16    # ring == window
        step = jax.jit(model.decode_step)
        tok = jnp.zeros((B, 1), jnp.int32)
        for _ in range(S + gen):
            logits, state = step(params, state, tok)
            assert bool(jnp.isfinite(logits).all())
            tok = jnp.argmax(logits, -1).astype(jnp.int32)


class TestShardingRules:
    def _specs(self, arch):
        cfg = get_config(arch)
        model = build_model(cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # use a fake big mesh for divisibility logic
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        return model.abstract_params(), sharding.param_pspecs(
            model.abstract_params(), zero3=cfg.zero3, mesh=FakeMesh()
        )

    def test_mlp_weights_are_sharded(self):
        params, specs = self._specs("yi_6b")
        wg = specs["layers"]["mlp"]["w_gate"]
        assert wg == P(None, "data", "model")      # (L, d-zero3, ff-model)
        wd = specs["layers"]["mlp"]["w_down"]
        assert wd == P(None, "model", "data")

    def test_attention_and_embed_rules(self):
        params, specs = self._specs("yi_6b")
        assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
        assert specs["embed"]["table"] == P("model", "data")

    def test_experts_rule_precedence(self):
        params, specs = self._specs("deepseek_v2_236b")
        we = specs["moe_layers"]["moe"]["experts"]["w_gate"]
        assert we == P(None, "model", "data", None)  # (L, E-model, d-zero3, f)

    def test_indivisible_dims_fall_back(self):
        params, specs = self._specs("mamba2_130m")   # vocab 50280 % 16 != 0
        assert specs["embed"]["table"][0] is None

    def test_inference_specs_strip_zero3(self):
        cfg = get_config("deepseek_v2_236b")
        model = build_model(cfg)

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        specs = inference_param_specs(model, FakeMesh())
        # dense weights: no 'data' axis anywhere
        q = specs["moe_layers"]["attn"]["w_uq"]["w"]
        assert "data" not in [a for a in q if a]
        # experts: E over data, ff over model
        we = specs["moe_layers"]["moe"]["experts"]["w_gate"]
        assert we == P(None, "data", None, "model")

    def test_decode_state_specs_prefer_length_sharding(self):
        cfg = get_config("qwen15_4b")              # kv=20 ∤ 16
        model = build_model(cfg)
        state = jax.eval_shape(lambda: model.init_decode_state(128, 1024))

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        specs = decode_state_specs(model, state, FakeMesh())
        assert specs["kv_k"] == P(None, "data", "model", None, None)

    def test_lshard_noop_without_mesh(self):
        x = jnp.ones((4, 4))
        y = sharding.lshard(x, "batch", None)
        assert y is x


class TestContainerFormat:
    def test_metadata_map_enables_random_access(self):
        rng = np.random.default_rng(0)
        w = (rng.standard_normal(300_000) * 0.02).astype(ml_dtypes.bfloat16)
        blob = zipnn.compress_bytes(
            np.ascontiguousarray(w).view(np.uint8), "bfloat16"
        )
        meta, mv = container.unpack_stream(bytes(blob))
        assert meta.layout_name == "bf16"
        assert meta.n_planes == 2
        # every payload offset is consistent with the declared lengths
        for pl in range(meta.n_planes):
            for c, e in enumerate(meta.entries[pl]):
                view = container.payload_view(meta, mv, pl, c)
                assert len(view) == e.comp_len

    def test_bad_magic_rejected(self):
        with pytest.raises(ValueError):
            container.unpack_stream(b"NOPE" + b"\x00" * 64)

    def test_entry_methods_recorded(self):
        rng = np.random.default_rng(1)
        w = (rng.standard_normal(300_000) * 0.02).astype(ml_dtypes.bfloat16)
        blob = zipnn.compress_bytes(np.ascontiguousarray(w).view(np.uint8), "bfloat16")
        meta, _ = container.unpack_stream(bytes(blob))
        exp_methods = {e.method for e in meta.entries[0]}
        frac_methods = {e.method for e in meta.entries[1]}
        assert exp_methods <= {Method.HUFF, Method.HUFFLIB}   # compressible
        assert frac_methods == {Method.STORE}                 # random fraction


class TestStats:
    def test_classify_regular_vs_clean(self):
        rng = np.random.default_rng(0)
        regular = [(rng.standard_normal(100_000) * 0.02).astype(np.float32)]
        assert stats.classify_model(regular) == "regular"
        u = regular[0].view(np.uint32) & np.uint32(0xFFFFF000)
        clean = [u.view(np.float32).copy()]
        assert stats.classify_model(clean) == "clean"

    def test_byte_entropy_bounds(self):
        assert stats.byte_entropy(np.zeros(1000, np.uint8)) == 0.0
        rnd = np.random.default_rng(0).integers(0, 256, 100_000).astype(np.uint8)
        assert 7.9 < stats.byte_entropy(rnd) <= 8.0


class TestMesh:
    def test_make_host_mesh(self):
        from repro.launch.mesh import make_host_mesh, n_chips

        mesh = make_host_mesh()
        assert n_chips(mesh) == 1
        assert tuple(mesh.axis_names) == ("data", "model")
