"""Canonical length-limited Huffman codec tests (paper §3.1 'Huffman only')."""

import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core import huffman


def _roundtrip(data: np.ndarray):
    hist = np.bincount(data, minlength=256)
    lens = huffman.code_lengths(hist)
    codes = huffman.canonical_codes(lens)
    blob = huffman.encode(data, lens, codes)
    back = huffman.decode(blob, data.size, lens)
    np.testing.assert_array_equal(back, data)
    return blob, lens


def test_kraft_inequality_and_limit():
    rng = np.random.default_rng(0)
    for trial in range(20):
        # extremely skewed histograms push plain Huffman past the limit
        freqs = np.zeros(256, dtype=np.int64)
        k = rng.integers(2, 256)
        freqs[:k] = np.maximum(1, (1 << (np.arange(k) % 40)).astype(np.int64))
        lens = huffman.code_lengths(freqs)
        used = lens[lens > 0]
        assert used.max() <= huffman.MAX_CODE_LEN
        kraft = np.sum(2.0 ** (-used.astype(np.float64)))
        assert kraft <= 1.0 + 1e-12


def test_canonical_codes_prefix_free():
    freqs = np.array([1000, 500, 200, 90, 8, 1, 1, 1] + [0] * 248, dtype=np.int64)
    lens = huffman.code_lengths(freqs)
    codes = huffman.canonical_codes(lens)
    pairs = [(int(codes[s]), int(lens[s])) for s in range(256) if lens[s]]
    for (c1, l1) in pairs:
        for (c2, l2) in pairs:
            if (c1, l1) == (c2, l2):
                continue
            if l1 <= l2:
                assert (c2 >> (l2 - l1)) != c1, "prefix violation"


def test_table_pack_roundtrip():
    rng = np.random.default_rng(1)
    data = rng.integers(0, 40, 10000).astype(np.uint8)
    hist = np.bincount(data, minlength=256)
    lens = huffman.code_lengths(hist)
    assert np.array_equal(huffman.unpack_table(huffman.pack_table(lens)), lens)


@pytest.mark.parametrize("n", [1, 2, 255, 4096, 100_000])
def test_roundtrip_skewed(n):
    rng = np.random.default_rng(n)
    p = np.r_[np.full(12, 0.08), np.full(244, 0.04 / 244)]
    data = rng.choice(256, p=p / p.sum(), size=n).astype(np.uint8)
    blob, lens = _roundtrip(data)
    # skewed data must actually compress
    if n >= 4096:
        assert len(blob) < 0.7 * n


def test_roundtrip_uniform_and_constant():
    rng = np.random.default_rng(7)
    _roundtrip(rng.integers(0, 256, 10000).astype(np.uint8))
    _roundtrip(np.full(5000, 173, dtype=np.uint8))
    _roundtrip(np.array([0], dtype=np.uint8))


def test_encode_chunks_matches_per_chunk_encode():
    rng = np.random.default_rng(3)
    data = rng.integers(0, 16, 50_000).astype(np.uint8)
    hist = np.bincount(data, minlength=256)
    lens = huffman.code_lengths(hist)
    codes = huffman.canonical_codes(lens)
    counts = np.array([20_000, 25_000, 5_000])
    blobs = huffman.encode_chunks(data, counts, lens, codes)
    off = 0
    for blob, c in zip(blobs, counts):
        np.testing.assert_array_equal(
            blob, huffman.encode(data[off : off + c], lens, codes)
        )
        off += c
    decoded = huffman.decode_many(blobs, counts, lens)
    np.testing.assert_array_equal(np.concatenate(decoded), data)


def test_decode_many_ragged_counts():
    """Chunks of very different lengths exercise the early-finish clamping."""
    rng = np.random.default_rng(4)
    data = rng.integers(0, 8, 10_000).astype(np.uint8)
    hist = np.bincount(data, minlength=256)
    lens = huffman.code_lengths(hist)
    codes = huffman.canonical_codes(lens)
    counts = np.array([1, 9000, 37, 500, 462])
    assert counts.sum() == data.size
    blobs = huffman.encode_chunks(data, counts, lens, codes)
    decoded = huffman.decode_many(blobs, counts, lens)
    np.testing.assert_array_equal(np.concatenate(decoded), data)


@given(st.lists(st.integers(0, 255), min_size=1, max_size=2000))
@settings(max_examples=60, deadline=None)
def test_roundtrip_property(xs):
    data = np.asarray(xs, dtype=np.uint8)
    _roundtrip(data)


@given(
    st.integers(2, 6).flatmap(
        lambda k: st.lists(
            st.lists(st.integers(0, 255), min_size=1, max_size=300),
            min_size=k,
            max_size=k,
        )
    )
)
@settings(max_examples=30, deadline=None)
def test_chunked_roundtrip_property(chunks):
    data = np.asarray([x for c in chunks for x in c], dtype=np.uint8)
    counts = np.asarray([len(c) for c in chunks])
    hist = np.bincount(data, minlength=256)
    lens = huffman.code_lengths(hist)
    codes = huffman.canonical_codes(lens)
    blobs = huffman.encode_chunks(data, counts, lens, codes)
    decoded = huffman.decode_many(blobs, counts, lens)
    np.testing.assert_array_equal(np.concatenate(decoded), data)


def test_estimate_matches_actual():
    rng = np.random.default_rng(9)
    data = rng.integers(0, 30, 65536).astype(np.uint8)
    hist = np.bincount(data, minlength=256)
    lens = huffman.code_lengths(hist)
    codes = huffman.canonical_codes(lens)
    est_bits = huffman.estimate_encoded_bits(hist, lens)
    blob = huffman.encode(data, lens, codes)
    assert len(blob) == -(-est_bits // 8)
