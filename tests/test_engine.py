"""Parallel + streaming engine tests: determinism across thread counts,
bounded-memory file round-trips, framed-container edge cases."""

import io
import os

import ml_dtypes
import numpy as np
import pytest

from repro.core import codec, engine, zipnn


def _bf16_bytes(n, seed=0, scale=0.02):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal(n) * scale).astype(ml_dtypes.bfloat16)
    return np.ascontiguousarray(w).view(np.uint8).tobytes()


class TestParallelDeterminism:
    @pytest.mark.parametrize("backend", ["hufflib", "huffman"])
    def test_threads_1_vs_8_byte_identical(self, backend):
        cfg = zipnn.ZipNNConfig(backend=backend)
        raw = _bf16_bytes(1_000_000)
        blob1 = zipnn.compress_bytes(raw, "bfloat16", cfg, threads=1)
        blob8 = zipnn.compress_bytes(raw, "bfloat16", cfg, threads=8)
        assert blob1 == blob8
        assert zipnn.decompress_bytes(blob8, cfg, threads=8) == raw
        assert zipnn.decompress_bytes(blob8, cfg, threads=1) == raw

    def test_threads_identical_on_delta_stream(self):
        raw = bytearray(_bf16_bytes(500_000))
        raw[::997] = bytes(len(raw[::997]))          # zero runs → ZLIB chunks
        blob1 = zipnn.compress_bytes(bytes(raw), "bfloat16", delta=True, threads=1)
        blob4 = zipnn.compress_bytes(bytes(raw), "bfloat16", delta=True, threads=4)
        assert blob1 == blob4
        assert zipnn.decompress_bytes(blob4, threads=4) == bytes(raw)

    def test_config_threads_knob(self):
        cfg = zipnn.ZipNNConfig(threads=8)
        raw = _bf16_bytes(200_000, seed=3)
        blob = zipnn.compress_bytes(raw, "bfloat16", cfg)   # pool via config
        assert blob == zipnn.compress_bytes(raw, "bfloat16")
        assert zipnn.decompress_bytes(blob, cfg) == raw

    def test_pytree_threads_identical(self):
        tree = {
            "w": np.frombuffer(_bf16_bytes(80_000), dtype=ml_dtypes.bfloat16),
            "b": np.zeros(1000, np.float32),
        }
        m0 = zipnn.compress_pytree(tree)
        m8 = zipnn.compress_pytree(tree, threads=8)
        assert [c.blob for c in m0["leaves"]] == [c.blob for c in m8["leaves"]]
        back = zipnn.decompress_pytree(m8, threads=8)
        np.testing.assert_array_equal(
            np.asarray(back["w"]).view(np.uint8),
            np.asarray(tree["w"]).view(np.uint8),
        )

    def test_resolve_threads_semantics(self):
        cores = os.cpu_count() or 1
        assert engine.resolve_threads(None) == 1
        assert engine.resolve_threads(0) == 1
        assert engine.resolve_threads(1) == 1
        assert engine.resolve_threads(6) == min(6, cores)   # capped at cores
        assert engine.resolve_threads(-1) == cores
        assert engine.get_pool(0) is None
        pool = engine.get_pool(2)
        assert pool is engine.get_pool(2)      # cached per worker count

    def test_split_ids_partition(self):
        for n, parts in [(0, 4), (1, 4), (7, 3), (64, 8), (5, 100)]:
            rs = codec.split_ids(n, parts)
            flat = [i for r in rs for i in r]
            assert flat == list(range(n))
            assert len(rs) <= max(parts, 1)


class TestStreamingFiles:
    def test_file_roundtrip_larger_than_window(self, tmp_path):
        # > 4 windows, plus an unaligned TAIL remainder, plus an all-zero
        # stretch wider than a window (ZERO planes mid-stream).
        body = bytearray(_bf16_bytes(3_000_000, seed=1))
        body[1_000_000:2_500_000] = bytes(1_500_000)
        data = bytes(body) + b"\x07\x01\x03"            # len % 2 == 1 → TAIL
        src, dst, back = (tmp_path / n for n in ("in.bin", "out.znns", "back.bin"))
        src.write_bytes(data)

        raw_b, comp_b = engine.compress_file(
            str(src), str(dst), "bfloat16", window_bytes=1 << 20, threads=4
        )
        assert raw_b == len(data)
        assert comp_b == dst.stat().st_size
        assert comp_b < len(data)                       # zeros must compress

        n = engine.decompress_file(str(dst), str(back), threads=4)
        assert n == len(data)
        assert back.read_bytes() == data

    def test_stream_smaller_than_window(self, tmp_path):
        data = _bf16_bytes(10_000, seed=2)
        src = tmp_path / "small.bin"
        src.write_bytes(data)
        dst = tmp_path / "small.znns"
        engine.compress_file(str(src), str(dst), "bfloat16")
        with engine.DecompressReader(str(dst)) as r:
            assert r.read() == data

    def test_writer_reader_incremental_io(self):
        # many small writes in, odd-sized reads out — exercises both buffers
        data = _bf16_bytes(300_000, seed=4)
        sink = io.BytesIO()
        with zipnn.CompressWriter(sink, "bfloat16", window_bytes=1 << 17) as w:
            for i in range(0, len(data), 9973):
                w.write(data[i : i + 9973])
        assert w.raw_bytes == len(data)
        assert w.comp_bytes == len(sink.getvalue())

        sink.seek(0)
        r = zipnn.DecompressReader(sink)
        assert r.dtype_name == "bfloat16"
        out = bytearray()
        while True:
            piece = r.read(31337)
            if not piece:
                break
            out += piece
        assert bytes(out) == data

    def test_empty_stream(self, tmp_path):
        src = tmp_path / "empty.bin"
        src.write_bytes(b"")
        dst = tmp_path / "empty.znns"
        raw_b, comp_b = engine.compress_file(str(src), str(dst), "float32")
        assert raw_b == 0
        with engine.DecompressReader(str(dst)) as r:
            assert r.read() == b""

    def test_truncated_stream_raises(self, tmp_path):
        data = _bf16_bytes(100_000, seed=5)
        src = tmp_path / "t.bin"
        src.write_bytes(data)
        dst = tmp_path / "t.znns"
        engine.compress_file(str(src), str(dst), "bfloat16", window_bytes=1 << 17)
        whole = dst.read_bytes()
        clipped = tmp_path / "clipped.znns"
        clipped.write_bytes(whole[: len(whole) - 40])
        with pytest.raises(IOError):
            with engine.DecompressReader(str(clipped)) as r:
                r.read()

    def test_mixed_read_then_frames_loses_nothing(self):
        data = _bf16_bytes(250_000, seed=8)
        sink = io.BytesIO()
        with zipnn.CompressWriter(sink, "bfloat16", window_bytes=1 << 17) as w:
            w.write(data)
        sink.seek(0)
        r = engine.DecompressReader(sink)
        head = r.read(16)                       # buffers a partial frame
        rest = b"".join(r.frames())             # must start from the buffer
        assert head + rest == data

    def test_missing_middle_frame_detected(self):
        import struct

        data = _bf16_bytes(250_000, seed=9)
        sink = io.BytesIO()
        with zipnn.CompressWriter(sink, "bfloat16", window_bytes=1 << 17) as w:
            w.write(data)
        blob = sink.getvalue()
        frame = struct.Struct("<BQQI")
        off = 32                                 # ZNS1 header size
        spans = []
        while True:
            kind, _rl, cl, _crc = frame.unpack_from(blob, off)
            spans.append((off, frame.size + cl, kind))
            off += frame.size + cl
            if kind == 0:
                break
        assert len(spans) > 2                    # multiple data frames
        start, length, _ = spans[1]
        cut = blob[:start] + blob[start + length :]   # drop 2nd data frame
        with pytest.raises(IOError, match="end frame declares"):
            engine.DecompressReader(io.BytesIO(cut)).read()

    def test_interrupted_write_never_looks_complete(self):
        # an exception inside the with-block must NOT finalize the stream:
        # no buffered flush, no end frame → the reader rejects the file
        data = _bf16_bytes(200_000, seed=7)
        sink = io.BytesIO()
        with pytest.raises(RuntimeError):
            with zipnn.CompressWriter(sink, "bfloat16", window_bytes=1 << 17) as w:
                w.write(data)
                raise RuntimeError("interrupted mid-stream")
        partial = io.BytesIO(sink.getvalue())
        with pytest.raises(IOError):
            zipnn.DecompressReader(partial).read()

    def test_corrupt_frame_crc_raises(self, tmp_path):
        data = _bf16_bytes(100_000, seed=6)
        src = tmp_path / "c.bin"
        src.write_bytes(data)
        dst = tmp_path / "c.znns"
        engine.compress_file(str(src), str(dst), "bfloat16", window_bytes=1 << 17)
        blob = bytearray(dst.read_bytes())
        blob[len(blob) // 2] ^= 0xFF                     # flip a payload byte
        bad = tmp_path / "bad.znns"
        bad.write_bytes(bytes(blob))
        with pytest.raises(IOError):
            with engine.DecompressReader(str(bad)) as r:
                r.read()


@pytest.mark.slow
def test_large_file_roundtrip_bounded_memory(tmp_path):
    """Synthetic checkpoint (default ~64 MiB; set ZIPNN_STREAM_TEST_MIB=512
    for the acceptance-scale run) through a 4 MiB window: 16+ frames, peak
    extra memory O(window) — the writer/reader never hold more than one
    window of raw plus its compressed frame."""
    mib = int(os.environ.get("ZIPNN_STREAM_TEST_MIB", "64"))
    src = tmp_path / "big.bin"
    rng = np.random.default_rng(9)
    with open(src, "wb") as f:
        for _ in range(mib // 4):
            w = (rng.standard_normal(2_000_000) * 0.02).astype(ml_dtypes.bfloat16)
            f.write(np.ascontiguousarray(w).view(np.uint8).tobytes())
        f.write(b"\x01")                                 # unaligned tail
    dst = tmp_path / "big.znns"
    back = tmp_path / "back.bin"
    raw_b, comp_b = engine.compress_file(
        str(src), str(dst), "bfloat16", window_bytes=4 << 20, threads=2
    )
    assert raw_b == src.stat().st_size
    assert comp_b < raw_b * 0.75                         # ~66 % paper ratio
    assert engine.decompress_file(str(dst), str(back), threads=2) == raw_b
    # spot-check equality without loading both files whole
    with open(src, "rb") as a, open(back, "rb") as b:
        while True:
            ca, cb = a.read(1 << 20), b.read(1 << 20)
            assert ca == cb
            if not ca:
                break
