"""Regenerate the golden ZipNN fixtures (format-stability guard).

The checked-in blobs under this directory freeze today's container format
and codec byte stream.  ``tests/test_golden.py`` (via ``tests/parity.py``)
asserts that the current code still decodes them bit-exactly on every
backend × thread combination AND re-encodes the frozen raw bytes to the
byte-identical blob.  A failing golden test means the on-disk format
changed — bump the container version and regenerate deliberately:

    PYTHONPATH=src python tests/fixtures/generate_fixtures.py

``--check`` regenerates every fixture **in memory** and byte-compares it
against the checked-in files without writing anything — the CI
fixture-staleness gate (scripts/ci.sh): encoder drift is caught at PR time
with a named diff instead of a downstream golden-test failure.

Inputs are seeded ``np.random.default_rng`` draws (stream-stable per
NEP 19), but the raw bytes are checked in alongside the blobs so the guard
never depends on RNG stability.
"""

from __future__ import annotations

import argparse
import io
import json
import os
import sys

import ml_dtypes
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

from parity import as_bytes  # noqa: E402
from repro.core import engine, zipnn  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))


def _weights(n, npdt, seed, scale):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(npdt)


def build():
    """Regenerate every fixture into memory: returns (fixtures_meta, files)."""
    fixtures = []
    files = {}

    def write(name: str, data: bytes) -> str:
        files[name] = data
        return name

    # 1. bf16 through the default hufflib coder (HUFFLIB + STORE chunks)
    cfg_bf16 = {"chunk_param_bytes": 1 << 15, "backend": "hufflib"}
    raw = as_bytes(_weights(12_288, ml_dtypes.bfloat16, seed=1, scale=0.02))
    blob = zipnn.compress_bytes(raw, "bfloat16", zipnn.ZipNNConfig(**cfg_bf16))
    fixtures.append({
        "name": "bf16_hufflib", "kind": "bytes", "dtype": "bfloat16",
        "config": cfg_bf16,
        "raw": write("bf16_hufflib.raw", raw),
        "blob": write("bf16_hufflib.znn", blob),
    })

    # 2. fp32 through our from-scratch canonical coder (HUFF chunks + table)
    cfg_fp32 = {"chunk_param_bytes": 1 << 16, "backend": "huffman"}
    raw = as_bytes(_weights(8_192, np.float32, seed=2, scale=0.3))
    blob = zipnn.compress_bytes(raw, "float32", zipnn.ZipNNConfig(**cfg_fp32))
    fixtures.append({
        "name": "fp32_huffman", "kind": "bytes", "dtype": "float32",
        "config": cfg_fp32,
        "raw": write("fp32_huffman.raw", raw),
        "blob": write("fp32_huffman.znn", blob),
    })

    # 3. fp16 (5-bit exponent layout) with an unaligned TAIL byte
    cfg_fp16 = {"chunk_param_bytes": 1 << 15, "backend": "huffman"}
    raw = as_bytes(_weights(12_288, np.float16, seed=3, scale=0.02)) + b"\x2a"
    blob = zipnn.compress_bytes(raw, "float16", zipnn.ZipNNConfig(**cfg_fp16))
    fixtures.append({
        "name": "fp16_tail", "kind": "bytes", "dtype": "float16",
        "config": cfg_fp16,
        "raw": write("fp16_tail.raw", raw),
        "blob": write("fp16_tail.znn", blob),
    })

    # 4. §4.2 XOR delta of a ~2%-changed bf16 tensor (ZERO/ZLIB chunks)
    cfg_delta = {"chunk_param_bytes": 1 << 15, "backend": "hufflib"}
    base = _weights(12_288, ml_dtypes.bfloat16, seed=4, scale=0.02)
    new = np.asarray(base).copy()
    rng = np.random.default_rng(5)
    idx = rng.integers(0, new.size, new.size // 50)
    new[idx] = (np.asarray(new[idx], np.float32) * 1.01).astype(ml_dtypes.bfloat16)
    ct = zipnn.delta_compress(new, base, zipnn.ZipNNConfig(**cfg_delta))
    fixtures.append({
        "name": "bf16_delta", "kind": "delta", "dtype": "bfloat16",
        "config": cfg_delta, "shape": list(ct.shape),
        "raw": write("bf16_delta.raw", as_bytes(new)),
        "base": write("bf16_delta.base", as_bytes(np.asarray(base))),
        "blob": write("bf16_delta.znn", ct.blob),
    })

    # 5. a multi-frame ZNS1 streaming container
    cfg_stream = {"chunk_param_bytes": 1 << 14, "backend": "hufflib"}
    window = 1 << 14
    raw = as_bytes(_weights(32_768, ml_dtypes.bfloat16, seed=6, scale=0.02))
    sink = io.BytesIO()
    with engine.CompressWriter(
        sink, "bfloat16", zipnn.ZipNNConfig(**cfg_stream), window_bytes=window
    ) as w:
        w.write(raw)
    fixtures.append({
        "name": "bf16_stream", "kind": "stream", "dtype": "bfloat16",
        "config": cfg_stream, "window_bytes": window,
        "raw": write("bf16_stream.raw", raw),
        "blob": write("bf16_stream.znns", sink.getvalue()),
    })

    return fixtures, files


def check() -> int:
    """Byte-compare regenerated fixtures against the checked-in files.

    Returns the number of stale/missing files (0 ⇒ fixtures are fresh).
    """
    fixtures, files = build()
    stale = []
    for name, data in files.items():
        path = os.path.join(HERE, name)
        if not os.path.exists(path):
            stale.append(f"{name}: missing on disk")
            continue
        with open(path, "rb") as f:
            have = f.read()
        if have != data:
            stale.append(
                f"{name}: {len(have)} bytes on disk != {len(data)} regenerated"
            )
    meta_path = os.path.join(HERE, "meta.json")
    want_meta = {"format": "ZNN1/ZNS1 v1", "fixtures": fixtures}
    try:
        with open(meta_path) as f:
            have_meta = json.load(f)
    except (OSError, json.JSONDecodeError):
        have_meta = None
    if have_meta != want_meta:
        stale.append("meta.json: does not match regenerated metadata")
    if stale:
        print("STALE fixtures (encoder output drifted from the checked-in blobs):")
        repo = os.path.dirname(os.path.dirname(HERE))
        for s in stale:
            print(f"  - {s}")
            if os.environ.get("GITHUB_ACTIONS"):
                # clickable annotation on the stale fixture file in the PR
                name = s.split(":", 1)[0]
                rel = os.path.relpath(os.path.join(HERE, name), repo)
                msg = s.replace("%", "%25").replace("\n", "%0A")
                print(f"::error file={rel},title=stale fixture::{msg}")
        print(
            "If the format change is deliberate, regenerate with\n"
            "    PYTHONPATH=src python tests/fixtures/generate_fixtures.py"
        )
    else:
        print(f"fixtures fresh: {len(files)} files byte-identical to regeneration")
    return len(stale)


def main() -> None:
    fixtures, files = build()
    for name, data in files.items():
        with open(os.path.join(HERE, name), "wb") as f:
            f.write(data)
    with open(os.path.join(HERE, "meta.json"), "w") as f:
        json.dump({"format": "ZNN1/ZNS1 v1", "fixtures": fixtures}, f, indent=2)
    total = sum(len(d) for d in files.values())
    print(f"wrote {len(fixtures)} fixtures ({total / 1024:.0f} KiB) to {HERE}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--check", action="store_true",
        help="regenerate in memory and byte-compare against the checked-in "
             "fixtures; exit 1 on drift (the CI staleness gate)",
    )
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check() else 0)
    main()
