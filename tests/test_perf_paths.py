"""Tests for the §Perf memory-path optimizations: flash-attention custom
VJP (gradients vs dense-attention autodiff) and fused chunked CE (loss and
gradients vs explicit logits+CE)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers
from repro.models.attention import dense_attention, flash_attention


class TestFlashVJP:
    @pytest.mark.parametrize(
        "B,S,H,G,hd,hdv,causal,window,qb,kb",
        [
            (2, 64, 4, 2, 16, 16, True, 0, 16, 32),
            (1, 100, 4, 4, 8, 8, True, 24, 32, 16),    # ragged + SWA
            (2, 128, 6, 2, 12, 20, True, 0, 64, 64),   # MLA-style hd_v ≠ hd
            (1, 96, 4, 1, 16, 16, False, 0, 32, 32),   # encoder + MQA
        ],
    )
    def test_grads_match_dense(self, B, S, H, G, hd, hdv, causal, window, qb, kb):
        rng = np.random.default_rng(S * 7 + H)
        q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, G, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, G, hdv)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((B, S, H, hdv)), jnp.float32)

        gf = jax.grad(
            lambda *a: jnp.sum(
                flash_attention(*a, causal=causal, window=window,
                                q_block=qb, kv_block=kb) * w
            ),
            argnums=(0, 1, 2),
        )(q, k, v)
        gd = jax.grad(
            lambda *a: jnp.sum(
                dense_attention(*a, causal=causal, window=window) * w
            ),
            argnums=(0, 1, 2),
        )(q, k, v)
        for a, b in zip(gf, gd):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-3, rtol=5e-3)

    def test_residuals_are_linear_not_quadratic(self):
        """The VJP must save O(S) residuals (q,k,v,out,lse) — no (qb×kb)
        probability tensors."""
        from repro.models.attention import _flash_core_fwd

        B, S, H, hd = 1, 256, 2, 16
        q = jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32)
        k = jax.ShapeDtypeStruct((B, S, 2, hd), jnp.float32)
        v = jax.ShapeDtypeStruct((B, S, 2, hd), jnp.float32)
        _, res = jax.eval_shape(
            lambda a, b, c: _flash_core_fwd(a, b, c, S, True, 0, 64, 64), q, k, v
        )
        total = sum(np.prod(r.shape) for r in jax.tree_util.tree_leaves(res))
        # q+k+v+out ≈ 4·S·H·hd; lse ≈ S·H.  Anything ≫ that means we saved probs.
        assert total < 6 * S * H * hd


class TestFusedCE:
    @pytest.mark.parametrize("n_chunks,masked", [(4, True), (8, False), (1, True)])
    def test_matches_reference(self, n_chunks, masked):
        rng = np.random.default_rng(n_chunks)
        B, S, D, V = 2, 32, 16, 50
        table = jnp.asarray(rng.standard_normal((V, D)) * 0.1, jnp.float32)
        x = jnp.asarray(rng.standard_normal((B, S, D)) * 0.5, jnp.float32)
        labels = jnp.asarray(rng.integers(0, V, (B, S)))
        mask = (
            jnp.asarray(rng.integers(0, 2, (B, S)), jnp.float32)
            if masked else jnp.ones((B, S), jnp.float32)
        )

        def ref(t, xx):
            return layers.cross_entropy(
                layers.unembed({"table": t}, xx), labels, mask
            )

        def fused(t, xx):
            return layers.fused_cross_entropy(t, xx, labels, mask, n_chunks)

        l1, (gt1, gx1) = jax.value_and_grad(ref, argnums=(0, 1))(table, x)
        l2, (gt2, gx2) = jax.value_and_grad(fused, argnums=(0, 1))(table, x)
        assert abs(float(l1 - l2)) < 1e-2
        np.testing.assert_allclose(np.asarray(gt1), np.asarray(gt2), atol=2e-2)
        np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), atol=2e-2)

    def test_odd_seq_falls_back_to_single_chunk(self):
        rng = np.random.default_rng(0)
        B, S, D, V = 1, 13, 8, 20          # S not divisible by chunks
        table = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, V, (B, S)))
        mask = jnp.ones((B, S), jnp.float32)
        l = layers.fused_cross_entropy(table, x, labels, mask, 8)
        ref = layers.cross_entropy(layers.unembed({"table": table}, x), labels, mask)
        assert abs(float(l - ref)) < 1e-3
