"""zipnn-lint self-tests: must-flag / must-pass fixtures per rule family,
plus the repo-clean smoke (``python -m repro.analysis --strict`` exit 0).

Each fixture is an in-memory module analyzed under a *virtual* repo path
(rule scoping is path-prefix based), seeded with exactly one violation —
or its minimally-fixed twin, which must pass.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import analyze_source
from repro.analysis.base import Project, SourceFile, analyze_project
from repro.analysis import (
    container_spec,
    determinism,
    kernel_contract,
    knobs,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CORE = "src/repro/core/fake_mod.py"
KERN = "src/repro/kernels/fake_kern.py"


def lint(code, rel, families):
    return analyze_source(textwrap.dedent(code), rel, families=families)


def rules_of(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "rule,bad,good",
    [
        (
            "det-wallclock",
            "import time\nstamp = time.time()\n",
            "import time\nstamp = time.perf_counter()\n",
        ),
        (
            "det-random",
            "import os\nnonce = os.urandom(16)\n",
            "import zlib\nnonce = zlib.crc32(b'seed')\n",
        ),
        (
            "det-random",
            "import random\nx = random.random()\n",
            "x = 0.5\n",
        ),
        (
            "det-hash",
            "key = hash('plane0')\n",
            "import zlib\nkey = zlib.crc32(b'plane0')\n",
        ),
        (
            "det-set-order",
            "out = []\nfor p in {'exp', 'frac'}:\n    out.append(p)\n",
            "out = []\nfor p in sorted({'exp', 'frac'}):\n    out.append(p)\n",
        ),
        (
            "det-set-order",
            "planes = list(set(['a', 'b']))\n",
            "planes = sorted(set(['a', 'b']))\n",
        ),
        (
            "det-id-key",
            "def f(cache, arr):\n    cache[id(arr)] = 1\n",
            "def f(cache, key, arr):\n    cache[key] = 1\n",
        ),
        (
            "det-fs-order",
            "import os\ndef f(d):\n    return [n for n in os.listdir(d)]\n",
            "import os\ndef f(d):\n    return [n for n in sorted(os.listdir(d))]\n",
        ),
        (
            "det-float-size",
            "def f(buf, n):\n    return buf[: n / 2]\n",
            "def f(buf, n):\n    return buf[: n // 2]\n",
        ),
        (
            "det-float-size",
            "def f(n):\n    return bytearray(n / 4)\n",
            "def f(n):\n    return bytearray(n // 4)\n",
        ),
    ],
)
def test_determinism_fixtures(rule, bad, good):
    assert rule in rules_of(lint(bad, CORE, [determinism]))
    assert not lint(good, CORE, [determinism])


def test_determinism_scope_excludes_benchmarks():
    code = "import time\nstamp = time.time()\n"
    assert not lint(code, "benchmarks/fake_bench.py", [determinism])


def test_perf_counter_allowed_everywhere():
    code = "import time\nt0 = time.perf_counter()\ndt = time.monotonic()\n"
    assert not lint(code, CORE, [determinism])


# ---------------------------------------------------------------------------
# knobs
# ---------------------------------------------------------------------------

KNOB_SCOPE = "src/repro/checkpoint/fake_knobs.py"  # in scope, not on SURFACE

_KNOB_BASE = """
    def inner(data, threads=None, backend=None):
        return data

    def outer(data, threads=None, backend=None):
        return inner(data{fwd})
"""


def test_knob_dropped():
    v = lint(_KNOB_BASE.format(fwd=""), KNOB_SCOPE, [knobs])
    assert {x.rule for x in v} >= {"knob-dropped"}
    # both threads and backend dropped
    assert sum(1 for x in v if x.rule == "knob-dropped") == 2


def test_knob_forwarded_ok():
    ok = _KNOB_BASE.format(fwd=", threads=threads, backend=backend")
    assert not lint(ok, KNOB_SCOPE, [knobs])


def test_knob_forwarded_positionally_ok():
    ok = _KNOB_BASE.format(fwd=", threads, backend")
    assert not lint(ok, KNOB_SCOPE, [knobs])


def test_knob_kwargs_forwarding_ok():
    code = """
    def inner(data, threads=None, backend=None):
        return data

    def outer(data, **kw):
        return inner(data, **kw)
    """
    assert not lint(code, KNOB_SCOPE, [knobs])


def test_knob_redefault():
    bad = _KNOB_BASE.format(fwd=", threads=threads, backend='host'")
    v = lint(bad, KNOB_SCOPE, [knobs])
    assert rules_of(v) == {"knob-redefault"}


def test_knob_none_is_not_redefault():
    # explicit None means "derive from config" on this surface
    ok = _KNOB_BASE.format(fwd=", threads=threads, backend=None")
    assert not lint(ok, KNOB_SCOPE, [knobs])


def test_knob_config_carried_caller_exempt():
    code = """
    def inner(data, threads=None, backend=None):
        return data

    def outer(data, config):
        return inner(data)
    """
    assert not lint(code, KNOB_SCOPE, [knobs])


def test_knob_instance_carried_method():
    code = """
    def inner(data, backend=None):
        return data

    class Writer:
        def __init__(self, backend=None):
            self._backend = backend

        def run(self, data):
            return inner(data)
    """
    v = lint(code, KNOB_SCOPE, [knobs])
    assert rules_of(v) == {"knob-dropped"}


def test_knob_suppression_with_reason():
    bad = """
    def inner(data, backend=None):
        return data

    def outer(data, backend=None):
        # zipnn: allow(knob-redefault): fixture exercises the suppression path
        return inner(data, backend='host')
    """
    assert not lint(bad, KNOB_SCOPE, [knobs])


def test_suppression_without_reason_is_flagged():
    bad = """
    def inner(data, backend=None):
        return data

    def outer(data, backend=None):
        return inner(data, backend='host')  # zipnn: allow(knob-redefault)
    """
    v = lint(bad, KNOB_SCOPE, [knobs])
    # the reasonless allow() does not suppress, and is itself a finding
    assert rules_of(v) == {"knob-redefault", "bad-suppression"}


def test_knob_surface_contract():
    # a knob-scope module that exists but lost a public entry point knob
    code = """
    def compress_file(src, dst, dtype_name, config, threads=None):
        return None
    """
    v = lint(code, "src/repro/core/engine.py", [knobs])
    surface = [x for x in v if x.rule == "knob-surface"]
    assert surface, "missing entry points / knobs must be flagged"


def test_knob_surface_requires_options_bag():
    # the full legacy knob set without options= now fails the contract
    code = """
    def simulate_transfer(data, dtype_name, channel, threads=None,
                          backend=None, entropy_backend=None):
        return None

    def simulate_file_transfer(path, dtype_name, channel, threads=None,
                               backend=None, entropy_backend=None,
                               options=None):
        return None
    """
    v = lint(code, "src/repro/checkpoint/hub.py", [knobs])
    surface = [x for x in v if x.rule == "knob-surface"]
    assert len(surface) == 1
    assert "simulate_transfer" in surface[0].message
    assert "options" in surface[0].message


def test_knob_options_bag_supersedes_legacy_edges():
    # binding options= (non-None) satisfies the legacy knobs on that edge
    code = """
    def inner(data, threads=None, backend=None, options=None):
        return data

    def outer(data, threads=None, backend=None, options=None):
        return inner(data, options=options)
    """
    assert not lint(code, KNOB_SCOPE, [knobs])


def test_knob_options_none_does_not_supersede():
    # an explicit options=None edge still checks the legacy knobs
    code = """
    def inner(data, threads=None, backend=None, options=None):
        return data

    def outer(data, threads=None, backend=None, options=None):
        return inner(data, options=None)
    """
    v = lint(code, KNOB_SCOPE, [knobs])
    assert {x.rule for x in v} == {"knob-dropped"}
    # threads + backend dropped (options itself was explicitly bound)
    assert sum(1 for x in v if x.rule == "knob-dropped") == 2


def test_knob_options_dropped_is_flagged():
    # the bag is a knob too: dropping it on an edge is caught
    code = """
    def inner(data, options=None):
        return data

    def outer(data, options=None):
        return inner(data)
    """
    v = lint(code, KNOB_SCOPE, [knobs])
    assert rules_of(v) == {"knob-dropped"}
    assert "options" in v[0].message


def test_knob_codec_options_constructor_exempt():
    # building the bag from knob locals/constants is the forwarding act —
    # CodecOptions(...) edges are never knob-checked
    code = """
    class CodecOptions:
        def __init__(self, threads=None, backend=None, entropy_backend=None):
            self.threads = threads

    def outer(data, threads=None, backend=None, entropy_backend=None):
        return CodecOptions(threads=threads, backend="host")
    """
    assert not lint(code, KNOB_SCOPE, [knobs])


def test_knob_surface_round_trip_real_repo():
    """Every SURFACE pin resolves against the real repo files: the declared
    entry points exist and accept their full knob sets (incl. options=)."""
    from repro.analysis.driver import find_repo_root, load_project

    project = load_project(find_repo_root())
    v = [x for x in knobs.check(project) if x.rule == "knob-surface"]
    assert not v, [f"{x.path}:{x.lineno} {x.message}" for x in v]
    # the pins themselves cover the redesigned surface
    assert "options" in knobs.KNOBS
    for rel in (
        "src/repro/core/options.py",
        "src/repro/serve/kvcache.py",
    ):
        assert rel in knobs.SURFACE


# ---------------------------------------------------------------------------
# container spec
# ---------------------------------------------------------------------------

ENGINE = "src/repro/core/engine.py"

_SPEC_OK_PREFIX = """
    import struct

    _STREAM_MAGIC = b"ZNS1"
    _SHDR = struct.Struct("<4sHH16sQ")
    _FRAME = struct.Struct("<BQQI")
"""


def test_spec_format_matches():
    v = lint(_SPEC_OK_PREFIX, ENGINE, [container_spec])
    assert not [x for x in v if x.rule in ("spec-format", "spec-magic")]


def test_spec_format_drift_flagged():
    bad = _SPEC_OK_PREFIX.replace('"<BQQI"', '"<BQII"')
    v = lint(bad, ENGINE, [container_spec])
    assert "spec-format" in rules_of(v)


def test_spec_undeclared_struct_flagged():
    bad = _SPEC_OK_PREFIX + "    _EXTRA = struct.Struct('<II')\n"
    v = lint(bad, ENGINE, [container_spec])
    assert "spec-format" in rules_of(v)


def test_spec_inline_struct_outside_owning_modules():
    code = "import struct\nhdr = struct.pack('<I', 1)\n"
    v = lint(code, CORE, [container_spec])
    assert rules_of(v) == {"spec-format"}


def test_spec_missing_magic():
    bad = _SPEC_OK_PREFIX.replace('    _STREAM_MAGIC = b"ZNS1"\n', "")
    v = lint(bad, ENGINE, [container_spec])
    assert "spec-magic" in rules_of(v)


def test_spec_pack_arity():
    bad = _SPEC_OK_PREFIX + "    rec = _FRAME.pack(1, 2, 3)\n"
    v = lint(bad, ENGINE, [container_spec])
    assert "spec-arity" in rules_of(v)


def test_spec_unpack_arity():
    bad = _SPEC_OK_PREFIX + """
    def parse(rec):
        kind, raw_len, comp_len = _FRAME.unpack(rec)
        return kind
    """
    v = lint(bad, ENGINE, [container_spec])
    assert "spec-arity" in rules_of(v)


_PARSE = _SPEC_OK_PREFIX + """
    def parse(fp):
        kind, raw_len, comp_len, crc = _FRAME.unpack(fp.read(_FRAME.size))
        {guard}body = fp.read(comp_len)
        return body
"""


def test_spec_unchecked_length_flagged():
    v = lint(_PARSE.format(guard=""), ENGINE, [container_spec])
    assert "spec-unchecked-length" in rules_of(v)


def test_spec_checked_length_passes():
    ok = _PARSE.format(
        guard="if comp_len > (64 << 20):\n"
        "            raise IOError('frame too large')\n        "
    )
    v = lint(ok, ENGINE, [container_spec])
    assert "spec-unchecked-length" not in rules_of(v)


def test_spec_min_clamp_passes():
    ok = _PARSE.format(guard="comp_len = min(comp_len, 64 << 20)\n        ")
    v = lint(ok, ENGINE, [container_spec])
    assert "spec-unchecked-length" not in rules_of(v)


# ---------------------------------------------------------------------------
# kernel contract
# ---------------------------------------------------------------------------

_KERNEL = """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    HIST_ROWS = 128
    LANES = 128

    def _hist_kernel(x_ref, out_ref):
        out_ref[...] = jnp.zeros_like(out_ref)

    def histogram_2d(x, *, interpret: bool = True):
        m = x.shape[0]
        return pl.pallas_call(
            _hist_kernel,
            grid=(m // HIST_ROWS,),
            in_specs=[pl.BlockSpec(({in_rows}, LANES), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((256,), {out_lam}),
            out_shape=jax.ShapeDtypeStruct((256,), jnp.{dtype}),
            interpret={interp},
        )(x)
"""

_GOOD = dict(in_rows="HIST_ROWS", out_lam="lambda i: (0,)", dtype="int32",
             interp="interpret")


def _kern(**over):
    return _KERNEL.format(**{**_GOOD, **over})


def test_kernel_clean_passes():
    assert not lint(_kern(), KERN, [kernel_contract])


def test_kernel_registry():
    code = _kern().replace("histogram_2d", "mystery_kernel_2d")
    v = lint(code, KERN, [kernel_contract])
    assert "kernel-registry" in rules_of(v)


def test_kernel_index_map_arity():
    v = lint(_kern(out_lam="lambda i, j: (0,)"), KERN, [kernel_contract])
    assert "kernel-index-map" in rules_of(v)


def test_kernel_index_map_rank():
    v = lint(_kern(out_lam="lambda i: (0, 0)"), KERN, [kernel_contract])
    assert "kernel-index-map" in rules_of(v)


def test_kernel_block_shape_mismatch():
    # FP32_ROWS block under a grid stepping by HIST_ROWS: copy-paste class
    code = "    FP32_ROWS = 256\n" + _kern(in_rows="FP32_ROWS")
    v = lint(textwrap.dedent(code), KERN, [kernel_contract])
    assert "kernel-block-shape" in rules_of(v)


def test_kernel_dtype_contract():
    v = lint(_kern(dtype="uint8"), KERN, [kernel_contract])
    assert "kernel-dtype" in rules_of(v)


def test_kernel_interpret_hardcoded():
    v = lint(_kern(interp="True"), KERN, [kernel_contract])
    assert "kernel-interpret" in rules_of(v)


def test_kernel_arity_mismatch():
    code = _kern().replace(
        "def _hist_kernel(x_ref, out_ref):",
        "def _hist_kernel(x_ref, y_ref, out_ref):",
    )
    v = lint(code, KERN, [kernel_contract])
    assert "kernel-arity" in rules_of(v)


# ---------------------------------------------------------------------------
# whole-repo smoke
# ---------------------------------------------------------------------------

def test_repo_is_clean_strict():
    """`python -m repro.analysis --strict` exits 0 on the repo (the CI gate)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("GITHUB_ACTIONS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict", "--root", REPO],
        capture_output=True,
        text=True,
        env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_real_repo_files_parse_into_project():
    from repro.analysis.driver import find_repo_root, load_project

    root = find_repo_root()
    project = load_project(root)
    rels = {f.rel for f in project.files}
    assert "src/repro/core/zipnn.py" in rels
    assert "src/repro/core/engine.py" in rels
    # scan order is sorted -> deterministic report order
    assert [f.rel for f in project.files] == sorted(rels)


def test_multifile_project_cross_module_knobs():
    """Knob edges resolve across files (zipnn -> engine style)."""
    callee = SourceFile.parse(
        "src/repro/checkpoint/fake_engine.py",
        "def get_pool(threads):\n    return None\n",
    )
    caller = SourceFile.parse(
        "src/repro/checkpoint/fake_zipnn.py",
        "def compress_bytes(raw, threads=None):\n"
        "    return get_pool()\n",
    )
    v = [
        x
        for x in analyze_project(Project([callee, caller]), [knobs])
        if x.rule == "knob-dropped"
    ]
    assert len(v) == 1 and v[0].path.endswith("zipnn.py")
