"""Device plane-consumer decode backend: bit-parity with the host path.

The contract under test (ISSUE 3): for every backend × thread-count
combination, *decoded* bytes are **bit-identical** — the knobs change
wall-clock only.  All parity assertions go through the shared harness in
``tests/parity.py`` (also the CI smoke), so every decode test and the
smoke enforce one contract.  Device kernels run in interpret mode on CPU,
so these are exact-semantics tests, not speed tests.
"""

import io

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

import parity
from repro.core import bitlayout, device_plane, device_unplane, engine, zipnn


def _bf16(n, seed=0, scale=0.02):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(ml_dtypes.bfloat16)


def _fp32(n, seed=0, scale=0.3):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


class TestDecodeParity:
    """Acceptance criterion: bf16/fp32/fp16 × {host, device, auto} ×
    {1, 4} threads, all bit-exact through the shared harness."""

    @pytest.mark.parametrize("dtype", parity.DTYPES)
    def test_bytes_parity(self, dtype):
        arr = parity.make_array(dtype, 150_003, seed=1)
        parity.assert_decode_parity(parity.as_bytes(arr), dtype, label=dtype)

    def test_unaligned_tail_parity(self):
        raw = parity.as_bytes(_bf16(70_000, seed=2)) + b"\x05"
        parity.assert_decode_parity(raw, "bfloat16", label="tail")

    def test_empty_and_tiny(self):
        for n in (0, 1, 7):
            arr = parity.make_array("bfloat16", n, seed=n)
            parity.assert_decode_parity(
                parity.as_bytes(arr), "bfloat16", label=f"n={n}"
            )

    @pytest.mark.parametrize("dtype", parity.DTYPES)
    def test_delta_parity(self, dtype):
        base = parity.make_array(dtype, 120_000, seed=3)
        new = np.asarray(base).copy()
        idx = np.random.default_rng(4).integers(0, new.size, new.size // 50)
        new[idx] = parity.make_array(dtype, idx.size, seed=5)
        parity.assert_delta_parity(new, base, label=f"delta {dtype}")

    def test_delta_all_zero(self):
        base = _fp32(80_000, seed=6)
        parity.assert_delta_parity(base, base, label="zero delta")

    def test_stream_reader_parity(self):
        raw = parity.as_bytes(_bf16(300_000, seed=7))
        parity.assert_stream_parity(raw, "bfloat16", label="stream")

    def test_decompress_file_device_backend(self, tmp_path):
        data = parity.as_bytes(_bf16(300_000, seed=8))
        src, dst = tmp_path / "in.bin", tmp_path / "out.znns"
        src.write_bytes(data)
        engine.compress_file(str(src), str(dst), "bfloat16", window_bytes=1 << 18)
        for be in ("host", "device"):
            back = tmp_path / f"back_{be}.bin"
            n = engine.decompress_file(str(dst), str(back), threads=4, backend=be)
            assert n == len(data)
            assert back.read_bytes() == data

    def test_pytree_batched_decode_parity(self):
        import jax

        tree = {
            "wte": _bf16(70_000, seed=9).reshape(700, 100),
            "tiny": [_bf16(33, seed=10), _bf16(1, seed=11)],
            "zeros": np.zeros(40_000, ml_dtypes.bfloat16),
            "f32": _fp32(20_000, seed=12),
            "f16": parity.make_array("float16", 9_000, seed=13),
            "int": np.arange(100, dtype=np.int32),   # non-rotated → host
            "step": np.asarray(7, dtype=np.int32),
        }
        man = zipnn.compress_pytree(tree)
        host = zipnn.decompress_pytree(man, backend="host")
        dev = zipnn.decompress_pytree(man, threads=4, backend="device")
        def u8(x):
            return np.ascontiguousarray(x).reshape(-1).view(np.uint8)

        for a, b, c in zip(
            jax.tree_util.tree_leaves(tree),
            jax.tree_util.tree_leaves(host),
            jax.tree_util.tree_leaves(dev),
        ):
            np.testing.assert_array_equal(u8(a), u8(b))
            np.testing.assert_array_equal(u8(b), u8(c))


class TestDeviceUnplaneModule:
    def test_consume_inverts_produce(self):
        layout = bitlayout.layout_for("bfloat16")
        params = zipnn.DEFAULT.plane_params(2)
        arr = _bf16(262_144, seed=20)
        raw = parity.as_bytes(arr)
        planes, _ = device_plane.produce_planes(
            np.frombuffer(raw, np.uint8), layout, params
        )
        back = device_unplane.consume_planes(planes, layout)
        np.testing.assert_array_equal(back, np.frombuffer(raw, np.uint8))

    def test_consume_matches_from_planes(self):
        layout = bitlayout.layout_for("float32")
        raw = np.frombuffer(parity.as_bytes(_fp32(65_536, seed=21)), np.uint8)
        planes = bitlayout.to_planes(raw, layout)
        dev = device_unplane.consume_planes(planes, layout)
        host = bitlayout.from_planes(planes, layout)
        np.testing.assert_array_equal(dev, host)

    def test_batched_matches_single(self):
        layout = bitlayout.layout_for("bfloat16")
        leaves = [_bf16(40_000, seed=22), _bf16(5, seed=23),
                  np.zeros(0, ml_dtypes.bfloat16), _bf16(131_072, seed=24)]
        planes_list = [
            bitlayout.to_planes(
                np.frombuffer(parity.as_bytes(l), np.uint8), layout
            )
            for l in leaves
        ]
        batched = device_unplane.consume_planes_batched(planes_list, layout)
        for leaf, planes, got in zip(leaves, planes_list, batched):
            single = device_unplane.consume_planes(planes, layout)
            np.testing.assert_array_equal(got, single)
            np.testing.assert_array_equal(
                got, np.frombuffer(parity.as_bytes(leaf), np.uint8)
            )

    def test_batched_delta_bases(self):
        layout = bitlayout.layout_for("bfloat16")
        news = [_bf16(30_000, seed=25), _bf16(17, seed=26)]
        bases = [_bf16(30_000, seed=27), None]
        planes_list = []
        for new, base in zip(news, bases):
            x = np.frombuffer(parity.as_bytes(new), np.uint8)
            if base is not None:
                x = np.bitwise_xor(
                    x, np.frombuffer(parity.as_bytes(base), np.uint8)
                )
            planes_list.append(bitlayout.to_planes(x, layout))
        back = device_unplane.consume_planes_batched(
            planes_list, layout, bases=bases
        )
        for new, got in zip(news, back):
            np.testing.assert_array_equal(
                got, np.frombuffer(parity.as_bytes(new), np.uint8)
            )

    def test_supports_and_resolve(self):
        assert device_unplane.supports(bitlayout.layout_for("bfloat16"))
        assert device_unplane.supports(bitlayout.layout_for("float16"))
        assert device_unplane.supports(bitlayout.layout_for("float32"))
        assert not device_unplane.supports(bitlayout.layout_for("int32"))
        assert not device_unplane.supports(bitlayout.layout_for("uint8"))
        assert not device_unplane.supports(bitlayout.layout_for("float64"))
        lay = bitlayout.layout_for("bfloat16")
        assert device_unplane.resolve(None, lay) == "host"
        assert device_unplane.resolve("host", lay) == "host"
        assert device_unplane.resolve("device", lay) == "device"
        assert (
            device_unplane.resolve("device", bitlayout.layout_for("int32"))
            == "host"
        )
        with pytest.raises(ValueError, match="unknown plane backend"):
            device_unplane.resolve("gpu", lay)

    def test_auto_without_accelerator_is_host_unless_device_base(self):
        import jax

        lay = bitlayout.layout_for("bfloat16")
        expected = "host" if jax.default_backend() == "cpu" else "device"
        assert device_unplane.resolve("auto", lay) == expected
        # a device-resident base flips auto to device only on accelerators;
        # CPU jax arrays do not count (no upload is worth paying for)
        base = jnp.asarray(_bf16(1024, seed=28))
        assert device_unplane.resolve("auto", lay, base=base) == expected

    def test_unknown_layout_name_raises(self):
        with pytest.raises(ValueError, match="unknown ZNN1 layout"):
            bitlayout.layout_by_name("nope")


class TestEngineAwareDecodeSubsystems:
    def test_checkpoint_restore_backend_parity(self, tmp_path):
        from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

        state = {"w": _bf16(50_000, seed=30), "opt": {"m": _fp32(20_000, seed=31)}}
        outs = {}
        for name, backend in (("host", "host"), ("dev", "device")):
            cfg = CheckpointConfig(
                directory=str(tmp_path / name), backend=backend, async_save=False
            )
            m = CheckpointManager(cfg)
            m.save(1, state, blocking=True)
            step, back = m.restore()
            assert step == 1
            outs[name] = back
        for key in ("w",):
            np.testing.assert_array_equal(
                np.asarray(outs["host"][key]).view(np.uint8),
                np.asarray(outs["dev"][key]).view(np.uint8),
            )
            np.testing.assert_array_equal(
                np.asarray(outs["dev"][key]).view(np.uint8),
                np.ascontiguousarray(state[key]).view(np.uint8),
            )

    def test_batched_delta_saves_match_serial(self, tmp_path):
        """Satellite: manager delta saves route through
        produce_planes_batched(bases=...) on the device backend; blobs are
        byte-identical to the leaf-at-a-time host path."""
        from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

        state1 = {
            "w": _bf16(50_000, seed=32),
            "b": _bf16(300, seed=33),
            "opt": {"m": _fp32(20_000, seed=34)},
            "step": np.asarray(1, np.int32),
        }
        state2 = {
            "w": np.asarray(state1["w"]).copy(),
            "b": np.asarray(state1["b"]).copy(),
            "opt": {"m": state1["opt"]["m"] * np.float32(1.01)},
            "step": np.asarray(2, np.int32),
        }
        w2 = np.asarray(state2["w"]).reshape(-1)
        idx = np.random.default_rng(35).integers(0, w2.size, w2.size // 60)
        w2[idx] = (np.asarray(w2[idx], np.float32) * 1.01).astype(ml_dtypes.bfloat16)
        for name, backend in (("host", "host"), ("dev", "device")):
            cfg = CheckpointConfig(
                directory=str(tmp_path / name), backend=backend,
                async_save=False, base_every=5,
            )
            m = CheckpointManager(cfg)
            m.save(1, state1, blocking=True)       # base
            m.save(2, state2, blocking=True)       # delta vs base
        for step in (1, 2):
            h = (tmp_path / "host" / f"step_{step}" / "data.bin").read_bytes()
            d = (tmp_path / "dev" / f"step_{step}" / "data.bin").read_bytes()
            assert h == d, f"step {step} blobs differ across backends"

    def test_delta_compress_batched_matches_serial(self):
        news = [_bf16(40_000, seed=36), _bf16(64, seed=37), _fp32(9_000, seed=38)]
        bases = [_bf16(40_000, seed=39), _bf16(64, seed=37), _fp32(9_000, seed=40)]
        serial = [zipnn.delta_compress(a, b) for a, b in zip(news, bases)]
        for be in ("host", "device"):
            batched = zipnn.delta_compress_batched(news, bases, backend=be)
            assert [c.blob for c in batched] == [c.blob for c in serial], be
            for i, ct in enumerate(batched):
                back = zipnn.delta_decompress(ct, bases[i], backend=be)
                np.testing.assert_array_equal(
                    back.view(np.uint8),
                    np.ascontiguousarray(news[i]).view(np.uint8),
                )

    def test_grad_sync_decode_backend(self):
        import jax

        from repro.distributed.grad_sync import GradSync

        tree = {"w": _bf16(60_000, seed=41).reshape(300, 200),
                "b": np.zeros(256, np.float32)}
        manifest, _ = GradSync().pack(tree)
        back = GradSync(threads=4, backend="device").unpack(manifest)
        for a, b in zip(
            jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)
        ):
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8)
            )

    def test_hub_download_decode_backend(self, tmp_path):
        from repro.checkpoint import hub

        data = parity.as_bytes(_bf16(200_000, seed=42))
        src = tmp_path / "model.bin"
        src.write_bytes(data)
        rep = hub.simulate_file_transfer(
            str(src), "bfloat16", "first_download_home",
            window_bytes=1 << 18, threads=2, backend="device",
        )
        assert rep.raw_bytes == len(data)
        assert rep.overlapped_speedup > 0
