"""Substrate tests: optimizer, data pipeline, train step (loss decreases),
checkpoint manager (compression, deltas, periodic bases, crash recovery,
async), gradient sync, hub transfer."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.checkpoint.hub import simulate_transfer
from repro.configs import get_config
from repro.data import DataConfig, batch_specs, make_batch
from repro.distributed.grad_sync import GradSync, straggler_reissue_plan
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("repro_gpt_100m").reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    return cfg, model, state


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = get_config("yi_6b").reduced()
        dc = DataConfig(seq_len=32, global_batch=4, seed=7)
        b1 = make_batch(cfg, dc, 5)
        b2 = make_batch(cfg, dc, 5)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        b3 = make_batch(cfg, dc, 6)
        assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))

    def test_specs_match_batches(self):
        for arch in ["yi_6b", "qwen2_vl_2b", "hubert_xlarge", "mamba2_130m"]:
            cfg = get_config(arch).reduced()
            dc = DataConfig(seq_len=64, global_batch=2)
            specs = batch_specs(cfg, dc)
            batch = make_batch(cfg, dc, 0)
            assert set(specs) == set(batch)
            for k in specs:
                assert specs[k].shape == batch[k].shape, (arch, k)

    def test_tokens_in_vocab(self):
        cfg = get_config("yi_6b").reduced()
        dc = DataConfig(seq_len=128, global_batch=4)
        b = make_batch(cfg, dc, 3)
        assert int(jnp.max(b["tokens"])) < cfg.vocab_size
        assert int(jnp.min(b["tokens"])) >= 0


class TestTrainStep:
    def test_loss_decreases(self, tiny_setup):
        cfg, model, state = tiny_setup
        dc = DataConfig(seq_len=64, global_batch=8)
        ocfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=40)
        step = jax.jit(make_train_step(model, ocfg))
        batch = make_batch(cfg, dc, 0)   # overfit one batch
        losses = []
        for i in range(30):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 1.0, losses[::6]
        assert np.isfinite(losses).all()

    def test_microbatch_equivalence(self, tiny_setup):
        cfg, model, _ = tiny_setup
        state = init_train_state(model, jax.random.key(1))
        dc = DataConfig(seq_len=32, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3)
        batch = make_batch(cfg, dc, 0)
        s1, m1 = jax.jit(make_train_step(model, ocfg, microbatches=1))(state, batch)
        s2, m2 = jax.jit(make_train_step(model, ocfg, microbatches=4))(state, batch)
        # same data, same params → grads should match to accumulation error
        for a, b in zip(
            jax.tree_util.tree_leaves(s1["params"]),
            jax.tree_util.tree_leaves(s2["params"]),
        ):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-2
            )

    def test_lr_schedule(self):
        from repro.optim import lr_schedule

        ocfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        assert float(lr_schedule(ocfg, jnp.asarray(0))) == 0.0
        assert abs(float(lr_schedule(ocfg, jnp.asarray(10))) - 1.0) < 1e-6
        assert float(lr_schedule(ocfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


class TestCheckpointManager:
    def _state(self, seed=0, scale=1.0):
        rng = np.random.default_rng(seed)
        import ml_dtypes

        return {
            "params": {
                "w": (rng.standard_normal((256, 256)) * 0.02 * scale).astype(
                    ml_dtypes.bfloat16
                ),
                "b": np.zeros(256, np.float32),
            },
            "opt": {"m": {"w": (rng.standard_normal((256, 256)) * 1e-4).astype(np.float32)}},
            "step": np.asarray(seed, np.int32),
        }

    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        state = self._state(3)
        mgr.save(3, state, blocking=True)
        step, back = mgr.restore()
        assert step == 3
        np.testing.assert_array_equal(
            back["params"]["w"].view(np.uint8), state["params"]["w"].view(np.uint8)
        )
        np.testing.assert_array_equal(back["opt"]["m"]["w"], state["opt"]["m"]["w"])

    def test_periodic_base_and_deltas(self, tmp_path):
        mgr = CheckpointManager(
            CheckpointConfig(str(tmp_path), base_every=3, async_save=False, keep_bases=99)
        )
        base = self._state(0)
        for i in range(6):
            st = self._state(0)
            # small drift: ~1% of weights change per "epoch"
            w = np.asarray(st["params"]["w"], np.float32)
            idx = np.random.default_rng(i).integers(0, w.size, w.size // 100)
            w.reshape(-1)[idx] *= 1.001
            import ml_dtypes

            st["params"]["w"] = w.astype(ml_dtypes.bfloat16)
            st["step"] = np.asarray(i, np.int32)
            mgr.save(i, st, blocking=True)
        stats = mgr.stats()
        kinds = [s["kind"] for s in stats]
        assert kinds == ["base", "delta", "delta", "base", "delta", "delta"]
        # deltas must compress far better than bases
        base_r = [s["ratio_pct"] for s in stats if s["kind"] == "base"]
        delta_r = [s["ratio_pct"] for s in stats if s["kind"] == "delta"]
        assert min(base_r) > 50.0
        assert max(delta_r) < 30.0
        # every delta restores exactly
        for i in range(6):
            _, back = mgr.restore(i)
            assert int(back["step"]) == i

    def test_crash_recovery_skips_torn_checkpoint(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        mgr.save(1, self._state(1), blocking=True)
        mgr.save(2, self._state(2), blocking=True)
        # corrupt the newest one (torn write)
        with open(tmp_path / "step_2" / "data.bin", "r+b") as f:
            f.seek(100)
            f.write(b"\xde\xad\xbe\xef" * 8)
        step, back = mgr.restore()
        assert step == 1 and int(back["step"]) == 1

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=True))
        mgr.save(7, self._state(7))
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(
            CheckpointConfig(str(tmp_path), base_every=2, keep_bases=1, async_save=False)
        )
        for i in range(6):
            mgr.save(i, self._state(i), blocking=True)
        remaining = sorted(s["step"] for s in mgr.stats())
        assert remaining == [4, 5]          # last base + its delta

    def test_elastic_shard_restore(self, tmp_path):
        from jax.sharding import PartitionSpec as P

        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
        mgr.save(1, self._state(1), blocking=True)
        mesh = jax.make_mesh((1,), ("data",))
        specs = {
            "params": {"w": P(None, None), "b": P(None)},
            "opt": {"m": {"w": P(None, None)}},
            "step": P(),
        }
        step, tree = mgr.shard_restore(None, mesh, specs)
        assert step == 1
        assert isinstance(tree["params"]["w"], jax.Array)

    def test_resume_counts_from_disk(self, tmp_path):
        cfg = CheckpointConfig(str(tmp_path), base_every=2, async_save=False, keep_bases=99)
        mgr = CheckpointManager(cfg)
        mgr.save(0, self._state(0), blocking=True)
        mgr.save(1, self._state(1), blocking=True)
        # new manager (process restart) must continue the base cadence
        mgr2 = CheckpointManager(cfg)
        mgr2.save(2, self._state(2), blocking=True)
        kinds = [s["kind"] for s in mgr2.stats()]
        assert kinds == ["base", "delta", "base"]


class TestMomentChains:
    """Optimizer-moment compression: AdamW m/v delta-vs-previous-save.

    EMA moments drift a little every step, so vs-prev deltas are much
    sparser than vs-base — moment leaves in delta saves carry kind
    ``delta_prev`` with ``prev_step`` links, bases store moments in full
    (bounding the restore chain at ``base_every``), and every step
    restores bit-exactly through the chain."""

    def _state(self, i, rng):
        import ml_dtypes

        w = (rng.standard_normal((128, 128)) * 0.02).astype(ml_dtypes.bfloat16)
        g = (rng.standard_normal((128, 128)) * 1e-3).astype(np.float32)
        return {
            "params": {"w": w},
            "opt": {
                "m": {"w": g},
                "v": {"w": np.square(g)},
                "count": np.asarray(i, np.int32),
            },
            "step": np.asarray(i, np.int32),
        }

    def _drifted(self, steps, seed=0):
        """A save sequence whose moments drift like EMAs (small per-step
        change), while params drift independently."""
        import ml_dtypes

        rng = np.random.default_rng(seed)
        st = self._state(0, rng)
        out = [st]
        for i in range(1, steps):
            st = {
                "params": {"w": st["params"]["w"]},
                "opt": {
                    "m": {"w": st["opt"]["m"]["w"].copy()},
                    "v": {"w": st["opt"]["v"]["w"].copy()},
                    "count": np.asarray(i, np.int32),
                },
                "step": np.asarray(i, np.int32),
            }
            # ~1% of moment entries move per step (EMA-style slow drift)
            for key in ("m", "v"):
                arr = st["opt"][key]["w"].reshape(-1)
                idx = rng.integers(0, arr.size, arr.size // 100)
                arr[idx] *= 1.01
            w = np.asarray(st["params"]["w"], np.float32)
            idx = rng.integers(0, w.size, w.size // 100)
            w.reshape(-1)[idx] *= 1.001
            st["params"]["w"] = w.astype(ml_dtypes.bfloat16)
            out.append(st)
        return out

    def _manifest(self, tmp_path, step):
        import json

        with open(tmp_path / f"step_{step}" / "manifest.json") as f:
            return json.load(f)

    def test_delta_prev_chain_kinds_and_links(self, tmp_path):
        mgr = CheckpointManager(
            CheckpointConfig(
                str(tmp_path), base_every=3, async_save=False, keep_bases=99
            )
        )
        states = self._drifted(6)
        for i, st in enumerate(states):
            mgr.save(i, st, blocking=True)
        for i in range(6):
            man = self._manifest(tmp_path, i)
            kinds = {e["key"]: e["kind"] for e in man["entries"]}
            if i % 3 == 0:                       # base: moments in full
                assert kinds["opt/m/w"] == "full"
                assert kinds["opt/v/w"] == "full"
                assert man["prev_step"] is None
            else:                                # delta: moments vs prev save
                assert kinds["opt/m/w"] == "delta_prev"
                assert kinds["opt/v/w"] == "delta_prev"
                assert man["prev_step"] == i - 1
                assert kinds["params/w"] == "delta"   # params still vs base
            # non-moment opt leaves never chain
            assert kinds["opt/count"] in ("full", "delta")

    def test_chain_restores_bit_exact(self, tmp_path):
        mgr = CheckpointManager(
            CheckpointConfig(
                str(tmp_path), base_every=3, async_save=False, keep_bases=99
            )
        )
        states = self._drifted(7, seed=1)
        for i, st in enumerate(states):
            mgr.save(i, st, blocking=True)
        for i, st in enumerate(states):
            _, back = mgr.restore(i)
            for key in ("m", "v"):
                np.testing.assert_array_equal(
                    back["opt"][key]["w"].view(np.uint8),
                    st["opt"][key]["w"].view(np.uint8),
                )
            np.testing.assert_array_equal(
                back["params"]["w"].view(np.uint8),
                st["params"]["w"].view(np.uint8),
            )

    def test_moment_deltas_beat_full(self, tmp_path):
        """Slow-drifting moments must compress far better vs-prev than the
        full moment payload in the base save."""
        mgr = CheckpointManager(
            CheckpointConfig(
                str(tmp_path), base_every=4, async_save=False, keep_bases=99
            )
        )
        states = self._drifted(4, seed=2)
        for i, st in enumerate(states):
            mgr.save(i, st, blocking=True)
        base_man = self._manifest(tmp_path, 0)
        delta_man = self._manifest(tmp_path, 2)
        size = lambda man, key: next(
            e["size"] for e in man["entries"] if e["key"] == key
        )
        assert size(delta_man, "opt/m/w") < 0.5 * size(base_man, "opt/m/w")
        assert size(delta_man, "opt/v/w") < 0.5 * size(base_man, "opt/v/w")

    def test_restart_breaks_chain_safely(self, tmp_path):
        """The prev-moment snapshot lives in RAM only: a new manager must
        not emit delta_prev on its first save, and restores stay exact."""
        cfg = CheckpointConfig(
            str(tmp_path), base_every=4, async_save=False, keep_bases=99
        )
        states = self._drifted(4, seed=3)
        mgr = CheckpointManager(cfg)
        mgr.save(0, states[0], blocking=True)
        mgr.save(1, states[1], blocking=True)
        mgr2 = CheckpointManager(cfg)            # process restart
        mgr2.save(2, states[2], blocking=True)
        man = self._manifest(tmp_path, 2)
        kinds = {e["key"]: e["kind"] for e in man["entries"]}
        assert kinds["opt/m/w"] != "delta_prev"
        assert man["prev_step"] is None
        _, back = mgr2.restore(2)
        np.testing.assert_array_equal(
            back["opt"]["m"]["w"], states[2]["opt"]["m"]["w"]
        )

    def test_moment_keys_empty_disables_chaining(self, tmp_path):
        mgr = CheckpointManager(
            CheckpointConfig(
                str(tmp_path), base_every=3, async_save=False,
                keep_bases=99, moment_keys=(),
            )
        )
        for i, st in enumerate(self._drifted(3, seed=4)):
            mgr.save(i, st, blocking=True)
        man = self._manifest(tmp_path, 1)
        kinds = {e["kind"] for e in man["entries"]}
        assert "delta_prev" not in kinds
        assert man["prev_step"] is None

    def test_chain_survives_retention_gc(self, tmp_path):
        """GC deletes whole base segments (base + its deltas), so surviving
        delta_prev chains always have their predecessors on disk."""
        mgr = CheckpointManager(
            CheckpointConfig(
                str(tmp_path), base_every=3, keep_bases=1, async_save=False
            )
        )
        states = self._drifted(6, seed=5)
        for i, st in enumerate(states):
            mgr.save(i, st, blocking=True)
        remaining = sorted(s["step"] for s in mgr.stats())
        assert remaining == [3, 4, 5]
        for i in (3, 4, 5):
            _, back = mgr.restore(i)
            np.testing.assert_array_equal(
                back["opt"]["m"]["w"].view(np.uint8),
                states[i]["opt"]["m"]["w"].view(np.uint8),
            )


class TestGradSync:
    def test_lossless_and_compressed(self, tiny_setup):
        cfg, model, state = tiny_setup
        gs = GradSync()
        manifest, stats = gs.pack(state["params"])
        assert stats.ratio_pct < 90.0       # bf16-dominated tree compresses
        back = gs.unpack(manifest)
        for a, b in zip(
            jax.tree_util.tree_leaves(jax.device_get(state["params"])),
            jax.tree_util.tree_leaves(back),
        ):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()

    def test_exchange_model(self, tiny_setup):
        cfg, model, state = tiny_setup
        gs = GradSync()
        rep = gs.exchange(state["params"], n_peers=4, link_gbps=1.0)
        assert rep["ratio_pct"] < 90.0
        assert rep["zipnn_s"] > 0 and rep["raw_s"] > 0

    def test_straggler_plan(self):
        times = [1.0, 1.1, 0.9, 1.0, 5.0, 1.05, 9.0, 1.0]
        assert straggler_reissue_plan(times) == [4, 6]


class TestHubTransfer:
    def test_download_speedup_on_compressible_model(self):
        import ml_dtypes

        w = (np.random.default_rng(0).standard_normal(2_000_000) * 0.02).astype(
            ml_dtypes.bfloat16
        )
        rep = simulate_transfer(
            np.ascontiguousarray(w).view(np.uint8).tobytes(), "bfloat16",
            "first_download_home",
        )
        assert rep.comp_bytes < 0.72 * rep.raw_bytes
        assert rep.speedup > 1.0            # slow link ⇒ compression wins
