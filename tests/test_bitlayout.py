"""Byte-group / exponent-extraction transform tests (paper §3.1, Fig. 3/5)."""

import ml_dtypes
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core import bitlayout

DTYPES = ["float32", "bfloat16", "float16", "float64", "int32", "uint8"]


@pytest.mark.parametrize("dtype_name", DTYPES)
@pytest.mark.parametrize("n", [0, 1, 7, 128, 4096, 65537])
def test_roundtrip(dtype_name, n):
    layout = bitlayout.layout_for(dtype_name)
    rng = np.random.default_rng(42 + n)
    raw = rng.integers(0, 256, n * layout.itemsize, dtype=np.uint8)
    planes = bitlayout.to_planes(raw, layout)
    assert len(planes) == layout.n_planes
    assert all(p.size == n for p in planes)
    back = bitlayout.from_planes(planes, layout)
    np.testing.assert_array_equal(back, raw)


def test_bf16_plane0_is_pure_exponent():
    """After rotation, plane 0 of BF16 must be exactly the biased exponent."""
    rng = np.random.default_rng(0)
    w = (rng.standard_normal(10000) * 0.05).astype(ml_dtypes.bfloat16)
    raw = np.ascontiguousarray(w).view(np.uint8)
    layout = bitlayout.layout_for("bfloat16")
    planes = bitlayout.to_planes(raw, layout)
    np.testing.assert_array_equal(
        planes[0].astype(np.int32), bitlayout.exponent_view(w)
    )


def test_fp32_plane0_is_pure_exponent():
    rng = np.random.default_rng(1)
    w = (rng.standard_normal(10000) * 0.05).astype(np.float32)
    layout = bitlayout.layout_for("float32")
    planes = bitlayout.to_planes(np.ascontiguousarray(w).view(np.uint8), layout)
    np.testing.assert_array_equal(
        planes[0].astype(np.int32), bitlayout.exponent_view(w)
    )


def test_sign_preserved():
    w = np.array([1.5, -1.5, 0.0, -0.0, 3e-40, -3e-40], dtype=np.float32)
    layout = bitlayout.layout_for("float32")
    back = bitlayout.from_planes(
        bitlayout.to_planes(w.view(np.uint8), layout), layout
    )
    np.testing.assert_array_equal(back.view(np.float32), w)
    # signs live in the LSB of the last plane after rotation
    planes = bitlayout.to_planes(w.view(np.uint8), layout)
    np.testing.assert_array_equal(planes[-1] & 1, [0, 1, 0, 1, 0, 1])


@given(st.binary(min_size=0, max_size=4096))
@settings(max_examples=50, deadline=None)
def test_roundtrip_property_fp32(data):
    layout = bitlayout.layout_for("float32")
    n = len(data) - len(data) % 4
    raw = np.frombuffer(data[:n], dtype=np.uint8)
    back = bitlayout.from_planes(bitlayout.to_planes(raw, layout), layout)
    np.testing.assert_array_equal(back, raw)


def test_special_values_roundtrip():
    specials = np.array(
        [np.nan, np.inf, -np.inf, 0.0, -0.0, np.finfo(np.float32).tiny,
         np.finfo(np.float32).max, -np.finfo(np.float32).max],
        dtype=np.float32,
    )
    layout = bitlayout.layout_for("float32")
    back = bitlayout.from_planes(
        bitlayout.to_planes(specials.view(np.uint8), layout), layout
    ).view(np.float32)
    np.testing.assert_array_equal(back.view(np.uint32), specials.view(np.uint32))


def test_rejects_misaligned():
    layout = bitlayout.layout_for("float32")
    with pytest.raises(ValueError):
        bitlayout.to_planes(np.zeros(7, dtype=np.uint8), layout)
    with pytest.raises(TypeError):
        bitlayout.to_planes(np.zeros(8, dtype=np.int16), layout)


# --- fp8 sub-byte layouts + int8 -------------------------------------------

FP8_DTYPES = ["float8_e4m3fn", "float8_e5m2"]


@pytest.mark.parametrize("dtype_name", FP8_DTYPES + ["int8"])
@pytest.mark.parametrize("n", [0, 2, 128, 4096, 65538])
def test_fp8_int8_roundtrip(dtype_name, n):
    layout = bitlayout.layout_for(dtype_name)
    rng = np.random.default_rng(7 + n)
    raw = rng.integers(0, 256, n * layout.itemsize, dtype=np.uint8)
    planes = bitlayout.to_planes(raw, layout)
    assert len(planes) == layout.n_planes
    back = bitlayout.from_planes(planes, layout)
    np.testing.assert_array_equal(back, raw)


@pytest.mark.parametrize("dtype_name", FP8_DTYPES)
def test_fp8_odd_buffer_rejected(dtype_name):
    """Sub-byte layouts split element *pairs*: align is 2 even at itemsize 1
    (an odd trailing element rides the container TAIL, not the planes)."""
    layout = bitlayout.layout_for(dtype_name)
    assert layout.align == 2 and layout.itemsize == 1
    with pytest.raises(ValueError):
        bitlayout.to_planes(np.zeros(7, dtype=np.uint8), layout)


def test_e4m3_high_nibbles_are_exponents():
    """Plane 0 of e4m3 packs the two elements' 4-bit exponents per byte."""
    rng = np.random.default_rng(2)
    w = (rng.standard_normal(10000) * 0.5).astype(ml_dtypes.float8_e4m3fn)
    layout = bitlayout.layout_for("float8_e4m3fn")
    planes = bitlayout.to_planes(np.ascontiguousarray(w).view(np.uint8), layout)
    exps = bitlayout.exponent_view(w)
    np.testing.assert_array_equal(planes[0] >> 4, exps[0::2])
    np.testing.assert_array_equal(planes[0] & 0x0F, exps[1::2])


def test_int8_single_plane_no_rotation():
    layout = bitlayout.layout_for("int8")
    assert layout.name == "i8" and not layout.rotate and layout.n_planes == 1
    raw = np.arange(256, dtype=np.uint8)
    (plane,) = bitlayout.to_planes(raw, layout)
    np.testing.assert_array_equal(plane, raw)


@pytest.mark.parametrize(
    "dtype", [ml_dtypes.float8_e4m3fn, ml_dtypes.float8_e5m2, np.int8]
)
@pytest.mark.parametrize("n", [1, 7, 50_001])  # odd sizes: container TAIL
def test_fp8_int8_codec_roundtrip(dtype, n):
    """Full ZNN1 round-trip for the quantized layouts, odd lengths included."""
    from repro.core import zipnn

    rng = np.random.default_rng(3)
    if np.dtype(dtype) == np.int8:
        arr = rng.integers(-127, 128, n).astype(np.int8)
    else:
        arr = (rng.standard_normal(n) * 0.5).astype(dtype)
    ct = zipnn.compress_array(arr)
    back = zipnn.decompress_array(ct)
    assert back.dtype == arr.dtype and back.shape == arr.shape
    assert back.tobytes() == arr.tobytes()
