"""End-to-end ZipNN API tests: round-trips, paper-ratio validation, deltas."""

import ml_dtypes
import numpy as np
import pytest
from _hyp_compat import given, settings, strategies as st

from repro.core import codec, zipnn


RNG = np.random.default_rng(0)


def _gauss(n, dtype, scale=0.02):
    w = (np.random.default_rng(123).standard_normal(n) * scale).astype(np.float32)
    return w.astype(dtype)


@pytest.mark.parametrize("backend", ["hufflib", "huffman"])
@pytest.mark.parametrize(
    "dtype", [np.float32, ml_dtypes.bfloat16, np.float16, np.int32, np.uint8]
)
def test_array_roundtrip(backend, dtype):
    cfg = zipnn.ZipNNConfig(backend=backend)
    arr = _gauss(100_000, np.float32).view(np.uint8)[: 100_000 * 4].view(np.float32)
    arr = (
        _gauss(50_000, dtype)
        if np.dtype(dtype).kind == "f" or dtype == ml_dtypes.bfloat16
        else np.random.default_rng(5).integers(0, 100, 50_000).astype(dtype)
    )
    ct = zipnn.compress_array(arr, cfg)
    back = zipnn.decompress_array(ct, cfg)
    assert back.dtype == arr.dtype and back.shape == arr.shape
    np.testing.assert_array_equal(
        back.view(np.uint8), np.ascontiguousarray(arr).view(np.uint8)
    )


class TestPaperRatios:
    """Validate the paper's headline compression numbers (§3.3, Table 2)."""

    def test_bf16_regular_about_66pct(self):
        arr = _gauss(4_000_000, ml_dtypes.bfloat16)
        ct = zipnn.compress_array(arr)
        r = zipnn.ratio(arr.nbytes, ct.nbytes)
        assert 62.0 <= r <= 70.0, r      # paper: ~66.4 %

    def test_fp32_regular_about_83pct(self):
        arr = _gauss(2_000_000, np.float32)
        ct = zipnn.compress_array(arr)
        r = zipnn.ratio(arr.nbytes, ct.nbytes)
        assert 79.0 <= r <= 87.0, r      # paper: ~83.3 %

    def test_clean_fp32_below_60pct(self):
        arr = np.round(_gauss(2_000_000, np.float32), 3).astype(np.float32)
        ct = zipnn.compress_array(arr)
        r = zipnn.ratio(arr.nbytes, ct.nbytes)
        assert r < 60.0, r               # paper clean models: 33–55 %

    def test_exponent_plane_compresses_3x(self):
        from repro.core import bitlayout, stats

        arr = _gauss(2_000_000, ml_dtypes.bfloat16)
        rep = stats.plane_report(arr)
        # exponent plane entropy ⇒ ~3× reduction; fraction ~incompressible
        assert rep[0]["est_ratio_pct"] < 45.0
        assert rep[1]["est_ratio_pct"] > 95.0

    def test_zipnn_beats_lz_baseline_on_bf16(self):
        """Paper: ZipNN ≥ 17 % better than vanilla zstd-class on BF16."""
        from repro.core import baselines

        arr = _gauss(2_000_000, ml_dtypes.bfloat16)
        raw = np.ascontiguousarray(arr).view(np.uint8).tobytes()
        zlib_size, _ = baselines.run_baseline("zlib", raw)
        ct = zipnn.compress_array(arr)
        assert ct.nbytes < zlib_size


def test_pytree_roundtrip():
    import jax

    tree = {
        "wte": _gauss(10_000, ml_dtypes.bfloat16).reshape(100, 100),
        "blocks": [
            {"w": _gauss(4_096, np.float32).reshape(64, 64), "b": np.zeros(64, np.float32)}
        ],
        "step": np.asarray(7, dtype=np.int32),
    }
    manifest = zipnn.compress_pytree(tree)
    back = zipnn.decompress_pytree(manifest)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["comp_bytes"] < manifest["raw_bytes"]


class TestDelta:
    def test_delta_roundtrip_and_ratio(self):
        base = _gauss(1_000_000, ml_dtypes.bfloat16)
        new = np.asarray(base).copy()
        idx = np.random.default_rng(1).integers(0, new.size, new.size // 100)
        new[idx] = (np.asarray(new[idx], np.float32) * 1.001).astype(ml_dtypes.bfloat16)
        ct = zipnn.delta_compress(new, base)
        rec = zipnn.delta_decompress(ct, base)
        np.testing.assert_array_equal(
            rec.view(np.uint8), np.ascontiguousarray(new).view(np.uint8)
        )
        # a 1 % change must compress far better than a standalone model
        assert zipnn.ratio(new.nbytes, ct.nbytes) < 20.0

    def test_delta_identical_models_near_zero(self):
        base = _gauss(500_000, np.float32)
        ct = zipnn.delta_compress(base, base)
        assert zipnn.ratio(base.nbytes, ct.nbytes) < 1.0

    def test_delta_mismatched_raises(self):
        with pytest.raises(ValueError):
            zipnn.delta_compress(np.zeros(4, np.float32), np.zeros(5, np.float32))

    def test_auto_selection_criteria(self):
        # >90 % zeros ⇒ ZLIB (LZ) chosen per §4.2
        params = codec.CodecParams(delta_mode=True, chunk_bytes=4096)
        pc = codec.PlaneCodec(params)
        chunk = np.zeros(4096, dtype=np.uint8)
        chunk[:100] = np.random.default_rng(2).integers(1, 255, 100)
        rng_chunk = np.random.default_rng(3).integers(0, 255, 4096).astype(np.uint8)
        pc.build_table(np.concatenate([chunk, rng_chunk]))
        assert pc._choose_method(chunk, 0) == codec.Method.ZLIB
        # long zero run (>3 %) ⇒ ZLIB even when zeros < 90 %
        chunk2 = np.random.default_rng(4).integers(1, 255, 4096).astype(np.uint8)
        chunk2[1000:1200] = 0
        assert pc._choose_method(chunk2, 0) == codec.Method.ZLIB


class TestAutoDetection:
    def test_incompressible_plane_stored(self):
        raw = np.random.default_rng(5).integers(0, 256, 1 << 20).astype(np.uint8)
        blob = zipnn.compress_bytes(raw.tobytes(), "uint8")
        # stored with only header/metadata overhead (< 1 %)
        assert len(blob) < raw.size * 1.01
        assert zipnn.decompress_bytes(blob) == raw.tobytes()

    def test_zero_plane_truncated(self):
        z = np.zeros(1 << 20, dtype=np.float32)
        ct = zipnn.compress_array(z)
        assert ct.nbytes < 4096   # headers only
        np.testing.assert_array_equal(zipnn.decompress_array(ct), z)

    def test_longest_zero_run(self):
        a = np.array([0, 0, 1, 0, 0, 0, 2, 0], dtype=np.uint8)
        assert codec.longest_zero_run(a) == 3
        assert codec.longest_zero_run(np.zeros(10, np.uint8)) == 10
        assert codec.longest_zero_run(np.ones(10, np.uint8)) == 0


@given(
    st.integers(0, 3000),
    st.sampled_from(["float32", "bfloat16", "float16"]),
    st.sampled_from(["hufflib", "huffman"]),
)
@settings(max_examples=40, deadline=None)
def test_roundtrip_property(n, dtype_name, backend):
    import ml_dtypes as md

    dtype = {"float32": np.float32, "bfloat16": md.bfloat16, "float16": np.float16}[
        dtype_name
    ]
    rng = np.random.default_rng(n)
    arr = (rng.standard_normal(n) * rng.uniform(1e-6, 1e3)).astype(dtype)
    cfg = zipnn.ZipNNConfig(backend=backend, chunk_param_bytes=1 << 10)
    ct = zipnn.compress_array(arr, cfg)
    back = zipnn.decompress_array(ct, cfg)
    np.testing.assert_array_equal(
        back.view(np.uint8), np.ascontiguousarray(arr).view(np.uint8)
    )
