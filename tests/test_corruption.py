"""Corruption/truncation fuzz: a damaged stream must raise a clean error —
never a wrong-bytes success, never a hang (ISSUE 3 satellite).

Two surfaces:

* ``DecompressReader`` / ``decompress_file`` over ``ZNS1`` containers —
  frame CRCs cover every byte of the frame body (the inner ZNN1 header,
  plane tables, metadata map and Huffman payloads), so *any* flip there
  must be detected.  Flips in the stream header hit explicit validation.
* bare ``decompress_bytes`` over a ``ZNN1`` blob — payload and metadata
  flips are caught by the per-chunk CRCs; header flips by the parse-time
  validation; Huffman damage additionally by the decoder's bit-cursor
  check.  (The raw u64 ``n_bytes`` header field and the 128-byte Huffman
  table have no redundancy of their own at this layer — single-bit damage
  there is only guaranteed detectable under the framed container, which is
  why checkpoints/files always travel as ZNS1.  They are excluded here and
  covered by the ZNS1 fuzz above.)

A "clean error" is ``ValueError`` / ``OSError`` (``IOError``).  Equality
with the original output is also accepted: some bytes are genuinely
don't-care (e.g. the recorded window size) and flipping them must not
*break* anything either.
"""

import io
import struct

import ml_dtypes
import numpy as np
import pytest

import parity
from repro.core import container, engine, zipnn

CLEAN = (ValueError, OSError)

CFG = zipnn.ZipNNConfig(chunk_param_bytes=1 << 14)


def _bf16_bytes(n, seed=0):
    rng = np.random.default_rng(seed)
    return parity.as_bytes((rng.standard_normal(n) * 0.02).astype(ml_dtypes.bfloat16))


def _zns1(raw: bytes, window: int = 1 << 15) -> bytes:
    sink = io.BytesIO()
    with engine.CompressWriter(sink, "bfloat16", CFG, window_bytes=window) as w:
        w.write(raw)
    return sink.getvalue()


def _read_all(blob: bytes) -> bytes:
    return engine.DecompressReader(io.BytesIO(blob), CFG).read()


def _positions(n: int, step: int):
    """Deterministic sample: every ``step``-th byte plus both edges."""
    pos = set(range(0, n, step))
    pos.update((0, 1, n // 2, n - 2, n - 1))
    return sorted(p for p in pos if 0 <= p < n)


class TestZNS1Corruption:
    """Frame-CRC-protected container: every section (stream header, inner
    ZNN1 header, plane table, metadata, Huffman payload) is fuzzed."""

    def setup_method(self):
        self.raw = _bf16_bytes(40_000, seed=1)
        self.blob = _zns1(self.raw)
        assert _read_all(self.blob) == self.raw

    @pytest.mark.parametrize("flip", [0xFF, 0x01, 0x80])
    def test_single_byte_corruption_everywhere(self, flip):
        for pos in _positions(len(self.blob), step=211):
            bad = bytearray(self.blob)
            bad[pos] ^= flip
            try:
                out = _read_all(bytes(bad))
            except CLEAN:
                continue
            assert out == self.raw, (
                f"byte {pos} ^ {flip:#x}: wrong-bytes success "
                f"({len(out)} bytes out)"
            )

    def test_truncation_everywhere(self):
        for n in _positions(len(self.blob), step=977):
            with pytest.raises(CLEAN):
                _read_all(self.blob[:n])

    def test_frame_kind_corruption(self):
        # the first frame record sits right after the stream header
        pos = engine._SHDR.size          # kind byte of frame 0
        bad = bytearray(self.blob)
        bad[pos] = 7
        with pytest.raises(CLEAN, match="frame kind"):
            _read_all(bytes(bad))

    def test_missing_end_frame(self):
        # drop the trailing end frame entirely
        with pytest.raises(CLEAN, match="end frame"):
            _read_all(self.blob[: -engine._FRAME.size])

    def test_whole_frame_dropped(self):
        """Remove one entire (record + body) frame: the end frame's total
        raw length must reject the stream as incomplete."""
        hdr = engine._SHDR.size
        kind, raw_len, comp_len, crc = engine._FRAME.unpack(
            self.blob[hdr : hdr + engine._FRAME.size]
        )
        assert kind == 1
        cut = hdr + engine._FRAME.size + comp_len
        bad = self.blob[:hdr] + self.blob[cut:]
        with pytest.raises(CLEAN):
            _read_all(bad)

    def test_decompress_file_corruption(self, tmp_path):
        src = tmp_path / "bad.znns"
        bad = bytearray(self.blob)
        bad[len(bad) // 2] ^= 0x10       # mid-payload flip
        src.write_bytes(bytes(bad))
        with pytest.raises(CLEAN):
            engine.decompress_file(str(src), str(tmp_path / "out.bin"))

    def test_corruption_with_threads_and_device_backend(self):
        """The prefetching reader and the device decode path reject damage
        identically — no path may turn a flip into silent output."""
        bad = bytearray(self.blob)
        bad[engine._SHDR.size + engine._FRAME.size + 100] ^= 0x40
        for threads, backend in ((4, "host"), (1, "device"), (4, "device")):
            with pytest.raises(CLEAN):
                engine.DecompressReader(
                    io.BytesIO(bytes(bad)), CFG, threads=threads, backend=backend
                ).read()


class TestZNN1Corruption:
    """Bare in-memory blobs: per-chunk CRCs + parse validation + the
    Huffman bit-cursor check."""

    def setup_method(self):
        self.raw = _bf16_bytes(40_000, seed=2)
        self.blob = zipnn.compress_bytes(self.raw, "bfloat16", CFG)
        self.meta, _ = container.unpack_stream(self.blob)
        assert zipnn.decompress_bytes(self.blob, CFG) == self.raw

    def _sections(self):
        """(start, end, name) spans with per-layer redundancy (see module
        docstring for what is excluded and why)."""
        hdr = container._HDR.size
        # u64 n_bytes sits at offset 24..32 of the header; exclude it
        n_bytes_off = struct.calcsize("<4sHH16s")
        spans = [
            (0, n_bytes_off, "header-pre"),
            (n_bytes_off + 8, hdr, "header-post"),
        ]
        table_end = self.meta.payload_base - sum(
            len(pe) * container._REC.size for pe in self.meta.entries
        )
        spans.append((table_end, self.meta.payload_base, "metadata-map"))
        spans.append((self.meta.payload_base, len(self.blob), "payloads"))
        return spans

    @pytest.mark.parametrize("flip", [0xFF, 0x01])
    def test_section_corruption(self, flip):
        for start, end, name in self._sections():
            for pos in _positions(end - start, step=97):
                bad = bytearray(self.blob)
                bad[start + pos] ^= flip
                try:
                    out = zipnn.decompress_bytes(bytes(bad), CFG)
                except CLEAN:
                    continue
                assert out == self.raw, (
                    f"{name} byte {start + pos} ^ {flip:#x}: "
                    f"wrong-bytes success"
                )

    def test_truncation(self):
        for n in _positions(len(self.blob), step=499):
            try:
                out = zipnn.decompress_bytes(self.blob[:n], CFG)
            except CLEAN:
                continue
            assert out == self.raw, f"truncation at {n}: wrong-bytes success"

    def test_bad_magic_version_layout(self):
        for pos, val, match in (
            (0, ord("X"), "not a ZNN1"),
            (4, 0x7F, "unsupported ZNN version"),
            (8, ord("q"), "layout"),
        ):
            bad = bytearray(self.blob)
            bad[pos] = val
            with pytest.raises(ValueError, match=match):
                zipnn.decompress_bytes(bytes(bad), CFG)

    def test_zero_chunk_bytes(self):
        off = struct.calcsize("<4sHH16sQ")       # chunk_bytes u32 offset
        bad = bytearray(self.blob)
        bad[off : off + 4] = b"\x00\x00\x00\x00"
        with pytest.raises(ValueError, match="chunk_bytes"):
            zipnn.decompress_bytes(bytes(bad), CFG)

    def test_payload_crc_is_verified_on_both_backends(self):
        bad = bytearray(self.blob)
        bad[self.meta.payload_base + 11] ^= 0x20
        for backend in ("host", "device"):
            with pytest.raises(CLEAN):
                zipnn.decompress_bytes(bytes(bad), CFG, backend=backend)

    def test_method_flip_to_zero_rejected(self):
        """A metadata flip that turns a payload chunk into ZERO must not
        silently produce zeros (the payload is still declared)."""
        rec_off = self.meta.payload_base - sum(
            len(pe) * container._REC.size for pe in self.meta.entries
        )
        assert self.blob[rec_off] != 1           # first record's method
        bad = bytearray(self.blob)
        bad[rec_off] = 1                          # Method.ZERO
        with pytest.raises(CLEAN):
            zipnn.decompress_bytes(bytes(bad), CFG)

    def test_huge_header_counts_do_not_hang_or_allocate(self):
        """A corrupted n_bytes cannot drive an unbounded metadata parse:
        the map is bounds-checked against the blob before the loop."""
        off = struct.calcsize("<4sHH16s")
        bad = bytearray(self.blob)
        bad[off : off + 8] = struct.pack("<Q", 1 << 62)
        with pytest.raises(ValueError, match="truncated ZNN1 metadata"):
            zipnn.decompress_bytes(bytes(bad), CFG)

    def test_empty_and_garbage_blobs(self):
        for blob in (b"", b"\x00" * 3, b"garbage" * 10, b"ZNN1" + b"\x00" * 5):
            with pytest.raises(CLEAN):
                zipnn.decompress_bytes(blob, CFG)
        for blob in (b"", b"ZNS1", b"\xff" * 64):
            with pytest.raises(CLEAN):
                engine.DecompressReader(io.BytesIO(blob), CFG).read()


@pytest.mark.slow
class TestDenseCorruptionSweep:
    """Denser flip sweep (every 31st byte × 2 masks) over a ZNS1 stream —
    the heavyweight version of the sampled test above."""

    def test_dense_zns1_sweep(self):
        raw = _bf16_bytes(30_000, seed=3)
        blob = _zns1(raw, window=1 << 14)
        for flip in (0xFF, 0x04):
            for pos in _positions(len(blob), step=31):
                bad = bytearray(blob)
                bad[pos] ^= flip
                try:
                    out = _read_all(bytes(bad))
                except CLEAN:
                    continue
                assert out == raw, f"byte {pos} ^ {flip:#x}: wrong-bytes success"
