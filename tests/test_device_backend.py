"""Device plane-producer backend: byte-parity with the host path.

The contract under test (ISSUE 2): for every backend × thread-count
combination the output blobs are **byte-identical** — the knobs change
wall-clock only.  Device kernels run in interpret mode on CPU, so these are
exact-semantics tests, not speed tests.
"""

import io

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.core import bitlayout, codec, device_plane, engine, zipnn


def _bf16(n, seed=0, scale=0.02):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(ml_dtypes.bfloat16)


def _fp32(n, seed=0, scale=0.3):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


def _probe_for(plane, cb):
    n_chunks = -(-plane.size // cb)
    hists = np.stack(
        [
            np.bincount(plane[c * cb : (c + 1) * cb], minlength=256)
            for c in range(n_chunks)
        ]
    )
    return codec.ProbeStats(
        chunk_hists=hists, table_hist=codec.table_probe_hist(plane)
    )


class TestBlobParity:
    @pytest.mark.parametrize("threads", [1, 4])
    @pytest.mark.parametrize(
        "dtype,n",
        [("bfloat16", 300_001), ("float32", 150_003)],  # odd/unaligned sizes
    )
    def test_bytes_parity(self, dtype, n, threads):
        arr = _bf16(n, seed=1) if dtype == "bfloat16" else _fp32(n, seed=1)
        raw = np.ascontiguousarray(arr).view(np.uint8).tobytes()
        host = zipnn.compress_bytes(raw, dtype, threads=threads, backend="host")
        dev = zipnn.compress_bytes(raw, dtype, threads=threads, backend="device")
        assert host == dev
        assert zipnn.decompress_bytes(dev, threads=threads) == raw

    def test_unaligned_tail_parity(self):
        raw = np.ascontiguousarray(_bf16(70_000, seed=2)).view(np.uint8).tobytes()
        raw = raw + b"\x05"                          # odd byte count → TAIL
        host = zipnn.compress_bytes(raw, "bfloat16")
        dev = zipnn.compress_bytes(raw, "bfloat16", backend="device")
        assert host == dev
        assert zipnn.decompress_bytes(dev) == raw

    @pytest.mark.parametrize("threads", [1, 4])
    def test_delta_parity(self, threads):
        base = _bf16(200_000, seed=3)
        new = np.asarray(base).copy()
        idx = np.random.default_rng(4).integers(0, new.size, new.size // 50)
        new[idx] = (np.asarray(new[idx], np.float32) * 1.01).astype(
            ml_dtypes.bfloat16
        )
        host = zipnn.delta_compress(new, base, threads=threads, backend="host")
        dev = zipnn.delta_compress(new, base, threads=threads, backend="device")
        assert host.blob == dev.blob
        back = zipnn.delta_decompress(dev, base, threads=threads)
        np.testing.assert_array_equal(
            back.view(np.uint8), np.ascontiguousarray(new).view(np.uint8)
        )

    def test_delta_fp32_all_zero_delta(self):
        base = _fp32(100_000, seed=5)
        host = zipnn.delta_compress(base, base, backend="host")
        dev = zipnn.delta_compress(base, base, backend="device")
        assert host.blob == dev.blob
        assert host.nbytes < base.nbytes * 0.01      # ZERO planes

    def test_jax_array_leaf(self):
        arr = jnp.asarray(_bf16(100_000, seed=6))
        host = zipnn.compress_array(np.asarray(arr), backend="host")
        dev = zipnn.compress_array(arr, backend="device")
        assert host.blob == dev.blob
        back = zipnn.decompress_array(dev)
        np.testing.assert_array_equal(
            back.view(np.uint8), np.asarray(arr).view(np.uint8)
        )

    def test_pytree_batched_parity(self):
        tree = {
            "wte": _bf16(70_000, seed=7).reshape(700, 100),
            "tiny": [_bf16(33, seed=8), _bf16(1, seed=9)],
            "zeros": np.zeros(40_000, ml_dtypes.bfloat16),
            "f32": _fp32(20_000, seed=10),
            "int": np.arange(100, dtype=np.int32),   # non-rotated → host
            "step": np.asarray(7, dtype=np.int32),
        }
        host = zipnn.compress_pytree(tree, backend="host")
        dev = zipnn.compress_pytree(tree, backend="device")
        assert [c.blob for c in host["leaves"]] == [c.blob for c in dev["leaves"]]
        back = zipnn.decompress_pytree(dev)
        import jax

        for a, b in zip(
            jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_unsupported_chunk_size_falls_back_to_host(self):
        # chunk too small for whole histogram blocks → silent host fallback
        cfg = zipnn.ZipNNConfig(chunk_param_bytes=1 << 12)
        arr = _bf16(50_000, seed=11)
        host = zipnn.compress_array(arr, cfg, backend="host")
        dev = zipnn.compress_array(arr, cfg, backend="device")
        assert host.blob == dev.blob

    def test_auto_is_host_for_host_data(self):
        resolved = device_plane.resolve(
            "auto",
            bitlayout.layout_for("bfloat16"),
            zipnn.DEFAULT.plane_params(2),
            leaf=_bf16(10, seed=12),
        )
        assert resolved == "host"


class TestProbeInjection:
    """plan() consumes supplied ProbeStats for every Method, without any
    histogramming of its own."""

    def _parity(self, plane, params):
        pc_probe = codec.PlaneCodec(params)
        pc_host = codec.PlaneCodec(params)
        probe = _probe_for(plane, params.chunk_bytes)
        m_probe = pc_probe.plan(plane, probe=probe)
        m_host = pc_host.plan(plane)
        assert m_probe == m_host
        e1, p1 = pc_probe.compress(plane, probe=probe)
        e2, p2 = pc_host.compress(plane)
        assert p1 == p2 and e1 == e2
        return m_probe

    def test_store_plane(self):
        plane = np.random.default_rng(0).integers(0, 256, 1 << 16).astype(np.uint8)
        m = self._parity(plane, codec.CodecParams(chunk_bytes=1 << 14))
        assert set(m) == {codec.Method.STORE}

    def test_zero_plane(self):
        plane = np.zeros(1 << 16, np.uint8)
        m = self._parity(plane, codec.CodecParams(chunk_bytes=1 << 14))
        assert set(m) == {codec.Method.ZERO}

    def test_huff_plane(self):
        rng = np.random.default_rng(1)
        plane = rng.choice(12, 1 << 16).astype(np.uint8) + 1
        m = self._parity(plane, codec.CodecParams(chunk_bytes=1 << 14))
        assert codec.Method.HUFF in m

    def test_hufflib_plane(self):
        rng = np.random.default_rng(2)
        plane = rng.choice(12, 1 << 16).astype(np.uint8) + 1
        m = self._parity(
            plane, codec.CodecParams(chunk_bytes=1 << 14, backend="hufflib")
        )
        assert codec.Method.HUFFLIB in m

    def test_zlib_delta_plane(self):
        rng = np.random.default_rng(3)
        plane = np.zeros(1 << 16, np.uint8)
        plane[:: 97] = rng.integers(1, 255, plane[::97].size)  # >90 % zeros
        m = self._parity(plane, codec.CodecParams(chunk_bytes=1 << 14, delta_mode=True))
        assert codec.Method.ZLIB in m

    def test_zlib_zero_run_delta_plane(self):
        rng = np.random.default_rng(4)
        plane = rng.integers(1, 255, 1 << 16).astype(np.uint8)
        plane[1000:3000] = 0                       # long run, zeros < 90 %
        m = self._parity(plane, codec.CodecParams(chunk_bytes=1 << 14, delta_mode=True))
        assert codec.Method.ZLIB in m

    def test_plan_never_histograms_with_probe(self, monkeypatch):
        """Acceptance criterion: plan() computes no hist256/bincount when
        probe stats are supplied by the device path."""
        rng = np.random.default_rng(5)
        plane = rng.choice(12, 1 << 16).astype(np.uint8)
        params = codec.CodecParams(chunk_bytes=1 << 14)
        probe = _probe_for(plane, params.chunk_bytes)

        def boom(*a, **k):
            raise AssertionError("plan() must not histogram with probe stats")

        monkeypatch.setattr(codec, "hist256", boom)
        monkeypatch.setattr(codec.np, "bincount", boom)
        pc = codec.PlaneCodec(params)
        methods = pc.plan(plane, probe=probe)
        assert len(methods) == 4

    def test_probe_chunk_count_mismatch_raises(self):
        plane = np.zeros(1 << 16, np.uint8)
        params = codec.CodecParams(chunk_bytes=1 << 14)
        probe = _probe_for(plane[: 1 << 15], params.chunk_bytes)
        with pytest.raises(ValueError, match="chunk histograms"):
            codec.PlaneCodec(params).plan(plane, probe=probe)


class TestDevicePlaneModule:
    def test_batched_matches_single(self):
        layout = bitlayout.layout_for("bfloat16")
        params = zipnn.DEFAULT.plane_params(2)
        leaves = [_bf16(40_000, seed=20), _bf16(5, seed=21), _bf16(131_072, seed=22)]
        batched = device_plane.produce_planes_batched(leaves, layout, params)
        for leaf, (planes_b, probes_b) in zip(leaves, batched):
            planes_s, probes_s = device_plane.produce_planes(leaf, layout, params)
            for pb, ps in zip(planes_b, planes_s):
                np.testing.assert_array_equal(pb, ps)
            for qb, qs in zip(probes_b, probes_s):
                np.testing.assert_array_equal(qb.chunk_hists, qs.chunk_hists)
                np.testing.assert_array_equal(qb.table_hist, qs.table_hist)

    def test_probe_hists_match_bincount(self):
        layout = bitlayout.layout_for("float32")
        params = zipnn.DEFAULT.plane_params(4)
        leaf = _fp32(100_000, seed=23)
        planes, probes = device_plane.produce_planes(leaf, layout, params)
        for plane, probe in zip(planes, probes):
            expected = _probe_for(plane, params.chunk_bytes)
            np.testing.assert_array_equal(probe.chunk_hists, expected.chunk_hists)
            np.testing.assert_array_equal(probe.table_hist, expected.table_hist)

    def test_unsupported_layouts(self):
        params = zipnn.DEFAULT.plane_params(4)
        assert not device_plane.supports(bitlayout.layout_for("int32"), params)
        assert not device_plane.supports(bitlayout.layout_for("uint8"), params)
        assert device_plane.supports(bitlayout.layout_for("float32"), params)
        assert device_plane.supports(bitlayout.layout_for("bfloat16"), zipnn.DEFAULT.plane_params(2))

    def test_resolve_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown plane backend"):
            device_plane.resolve(
                "gpu", bitlayout.layout_for("float32"), zipnn.DEFAULT.plane_params(4)
            )


class TestPipelinedStreaming:
    def test_pipelined_file_identical_to_serial(self, tmp_path):
        data = np.ascontiguousarray(_bf16(600_000, seed=30)).view(np.uint8).tobytes()
        src = tmp_path / "in.bin"
        src.write_bytes(data)
        s_path, p_path = tmp_path / "serial.znns", tmp_path / "piped.znns"
        engine.compress_file(str(src), str(s_path), "bfloat16", window_bytes=1 << 18)
        engine.compress_file(
            str(src), str(p_path), "bfloat16", window_bytes=1 << 18, threads=4
        )
        assert s_path.read_bytes() == p_path.read_bytes()
        back = tmp_path / "back.bin"
        assert engine.decompress_file(str(p_path), str(back), threads=4) == len(data)
        assert back.read_bytes() == data

    def test_pipelined_writer_incremental(self):
        data = np.ascontiguousarray(_bf16(300_000, seed=31)).view(np.uint8).tobytes()
        serial, piped = io.BytesIO(), io.BytesIO()
        for sink, threads in ((serial, 0), (piped, 4)):
            with engine.CompressWriter(
                sink, "bfloat16", window_bytes=1 << 17, threads=threads
            ) as w:
                for i in range(0, len(data), 9973):
                    w.write(data[i : i + 9973])
        assert serial.getvalue() == piped.getvalue()

    def test_pipelined_abort_discards_pending(self):
        data = np.ascontiguousarray(_bf16(200_000, seed=32)).view(np.uint8).tobytes()
        sink = io.BytesIO()
        with pytest.raises(RuntimeError):
            with engine.CompressWriter(
                sink, "bfloat16", window_bytes=1 << 17, threads=4
            ) as w:
                w.write(data)
                raise RuntimeError("boom")
        with pytest.raises(IOError):
            engine.DecompressReader(io.BytesIO(sink.getvalue())).read()

    def test_device_backend_through_writer(self, tmp_path):
        data = np.ascontiguousarray(_bf16(300_000, seed=33)).view(np.uint8).tobytes()
        src = tmp_path / "in.bin"
        src.write_bytes(data)
        h, d = tmp_path / "h.znns", tmp_path / "d.znns"
        engine.compress_file(str(src), str(h), "bfloat16", window_bytes=1 << 18)
        engine.compress_file(
            str(src), str(d), "bfloat16", window_bytes=1 << 18, backend="device"
        )
        assert h.read_bytes() == d.read_bytes()

    def test_frame_records(self, tmp_path):
        data = np.ascontiguousarray(_bf16(300_000, seed=34)).view(np.uint8).tobytes()
        src, dst = tmp_path / "in.bin", tmp_path / "out.znns"
        src.write_bytes(data)
        engine.compress_file(str(src), str(dst), "bfloat16", window_bytes=1 << 18)
        recs = list(engine.frame_records(str(dst)))
        assert sum(r[0] for r in recs) == len(data)
        assert all(len(r[2]) == r[1] for r in recs)
        assert len(recs) >= 2


class TestEngineAwareSubsystems:
    def test_grad_sync_knobs_lossless_and_identical(self):
        import jax

        from repro.distributed.grad_sync import GradSync

        tree = {
            "w": _bf16(60_000, seed=40).reshape(300, 200),
            "b": np.zeros(256, np.float32),
        }
        plain, _ = GradSync().pack(tree)
        gs = GradSync(threads=4, backend="device")
        manifest, stats = gs.pack(tree)
        assert [c.blob for c in manifest["leaves"]] == [
            c.blob for c in plain["leaves"]
        ]
        back = gs.unpack(manifest)
        for a, b in zip(
            jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(back)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert stats.comp_bytes < stats.raw_bytes

    def test_checkpoint_manager_backend_parity(self, tmp_path):
        from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

        state = {
            "w": _bf16(50_000, seed=41),
            "opt": {"m": _fp32(20_000, seed=42)},
        }
        mgrs = {}
        for name, backend in (("host", "host"), ("dev", "device")):
            cfg = CheckpointConfig(
                directory=str(tmp_path / name), backend=backend, async_save=False
            )
            m = CheckpointManager(cfg)
            m.save(1, state, blocking=True)
            mgrs[name] = m
        h = (tmp_path / "host" / "step_1" / "data.bin").read_bytes()
        d = (tmp_path / "dev" / "step_1" / "data.bin").read_bytes()
        assert h == d
        step, back = mgrs["dev"].restore()
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(back["w"]).view(np.uint8),
            np.ascontiguousarray(state["w"]).view(np.uint8),
        )

    def test_hub_overlapped_report(self, tmp_path):
        from repro.checkpoint import hub

        data = np.ascontiguousarray(_bf16(400_000, seed=43)).view(np.uint8).tobytes()
        src = tmp_path / "model.bin"
        src.write_bytes(data)
        rep = hub.simulate_file_transfer(
            str(src), "bfloat16", "first_download_home",
            window_bytes=1 << 18, threads=2,
        )
        assert rep.total_comp_overlap_s > 0
        assert rep.codec_overlap_s >= 0
        # the pipeline always pays full wire time; it can only hide codec
        assert rep.total_comp_overlap_s >= rep.wire_comp_s - 1e-12
        assert rep.overlapped_speedup > 0
        seq_rep = hub.simulate_transfer(
            data, "bfloat16", "first_download_home", backend="device"
        )
        assert seq_rep.total_comp_overlap_s == 0.0
        assert seq_rep.overlapped_speedup == seq_rep.speedup
