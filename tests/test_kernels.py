"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles vs
the host codec.  Shape sweeps per kernel; exact equality everywhere (these
are bit-manipulation kernels — no tolerance)."""

import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.core import bitlayout, huffman
from repro.kernels import ops, ref

SIZES = [1, 100, 128, 4096, 65_536, 200_000]


def _rand_u16(n, seed=0):
    return np.random.default_rng(seed).integers(0, 1 << 16, n).astype(np.uint16)


def _rand_u32(n, seed=0):
    return np.random.default_rng(seed).integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32)


def _weights_bf16(n, seed=0):
    w = (np.random.default_rng(seed).standard_normal(n) * 0.02).astype(np.float32)
    return np.ascontiguousarray(w.astype(ml_dtypes.bfloat16)).view(np.uint16)


class TestBytegroup:
    @pytest.mark.parametrize("n", SIZES)
    def test_bf16_kernel_vs_oracle(self, n):
        x = _rand_u16(n, n)
        ke, kf = ops.bytegroup_bf16(jnp.asarray(x))
        oe, of = ref.bytegroup_bf16(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(ke), np.asarray(oe))
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(of))

    @pytest.mark.parametrize("n", SIZES)
    def test_bf16_kernel_vs_host_codec(self, n):
        x = _weights_bf16(n, n)
        ke, kf = ops.bytegroup_bf16(jnp.asarray(x))
        layout = bitlayout.layout_for("bfloat16")
        he, hf = bitlayout.to_planes(x.view(np.uint8), layout)
        np.testing.assert_array_equal(np.asarray(ke), he)
        np.testing.assert_array_equal(np.asarray(kf), hf)

    @pytest.mark.parametrize("n", SIZES)
    def test_bf16_roundtrip(self, n):
        x = _rand_u16(n, n + 1)
        e, f = ops.bytegroup_bf16(jnp.asarray(x))
        back = ops.ungroup_bf16(e, f)
        np.testing.assert_array_equal(np.asarray(back), x)

    @pytest.mark.parametrize("n", SIZES)
    def test_fp32_kernel_vs_oracle_and_roundtrip(self, n):
        x = _rand_u32(n, n)
        kp = ops.bytegroup_fp32(jnp.asarray(x))
        op = ref.bytegroup_fp32(jnp.asarray(x))
        for k, o in zip(kp, op):
            np.testing.assert_array_equal(np.asarray(k), np.asarray(o))
        back = ops.ungroup_fp32(*kp)
        np.testing.assert_array_equal(np.asarray(back), x)

    def test_fp32_plane0_is_exponent(self):
        w = (np.random.default_rng(3).standard_normal(10_000) * 0.05).astype(np.float32)
        planes = ops.bytegroup_fp32(jnp.asarray(w.view(np.uint32)))
        np.testing.assert_array_equal(
            np.asarray(planes[0]).astype(np.int32), bitlayout.exponent_view(w)
        )


class TestHistogram:
    @pytest.mark.parametrize("n", SIZES)
    def test_vs_oracle_and_numpy(self, n):
        x = np.random.default_rng(n).integers(0, 256, n).astype(np.uint8)
        kh = np.asarray(ops.byte_histogram(jnp.asarray(x)))
        np.testing.assert_array_equal(kh, np.bincount(x, minlength=256))
        oh = np.asarray(ref.histogram(jnp.asarray(x)))
        np.testing.assert_array_equal(oh, np.bincount(x, minlength=256))

    def test_skewed_exponent_plane(self):
        x = _weights_bf16(50_000, 9)
        exp_plane, _ = ops.bytegroup_bf16(jnp.asarray(x))
        kh = np.asarray(ops.byte_histogram(exp_plane))
        np.testing.assert_array_equal(
            kh, np.bincount(np.asarray(exp_plane), minlength=256)
        )
        # paper Fig. 2: ~top-12 exponents hold ≈ 99.9 % of the mass
        assert np.sort(kh)[-12:].sum() / kh.sum() > 0.99


class TestXorDelta:
    @pytest.mark.parametrize("n", SIZES)
    def test_vs_oracle(self, n):
        a, b = _rand_u32(n, n), _rand_u32(n, n + 7)
        kd, kc = ops.xor_delta_u32(jnp.asarray(a), jnp.asarray(b))
        od, oc = ref.xor_delta(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(kd), np.asarray(od))
        assert int(kc) == int(oc)
        np.testing.assert_array_equal(np.asarray(kd), a ^ b)

    def test_changed_byte_count(self):
        a = np.zeros(1000, dtype=np.uint32)
        b = a.copy()
        b[:10] = 0x000000FF          # 10 words, 1 byte each
        b[10] = 0xFFFFFFFF           # 1 word, 4 bytes
        _, cnt = ops.xor_delta_u32(jnp.asarray(a), jnp.asarray(b))
        assert int(cnt) == 14

    def test_self_delta_zero(self):
        a = _rand_u32(5000, 1)
        d, cnt = ops.xor_delta_u32(jnp.asarray(a), jnp.asarray(a))
        assert int(cnt) == 0 and not np.asarray(d).any()


class TestBitpack:
    def _table(self, data):
        hist = np.bincount(data, minlength=256)
        lens = huffman.code_lengths(hist)
        return lens, huffman.canonical_codes(lens)

    @pytest.mark.parametrize("n", [64, 8192, 16384, 20_000])
    def test_kernel_matches_host_encoder(self, n):
        rng = np.random.default_rng(n)
        p = np.r_[np.full(12, 0.08), np.full(244, 0.04 / 244)]
        data = rng.choice(256, p=p / p.sum(), size=n).astype(np.uint8)
        lens, codes = self._table(data)
        blobs = ops.huffman_encode_chunks(data, lens, codes, chunk_syms=8192)
        host = huffman.encode_chunks(
            data,
            np.asarray(
                [8192] * (n // 8192) + ([n % 8192] if n % 8192 else [])
            ),
            lens,
            codes,
        )
        assert len(blobs) == len(host)
        for kb, hb in zip(blobs, host):
            assert kb == hb

    def test_kernel_vs_oracle(self):
        rng = np.random.default_rng(5)
        data = rng.integers(0, 16, 8192).astype(np.uint8)
        lens, codes = self._table(data)
        words, nbits = ref.bitpack_encode(
            jnp.asarray(data), jnp.asarray(lens, jnp.int32), jnp.asarray(codes, jnp.int32)
        )
        payload = np.asarray(words).astype(">u4").tobytes()[: -(-int(nbits) // 8)]
        assert payload == huffman.encode(data, lens, codes)

    def test_decodable_by_host(self):
        rng = np.random.default_rng(6)
        data = rng.integers(0, 8, 16384).astype(np.uint8)
        lens, codes = self._table(data)
        blobs = ops.huffman_encode_chunks(data, lens, codes, chunk_syms=8192)
        decoded = huffman.decode_many(blobs, [8192, 8192], lens)
        np.testing.assert_array_equal(np.concatenate(decoded), data)

    @pytest.mark.parametrize("nsyms", [2, 5, 256])
    def test_alphabet_sweep(self, nsyms):
        rng = np.random.default_rng(nsyms)
        data = rng.integers(0, nsyms, 8192).astype(np.uint8)
        lens, codes = self._table(data)
        blobs = ops.huffman_encode_chunks(data, lens, codes, chunk_syms=8192)
        decoded = huffman.decode_many(blobs, [8192], lens)
        np.testing.assert_array_equal(decoded[0], data)


class TestChunkHistogram:
    @pytest.mark.parametrize("chunks", [1, 2, 5])
    def test_vs_bincount_and_oracle(self, chunks):
        from repro.kernels import histogram as hist_k

        chunk_elems = hist_k.HIST_ROWS * 128 * 2          # 2 blocks per chunk
        n = chunks * chunk_elems
        x = np.random.default_rng(chunks).integers(0, 256, n).astype(np.uint8)
        kh = np.asarray(
            hist_k.chunk_histogram_2d(
                jnp.asarray(x).reshape(-1, 128),
                chunk_rows=chunk_elems // 128,
                interpret=True,
            )
        )
        assert kh.shape == (chunks, 256)
        for c in range(chunks):
            np.testing.assert_array_equal(
                kh[c],
                np.bincount(x[c * chunk_elems : (c + 1) * chunk_elems], minlength=256),
            )
        oh = np.asarray(ref.chunk_histogram(jnp.asarray(x), chunk_elems))
        np.testing.assert_array_equal(kh, oh)


class TestXorElems:
    @pytest.mark.parametrize("dtype", [np.uint16, np.uint32])
    def test_vs_numpy(self, dtype):
        from repro.kernels import xor_delta as xd

        n = xd.XOR_ROWS * 128
        rng = np.random.default_rng(3)
        a = rng.integers(0, np.iinfo(dtype).max, n, dtype=np.uint64).astype(dtype)
        b = rng.integers(0, np.iinfo(dtype).max, n, dtype=np.uint64).astype(dtype)
        d = xd.xor_elems_2d(
            jnp.asarray(a).reshape(-1, 128), jnp.asarray(b).reshape(-1, 128),
            interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(d).reshape(-1), a ^ b)


class TestFusedPlaneProducer:
    def test_matches_host_planes_and_bincount(self):
        from repro.kernels import fused_plane

        n = fused_plane.ALIGN_ELEMS_U16 * 2
        chunk_elems = n // 4
        x = _weights_bf16(n, 11)
        planes, hists = fused_plane.plane_producer(
            jnp.asarray(x).reshape(-1, 128),
            itemsize=2, chunk_elems=chunk_elems, interpret=True,
        )
        layout = bitlayout.layout_for("bfloat16")
        host = bitlayout.to_planes(x.view(np.uint8), layout)
        for k, h in zip(planes, host):
            np.testing.assert_array_equal(np.asarray(k).reshape(-1), h)
        for p, h in enumerate(host):
            for c in range(4):
                np.testing.assert_array_equal(
                    np.asarray(hists)[c, p],
                    np.bincount(h[c * chunk_elems : (c + 1) * chunk_elems], minlength=256),
                )

    def test_delta_fusion_commutes_with_host_xor(self):
        from repro.kernels import fused_plane

        n = fused_plane.ALIGN_ELEMS_U32
        rng = np.random.default_rng(12)
        a = rng.integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32)
        b = rng.integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32)
        planes, _ = fused_plane.plane_producer(
            jnp.asarray(a).reshape(-1, 128), jnp.asarray(b).reshape(-1, 128),
            itemsize=4, chunk_elems=n, interpret=True,
        )
        layout = bitlayout.layout_for("float32")
        host = bitlayout.to_planes((a ^ b).view(np.uint8), layout)
        for k, h in zip(planes, host):
            np.testing.assert_array_equal(np.asarray(k).reshape(-1), h)
