"""Compressed-resident serving: the prefetch/decode ring must be invisible.

The contract under test: ``make_compressed_serve_step`` over a
``CompressedParamStore`` produces **bit-identical** logits and decode state
to the uncompressed ``model.decode_step`` — across model families, ring
depths, and the ``backend`` × ``entropy_backend`` knobs — while never
holding more than ``ring`` decoded layers (``store.peak_resident``).

Plus regression tests for the decode-surface bugfixes that shipped with
the ring: ``delta_decompress`` base validation, assert-free integrity
guards, ``greedy_generate`` degenerate shapes, and the
``decompress_pytree(device_resident=True)`` path the store builds on.
"""

import ast
import dataclasses
import inspect

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import zipnn
from repro.models import build_model
from repro.serve import CompressedParamStore, make_compressed_serve_step
from repro.serve.step import greedy_generate


def _tiny(name: str):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _lockstep(cfg, model, params, cstep, steps=3, seed=0):
    """Drive jit(decode_step) and the ring step on the same tokens; return
    True iff logits AND every state leaf match bit for bit at every step."""
    step = jax.jit(model.decode_step)
    B = 2
    sa = model.init_decode_state(B, steps, start_pos=0)
    sb = model.init_decode_state(B, steps, start_pos=0)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        la, sa = step(params, sa, toks)
        lb, sb = cstep(sb, toks)
        if np.asarray(la).tobytes() != np.asarray(lb).tobytes():
            return False
        for k in sa:
            if np.asarray(sa[k]).tobytes() != np.asarray(sb[k]).tobytes():
                return False
    return True


class TestCompressedServe:
    @pytest.mark.parametrize(
        "arch",
        [
            "repro_gpt_100m",      # dense
            "olmoe_1b_7b",         # moe (first_k_dense == 0)
            "deepseek_v2_236b",    # moe with dense prefix + MLA caches
            "mamba2_130m",         # ssm
        ],
    )
    def test_ring_bit_identical_per_family(self, arch):
        cfg, model, params = _tiny(arch)
        store = CompressedParamStore.from_params(params)
        cstep = make_compressed_serve_step(model, store)
        assert _lockstep(cfg, model, params, cstep)
        assert 1 <= store.peak_resident <= 2      # the double-buffer claim
        assert store.resident_count == 0          # every slot released
        assert store.comp_bytes < store.raw_bytes # actually compressed

    @pytest.mark.parametrize("ring,prefetch", [(1, False), (2, True), (3, True)])
    def test_ring_depths(self, ring, prefetch):
        cfg, model, params = _tiny("repro_gpt_100m")
        store = CompressedParamStore.from_params(params)
        cstep = make_compressed_serve_step(
            model, store, ring=ring, prefetch=prefetch
        )
        assert _lockstep(cfg, model, params, cstep, steps=2)
        assert store.peak_resident <= ring

    def test_knob_sweep_bit_identical(self):
        """Ring decode across backend × entropy_backend (host fallback and
        the device Huffman decoder) — logits identical on every combo."""
        cfg, model, params = _tiny("repro_gpt_100m")
        combos = [
            dict(backend=None, entropy_backend=None),        # host default
            dict(backend="host", entropy_backend="host", threads=2),
            dict(backend="device", entropy_backend="device"),
        ]
        huff = zipnn.ZipNNConfig(backend="huffman")
        for knobs in combos:
            store = CompressedParamStore.from_params(params, huff, **knobs)
            cstep = make_compressed_serve_step(model, store)
            assert _lockstep(cfg, model, params, cstep, steps=1), knobs
            assert store.peak_resident <= 2

    def test_store_payloads_knob_independent(self):
        """Two stores from the same params hold byte-identical payloads
        regardless of knobs — the determinism contract applied at rest."""
        _, _, params = _tiny("repro_gpt_100m")
        a = CompressedParamStore.from_params(params)
        b = CompressedParamStore.from_params(params, threads=2)
        for key in a.stack_keys:
            for i in range(a.n_layers(key)):
                la = [c.blob for c in a._stacks[key][i]["leaves"]]
                lb = [c.blob for c in b._stacks[key][i]["leaves"]]
                assert la == lb

    def test_hybrid_rejected(self):
        cfg = get_config("zamba2_7b").reduced()
        model = build_model(cfg)
        with pytest.raises(NotImplementedError):
            make_compressed_serve_step(model, CompressedParamStore())

    def test_layer_count_mismatch_rejected(self):
        cfg, model, params = _tiny("repro_gpt_100m")
        store = CompressedParamStore()              # empty: 0 layers
        store.static = dict(params)
        with pytest.raises(ValueError, match="layers"):
            make_compressed_serve_step(model, store)

    def test_footprint_accounting(self):
        _, _, params = _tiny("repro_gpt_100m")
        store = CompressedParamStore.from_params(params)
        assert 0 < store.ratio_pct < 100
        assert store.max_layer_raw_bytes > 0
        # footprint = payloads + static + ring slots, monotone in ring
        assert store.footprint_bytes(2) > store.footprint_bytes(1)
        assert (
            store.footprint_bytes(2)
            == store.comp_bytes + store.static_bytes
            + 2 * store.max_layer_raw_bytes
        )


class TestDecompressPytreeDeviceResident:
    def _manifest(self):
        rng = np.random.default_rng(0)
        tree = {
            "a": rng.standard_normal((64, 32)).astype(ml_dtypes.bfloat16),
            "b": rng.standard_normal((128,)).astype(np.float32),
        }
        return tree, zipnn.compress_pytree(tree, zipnn.ZipNNConfig(backend="huffman"))

    def test_device_resident_tree(self):
        tree, manifest = self._manifest()
        out = zipnn.decompress_pytree(
            manifest, zipnn.ZipNNConfig(backend="huffman"),
            backend="device", entropy_backend="device", device_resident=True,
        )
        for k, ref in tree.items():
            leaf = out[k]
            assert not isinstance(leaf, np.ndarray)   # stayed a jax.Array
            assert np.asarray(leaf).tobytes() == ref.tobytes()

    def test_host_resolved_leaves_fall_back_to_numpy(self):
        tree, manifest = self._manifest()
        out = zipnn.decompress_pytree(manifest, device_resident=True)
        for k, ref in tree.items():
            assert isinstance(out[k], np.ndarray)
            assert out[k].tobytes() == ref.tobytes()

    def test_manager_batched_full_restore(self, tmp_path):
        from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

        rng = np.random.default_rng(1)
        tree = {
            "w": rng.standard_normal((32, 16)).astype(ml_dtypes.bfloat16),
            "b": rng.standard_normal((16,)).astype(np.float32),
        }
        mgr = CheckpointManager(
            CheckpointConfig(directory=str(tmp_path), async_save=False)
        )
        mgr.save(0, tree)
        s, back = mgr.restore()
        assert s == 0
        for k in tree:
            assert np.asarray(back[k]).tobytes() == tree[k].tobytes()


class TestDeltaDecompressValidation:
    def test_mismatched_base_raises_cleanly(self):
        rng = np.random.default_rng(0)
        base = rng.standard_normal((8, 8)).astype(np.float32)
        new = base.copy()
        new[0, 0] += 1.0
        ct = zipnn.delta_compress(new, base)
        with pytest.raises(ValueError, match="delta requires matching"):
            zipnn.delta_decompress(ct, base[:4])               # wrong shape
        with pytest.raises(ValueError, match="delta requires matching"):
            zipnn.delta_decompress(ct, base.astype(np.float16))  # wrong dtype
        with pytest.raises(ValueError, match="delta requires matching"):
            zipnn.delta_decompress(ct, base[:4], backend="device")
        # the matching base still round-trips
        out = zipnn.delta_decompress(ct, base)
        assert out.tobytes() == new.tobytes()


class TestIntegrityGuardsAreRealExceptions:
    MODULES = (
        "repro.checkpoint.hub",
        "repro.distributed.grad_sync",
        "repro.core.container",
        "repro.core.codec",
        "repro.core.zipnn",
        "repro.checkpoint.manager",
    )

    def test_no_bare_asserts_on_integrity_surface(self):
        """Integrity checks must survive ``python -O``: no ``assert``
        statements anywhere in the audited decode/transfer modules."""
        import importlib

        for name in self.MODULES:
            mod = importlib.import_module(name)
            tree = ast.parse(inspect.getsource(mod))
            offenders = [
                n.lineno for n in ast.walk(tree) if isinstance(n, ast.Assert)
            ]
            assert not offenders, f"{name} has assert at lines {offenders}"

    def test_hub_lossless_guard_raises(self, monkeypatch):
        from repro.checkpoint import hub

        monkeypatch.setattr(
            hub.zipnn, "decompress_bytes", lambda *a, **k: b"corrupt"
        )
        with pytest.raises(IOError, match="lossless"):
            hub.simulate_transfer(
                np.zeros(64, np.float32).tobytes(), "float32",
                "cached_download_cloud",
            )

    def test_codec_table_blob_guard(self):
        from repro.core import codec

        pc = codec.PlaneCodec(codec.CodecParams(chunk_bytes=256))
        with pytest.raises(RuntimeError, match="build_table"):
            pc.table_blob()


class TestGreedyGenerateDegenerate:
    @pytest.fixture(scope="class")
    def dense(self):
        return _tiny("repro_gpt_100m")

    def test_empty_prompt_raises(self, dense):
        _, model, params = dense
        with pytest.raises(ValueError, match="at least one token"):
            greedy_generate(model, params, jnp.zeros((2, 0), jnp.int32), 4)

    def test_negative_steps_raises(self, dense):
        _, model, params = dense
        with pytest.raises(ValueError, match="steps"):
            greedy_generate(model, params, jnp.zeros((1, 2), jnp.int32), -1)

    def test_bad_rank_raises(self, dense):
        _, model, params = dense
        with pytest.raises(ValueError, match="\\(B, S\\)"):
            greedy_generate(model, params, jnp.zeros((4,), jnp.int32), 1)

    def test_zero_steps_returns_empty(self, dense):
        cfg, model, params = dense
        prompt = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 3)),
            jnp.int32,
        )
        out, state = greedy_generate(model, params, prompt, 0)
        assert out.shape == (2, 0) and out.dtype == jnp.int32
        assert int(state["pos"]) == 3          # prompt fed through the cache

    def test_single_token_prompt(self, dense):
        cfg, model, params = dense
        out, _ = greedy_generate(
            model, params, jnp.ones((1, 1), jnp.int32), 2
        )
        assert out.shape == (1, 2)
        assert int(jnp.max(out)) < cfg.vocab_size
