"""Model-zoo tests: per-arch smoke (reduced configs), flash-attention vs
naive reference, decode-vs-forward consistency, SSD vs naive recurrence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.models.attention import flash_attention
from repro.models.ssm import ssd_scan

ARCHS = list_archs() + ["repro_gpt_100m"]


def _batch_for(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.family == "vlm":
        s_img = max(S // 4, 8)
        s_txt = S - s_img
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_txt))),
            "patches": jnp.asarray(
                rng.standard_normal((B, s_img, cfg.frontend_dim)), jnp.float32
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_txt))),
            "pos_thw": jnp.asarray(
                np.tile(np.arange(S)[None, :, None], (B, 1, 3)), jnp.int32
            ),
        }
    if cfg.family == "audio":
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, S, cfg.frontend_dim)), jnp.float32
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward/loss + grad step on CPU, no NaNs."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(cfg, 2, 64)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    # reasonable CE at init: ~ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", [a for a in ARCHS if get_config(a).has_decode])
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    state = model.init_decode_state(2, 32, start_pos=0)
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, state2 = jax.jit(model.decode_step)(params, state, toks)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert int(state2["pos"]) == 1


def test_encoder_has_no_decode():
    cfg = get_config("hubert_xlarge").reduced()
    with pytest.raises(ValueError):
        build_model(cfg).init_decode_state(1, 8)


class TestFlashAttention:
    @staticmethod
    def _ref(q, k, v, causal, window):
        B, S, H, hd = q.shape
        G = k.shape[2]
        rep = H // G
        out = np.zeros((B, S, H, v.shape[-1]), np.float32)
        qf = np.asarray(q, np.float32) * hd ** -0.5
        for h in range(H):
            g = h // rep
            s = qf[:, :, h] @ np.asarray(k[:, :, g], np.float32).transpose(0, 2, 1)
            mask = np.ones((S, S), bool)
            if causal:
                mask &= np.tril(np.ones((S, S), bool))
            if window:
                mask &= ~np.tril(np.ones((S, S), bool), -window)
            s = np.where(mask[None], s, -1e30)
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[:, :, h] = p @ np.asarray(v[:, :, g], np.float32)
        return out

    @pytest.mark.parametrize(
        "B,S,H,G,hd,hdv,causal,window,qb,kb",
        [
            (2, 64, 4, 2, 16, 16, True, 0, 16, 32),
            (1, 100, 4, 4, 8, 8, True, 24, 32, 16),     # ragged + SWA
            (2, 128, 6, 2, 12, 20, True, 0, 64, 64),    # MLA-style hd_v ≠ hd
            (1, 96, 4, 1, 16, 16, False, 0, 32, 32),    # encoder + MQA
            (1, 33, 2, 2, 8, 8, True, 0, 64, 64),       # S < block
        ],
    )
    def test_vs_reference(self, B, S, H, G, hd, hdv, causal, window, qb, kb):
        rng = np.random.default_rng(S + H)
        q = rng.standard_normal((B, S, H, hd)).astype(np.float32)
        k = rng.standard_normal((B, S, G, hd)).astype(np.float32)
        v = rng.standard_normal((B, S, G, hdv)).astype(np.float32)
        out = flash_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            causal=causal, window=window, q_block=qb, kv_block=kb,
        )
        np.testing.assert_allclose(
            np.asarray(out, np.float32), self._ref(q, k, v, causal, window),
            atol=2e-3, rtol=2e-3,
        )


class TestSSD:
    @staticmethod
    def _ref_recurrence(xh, dt, A, Bc, Cc, D):
        """Token-by-token reference: state = state·exp(dtA) + dt·x⊗B."""
        B, S, H, P = xh.shape
        N = Bc.shape[-1]
        y = np.zeros((B, S, H, P), np.float32)
        state = np.zeros((B, H, P, N), np.float32)
        for t in range(S):
            dA = np.exp(dt[:, t] * A[None, :])                    # (B,H)
            dBx = np.einsum("bh,bn,bhp->bhpn", dt[:, t], Bc[:, t], xh[:, t])
            state = state * dA[:, :, None, None] + dBx
            y[:, t] = np.einsum("bhpn,bn->bhp", state, Cc[:, t])
        return y + xh * D[None, None, :, None]

    @pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (16, 16), (7, 8)])
    def test_chunked_matches_recurrence(self, S, chunk):
        rng = np.random.default_rng(S)
        B, H, P, N = 2, 3, 4, 8
        xh = rng.standard_normal((B, S, H, P)).astype(np.float32)
        dt = np.abs(rng.standard_normal((B, S, H))).astype(np.float32) * 0.5
        A = -np.abs(rng.standard_normal(H)).astype(np.float32)
        Bc = rng.standard_normal((B, S, N)).astype(np.float32)
        Cc = rng.standard_normal((B, S, N)).astype(np.float32)
        D = rng.standard_normal(H).astype(np.float32)
        y = ssd_scan(
            jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(A),
            jnp.asarray(Bc), jnp.asarray(Cc), jnp.asarray(D), chunk,
        )
        ref = self._ref_recurrence(xh, dt, A, Bc, Cc, D)
        np.testing.assert_allclose(np.asarray(y, np.float32), ref, atol=2e-2, rtol=2e-2)


class TestDecodeConsistency:
    """Teacher-forced decode must reproduce the training forward's logits —
    validates caches, ring buffers, rope positions across families."""

    @pytest.mark.parametrize(
        "arch", ["repro_gpt_100m", "h2o_danube3_4b", "yi_6b", "granite_20b",
                 "deepseek_v2_236b", "olmoe_1b_7b", "mamba2_130m", "zamba2_7b"]
    )
    def test_decode_matches_forward(self, arch):
        cfg = get_config(arch).reduced()
        if cfg.moe:
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        B, S = 1, 24
        rng = np.random.default_rng(2)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
        batch = {"tokens": tokens, "labels": tokens}
        fwd_logits, _ = jax.jit(model.forward)(params, batch)

        state = model.init_decode_state(B, S, start_pos=0)
        step = jax.jit(model.decode_step)
        dec = []
        for t in range(S):
            lg, state = step(params, state, tokens[:, t : t + 1])
            dec.append(np.asarray(lg[:, 0], np.float32))
        dec = np.stack(dec, axis=1)
        fwd = np.asarray(fwd_logits, np.float32)
        if cfg.mla:
            # Absorbed-matmul decode reassociates the train-side bf16 chain;
            # agreement is argmax-exact but not elementwise-tight.
            np.testing.assert_array_equal(dec.argmax(-1), fwd.argmax(-1))
            assert np.abs(dec - fwd).mean() < 5e-2
        else:
            np.testing.assert_allclose(dec, fwd, atol=8e-2, rtol=8e-2)


def test_mrope_reduces_to_rope_for_text():
    """Equal t=h=w positions ⇒ M-RoPE == standard RoPE (text tokens)."""
    from repro.models import layers

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 4, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    pos_thw = jnp.broadcast_to(jnp.arange(16)[None, :, None], (2, 16, 3)).astype(jnp.int32)
    a = layers.apply_rope(x, pos, 1e4)
    b = layers.apply_mrope(x, pos_thw, 1e4, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the published ballpark
    (eval_shape only — nothing allocated)."""
    expect = {
        "yi_6b": (5.5e9, 7.5e9),
        "granite_20b": (18e9, 23e9),
        "h2o_danube3_4b": (3.2e9, 4.5e9),
        "qwen15_4b": (3.3e9, 5e9),
        "qwen2_vl_2b": (1.2e9, 2.3e9),
        "olmoe_1b_7b": (6e9, 8e9),
        "deepseek_v2_236b": (2.0e11, 2.6e11),
        "mamba2_130m": (1.0e8, 1.9e8),
        "hubert_xlarge": (0.8e9, 1.3e9),
        "zamba2_7b": (6e9, 9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
