"""Shared decode-parity harness (ISSUE 3).

One implementation of the decode contract used by every decode test and
the CI smoke: for any blob, the decoded bytes are **bit-exact** across
``backend ∈ {host, device, auto} × threads ∈ {1, 4}``, equal to the host
reference, and (for the checked-in fixtures) equal to the frozen golden
raw bytes — while re-encoding the raw bytes reproduces the golden blob
byte-for-byte (format stability).  The encode side additionally sweeps
``entropy_backend`` (the fused device Huffman bit-pack stage,
``core/device_entropy.py``): blobs must stay byte-identical with the
entropy stage on device, including on the canonical-coder configs where
it actually engages.  Payload-resident rows additionally decode through
the parse-once :class:`~repro.core.zipnn.ArrayFeed` and assert both bit
equality and zero per-decode payload uploads.

Importable from test modules (no ``test_`` prefix, so pytest does not
collect it as a suite) and runnable standalone as the CI parity smoke:

    PYTHONPATH=src python tests/parity.py --smoke      # reduced sweep
    PYTHONPATH=src python tests/parity.py              # full sweep + golden
"""

from __future__ import annotations

import argparse
import io
import json
import os
import sys
from typing import Optional, Sequence, Tuple

import ml_dtypes
import numpy as np

from repro.core import engine, zipnn

FIXTURE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "fixtures")

DTYPES = ("bfloat16", "float32", "float16")
BACKENDS = ("host", "device", "auto")
THREADS = (1, 4)

NP_DTYPES = {
    "bfloat16": ml_dtypes.bfloat16,
    "float16": np.float16,
    "float32": np.float32,
    "float64": np.float64,
}

#: payload kinds the sweep covers — weight-like values plus the layouts'
#: special encodings (NaN/Inf payload bits, denormals, zeros, uniform bits)
PAYLOAD_KINDS = ("normal", "bits", "nan_inf", "denormal", "zeros")


def make_array(
    dtype_name: str, n: int, seed: int = 0, kind: str = "normal"
) -> np.ndarray:
    """Deterministic test tensor of ``n`` elements of the given payload kind."""
    npdt = np.dtype(NP_DTYPES[dtype_name])
    rng = np.random.default_rng(seed)
    if kind == "zeros":
        return np.zeros(n, npdt)
    if kind == "bits":
        # Uniform random bit patterns: exercises every exponent value,
        # NaN/Inf encodings and denormals in one stream.
        uint = {2: np.uint16, 4: np.uint32, 8: np.uint64}[npdt.itemsize]
        return rng.integers(0, np.iinfo(uint).max, n, dtype=uint).view(npdt)
    scale = 0.02 if npdt.itemsize == 2 else 0.3
    vals = (rng.standard_normal(n) * scale).astype(npdt)
    if kind == "nan_inf" and n:
        idx = rng.integers(0, n, max(1, n // 7))
        vals[idx[0::3]] = np.asarray(np.nan, npdt)
        vals[idx[1::3]] = np.asarray(np.inf, npdt)
        vals[idx[2::3]] = np.asarray(-np.inf, npdt)
    elif kind == "denormal" and n:
        # smallest-normal / 8 underflows to a denormal in every layout
        # (np.finfo rejects ml_dtypes scalars; ml_dtypes.finfo covers them)
        try:
            tiny = np.finfo(npdt).tiny / 8
        except ValueError:
            tiny = float(ml_dtypes.finfo(npdt.type).tiny) / 8
        idx = rng.integers(0, n, max(1, n // 5))
        vals[idx] = np.asarray(tiny, npdt) * rng.choice([-1, 1], idx.size).astype(npdt)
    return vals


def as_bytes(arr: np.ndarray) -> bytes:
    return np.ascontiguousarray(arr).view(np.uint8).tobytes()


def assert_decode_parity(
    raw: bytes,
    dtype_name: str,
    *,
    config: Optional[zipnn.ZipNNConfig] = None,
    backends: Sequence[str] = BACKENDS,
    threads: Sequence[int] = THREADS,
    label: str = "",
) -> bytes:
    """Compress once per backend (asserting encode parity), then decode the
    host-reference blob across every backend × thread combination and
    assert bit-exact equality with the raw bytes.  Returns the blob."""
    cfg = zipnn.DEFAULT if config is None else config
    ref = zipnn.compress_bytes(raw, dtype_name, cfg, backend="host")
    assert zipnn.decompress_bytes(ref, cfg, threads=1, backend="host") == raw, (
        f"host decode not lossless [{label}]"
    )
    for be in backends:
        blob = zipnn.compress_bytes(raw, dtype_name, cfg, backend=be)
        assert blob == ref, f"encode backend {be!r} changed blob bytes [{label}]"
        # Device entropy stage (fused Huffman bit-pack; host fallback for the
        # hufflib coder) must never change blob bytes either.
        blob = zipnn.compress_bytes(
            raw, dtype_name, cfg, backend=be, entropy_backend=be
        )
        assert blob == ref, (
            f"entropy backend {be!r} changed blob bytes [{label}]"
        )
        for t in threads:
            out = zipnn.decompress_bytes(ref, cfg, threads=t, backend=be)
            assert out == raw, (
                f"decode backend {be!r} × threads={t} not bit-exact [{label}]"
            )
            # Device entropy decode (fused Huffman decoder kernel; host
            # fallback off the canonical envelope) must be bit-exact too.
            out = zipnn.decompress_bytes(
                ref, cfg, threads=t, backend=be, entropy_backend=be
            )
            assert out == raw, (
                f"decode entropy backend {be!r} × threads={t} not bit-exact "
                f"[{label}]"
            )
    return ref


def assert_feed_parity(
    raw: bytes,
    dtype_name: str,
    *,
    config: Optional[zipnn.ZipNNConfig] = None,
    label: str = "",
) -> int:
    """Device-resident payload feed parity: the parse-once/decode-many
    :class:`~repro.core.zipnn.ArrayFeed` returns the same bytes as the
    one-shot decoder, with **zero** per-decode payload uploads — payload
    residency is a wall-clock/memory knob, never a bytes knob.

    Returns 1 when a feed covered the stream, 0 when it fell back
    (TAIL remainder, empty tensor, no device backend) — fallbacks are the
    per-call decoder's job and already swept above."""
    from repro.core import device_entropy

    cfg = zipnn.DEFAULT if config is None else config
    itemsize = np.dtype(NP_DTYPES[dtype_name]).itemsize
    if not len(raw) or len(raw) % itemsize:
        return 0
    blob = zipnn.compress_bytes(raw, dtype_name, cfg)
    ct = zipnn.CompressedTensor(blob, dtype_name, (len(raw) // itemsize,))
    feed = zipnn.build_array_feed(ct, cfg)
    if feed is None:
        return 0
    device_entropy.reset_transfer_stats()
    out = as_bytes(np.asarray(feed.decode()))
    assert out == raw, f"payload-feed decode not bit-exact [{label}]"
    assert device_entropy.transfer_stats()["payload_uploads"] == 0, (
        f"payload-feed decode moved payload bytes host→device [{label}]"
    )
    return 1


def assert_delta_parity(
    new: np.ndarray,
    base: np.ndarray,
    *,
    config: Optional[zipnn.ZipNNConfig] = None,
    backends: Sequence[str] = BACKENDS,
    threads: Sequence[int] = THREADS,
    label: str = "",
) -> zipnn.CompressedTensor:
    """Delta round-trip parity: same contract as :func:`assert_decode_parity`
    for the §4.2 XOR-delta path (fused device XOR on both sides)."""
    cfg = zipnn.DEFAULT if config is None else config
    ref = zipnn.delta_compress(new, base, cfg, backend="host")
    want = as_bytes(np.asarray(new))
    for be in backends:
        ct = zipnn.delta_compress(new, base, cfg, backend=be)
        assert ct.blob == ref.blob, (
            f"delta encode backend {be!r} changed blob bytes [{label}]"
        )
        ct = zipnn.delta_compress(new, base, cfg, backend=be, entropy_backend=be)
        assert ct.blob == ref.blob, (
            f"delta entropy backend {be!r} changed blob bytes [{label}]"
        )
        for t in threads:
            back = zipnn.delta_decompress(ref, base, cfg, threads=t, backend=be)
            assert as_bytes(back) == want, (
                f"delta decode backend {be!r} × threads={t} not bit-exact "
                f"[{label}]"
            )
            back = zipnn.delta_decompress(
                ref, base, cfg, threads=t, backend=be, entropy_backend=be
            )
            assert as_bytes(back) == want, (
                f"delta decode entropy backend {be!r} × threads={t} not "
                f"bit-exact [{label}]"
            )
    return ref


def assert_stream_parity(
    raw: bytes,
    dtype_name: str,
    *,
    config: Optional[zipnn.ZipNNConfig] = None,
    window_bytes: int = 1 << 17,
    backends: Sequence[str] = BACKENDS,
    threads: Sequence[int] = THREADS,
    label: str = "",
) -> bytes:
    """ZNS1 streaming parity: one compressed container, decoded through
    ``DecompressReader`` across every backend × thread combination."""
    cfg = zipnn.DEFAULT if config is None else config
    sink = io.BytesIO()
    with engine.CompressWriter(
        sink, dtype_name, cfg, window_bytes=window_bytes
    ) as w:
        w.write(raw)
    blob = sink.getvalue()
    for be in backends:
        for t in threads:
            r = engine.DecompressReader(
                io.BytesIO(blob), cfg, threads=t, backend=be
            )
            assert r.read() == raw, (
                f"stream decode backend {be!r} × threads={t} not bit-exact "
                f"[{label}]"
            )
    return blob


# ---------------------------------------------------------------------------
# full sweep
# ---------------------------------------------------------------------------

#: element counts covering empty, scalar, sub-chunk, multi-chunk and
#: odd/unaligned shapes (the huge-tail cases ride the +1/+3 offsets)
SWEEP_SIZES = (0, 1, 3, 257, 8_192, 40_001, 140_003)


def sweep(
    dtypes: Sequence[str] = DTYPES,
    sizes: Sequence[int] = SWEEP_SIZES,
    kinds: Sequence[str] = PAYLOAD_KINDS,
    backends: Sequence[str] = BACKENDS,
    threads: Sequence[int] = THREADS,
    deltas: bool = True,
    verbose: bool = False,
) -> int:
    """Run the dtype × shape × payload × delta × backend × threads sweep.

    Returns the number of cases checked; raises AssertionError on the
    first parity violation.
    """
    cases = 0
    cfg = zipnn.ZipNNConfig(chunk_param_bytes=1 << 15)  # multi-chunk at test sizes
    # Canonical-coder config: HUFF chunks, so the device entropy stage
    # (fused bit-pack) actually engages instead of falling back.
    cfg_huff = zipnn.ZipNNConfig(chunk_param_bytes=1 << 15, backend="huffman")
    for dtype in dtypes:
        itemsize = np.dtype(NP_DTYPES[dtype]).itemsize
        for n in sizes:
            for kind in kinds:
                arr = make_array(dtype, n, seed=cases, kind=kind)
                raw = as_bytes(arr)
                label = f"{dtype} n={n} {kind}"
                assert_decode_parity(
                    raw, dtype, config=cfg,
                    backends=backends, threads=threads, label=label,
                )
                # huge-tail: a raw stream that is NOT a whole number of
                # elements exercises the TAIL frame on both backends
                assert_decode_parity(
                    raw + b"\x09" * (itemsize - 1 or 1), dtype, config=cfg,
                    backends=backends, threads=threads, label=label + " +tail",
                )
                cases += 2
                if kind == "normal":
                    assert_decode_parity(
                        raw, dtype, config=cfg_huff,
                        backends=backends, threads=threads,
                        label=label + " huff",
                    )
                    cases += 1
                    # payload-resident rows: HUFF words resident (huffman
                    # coder) and pure-splice resident (zlib coder)
                    cases += assert_feed_parity(
                        raw, dtype, config=cfg_huff, label=label + " feed"
                    )
                    cases += assert_feed_parity(
                        raw, dtype, config=cfg, label=label + " feed-zlib"
                    )
                if verbose:
                    print(f"  ok: {label}")
            if deltas and n:
                base = make_array(dtype, n, seed=1000 + n, kind="normal")
                new = np.asarray(base).copy()
                rng = np.random.default_rng(n)
                idx = rng.integers(0, n, max(1, n // 50))
                new[idx] = make_array(dtype, idx.size, seed=n, kind="normal")
                assert_delta_parity(
                    new, base, config=cfg,
                    backends=backends, threads=threads,
                    label=f"{dtype} n={n} delta",
                )
                cases += 1
    return cases


# ---------------------------------------------------------------------------
# golden fixtures (format-stability regression guard)
# ---------------------------------------------------------------------------

def _fixture_config(d: dict) -> zipnn.ZipNNConfig:
    return zipnn.ZipNNConfig(**d)


def check_golden(
    fixture_dir: str = FIXTURE_DIR,
    backends: Sequence[str] = BACKENDS,
    threads: Sequence[int] = THREADS,
) -> int:
    """Decode every checked-in golden blob (across backends × threads) and
    assert the raw bytes match; re-encode the raw bytes and assert the blob
    is reproduced byte-identically.  Returns the number of fixtures."""
    with open(os.path.join(fixture_dir, "meta.json")) as f:
        meta = json.load(f)

    def rd(name: str) -> bytes:
        with open(os.path.join(fixture_dir, name), "rb") as f:
            return f.read()

    for fx in meta["fixtures"]:
        cfg = _fixture_config(fx["config"])
        label = f"golden:{fx['name']}"
        if fx["kind"] == "bytes":
            raw, blob = rd(fx["raw"]), rd(fx["blob"])
            for be in backends:
                for t in threads:
                    out = zipnn.decompress_bytes(blob, cfg, threads=t, backend=be)
                    assert out == raw, f"{label} decode {be}×{t} != frozen raw"
            out = zipnn.decompress_bytes(blob, cfg, entropy_backend="device")
            assert out == raw, f"{label} device-entropy decode != frozen raw"
            re = zipnn.compress_bytes(raw, fx["dtype"], cfg)
            assert re == blob, f"{label} re-encode != frozen blob"
            re = zipnn.compress_bytes(raw, fx["dtype"], cfg, entropy_backend="device")
            assert re == blob, f"{label} device-entropy re-encode != frozen blob"
        elif fx["kind"] == "delta":
            raw, base_raw, blob = rd(fx["raw"]), rd(fx["base"]), rd(fx["blob"])
            npdt = np.dtype(NP_DTYPES[fx["dtype"]])
            new = np.frombuffer(raw, dtype=npdt).copy()
            base = np.frombuffer(base_raw, dtype=npdt).copy()
            ct = zipnn.CompressedTensor(blob, fx["dtype"], tuple(fx["shape"]))
            for be in backends:
                for t in threads:
                    back = zipnn.delta_decompress(ct, base, cfg, threads=t, backend=be)
                    assert as_bytes(back) == raw, (
                        f"{label} decode {be}×{t} != frozen raw"
                    )
            back = zipnn.delta_decompress(ct, base, cfg, entropy_backend="device")
            assert as_bytes(back) == raw, (
                f"{label} device-entropy decode != frozen raw"
            )
            re = zipnn.delta_compress(new, base, cfg)
            assert re.blob == blob, f"{label} re-encode != frozen blob"
            re = zipnn.delta_compress(new, base, cfg, entropy_backend="device")
            assert re.blob == blob, (
                f"{label} device-entropy re-encode != frozen blob"
            )
        elif fx["kind"] == "stream":
            raw, blob = rd(fx["raw"]), rd(fx["blob"])
            for be in backends:
                for t in threads:
                    r = engine.DecompressReader(
                        io.BytesIO(blob), cfg, threads=t, backend=be
                    )
                    assert r.read() == raw, f"{label} decode {be}×{t} != frozen raw"
            r = engine.DecompressReader(
                io.BytesIO(blob), cfg, entropy_backend="device"
            )
            assert r.read() == raw, f"{label} device-entropy decode != frozen raw"
            sink = io.BytesIO()
            with engine.CompressWriter(
                sink, fx["dtype"], cfg, window_bytes=fx["window_bytes"]
            ) as w:
                w.write(raw)
            assert sink.getvalue() == blob, f"{label} re-encode != frozen blob"
        else:
            raise ValueError(f"unknown fixture kind {fx['kind']!r}")
    return len(meta["fixtures"])


# ---------------------------------------------------------------------------
# CLI — the CI decode-backend parity smoke
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="reduced sweep (host vs device × threads 1,4; one payload "
             "kind, small sizes) — the CI smoke",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        n = sweep(
            dtypes=("bfloat16", "float32"),
            sizes=(0, 3, 40_001),
            kinds=("normal", "bits"),
            backends=("host", "device"),
            threads=(1, 4),
        )
    else:
        n = sweep(verbose=True)
    g = check_golden()
    print(
        f"decode parity OK: {n} sweep cases bit-exact across "
        f"backends × threads; {g} golden fixtures decode + re-encode stable"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
