"""Golden-blob format-stability guard (ISSUE 3 satellite).

The fixtures under ``tests/fixtures/`` freeze the ZNN1/ZNS1 container
format and codec byte stream as of this PR.  Today's code must decode them
bit-exactly (on every backend × thread combination) AND re-encode the
frozen raw bytes to the byte-identical blob.  A failure here means the
wire format changed: bump the container version and regenerate via
``tests/fixtures/generate_fixtures.py`` — deliberately, never silently.
"""

import json
import os

import pytest

import parity


def test_fixture_dir_is_populated():
    with open(os.path.join(parity.FIXTURE_DIR, "meta.json")) as f:
        meta = json.load(f)
    assert len(meta["fixtures"]) >= 5
    kinds = {fx["kind"] for fx in meta["fixtures"]}
    assert kinds == {"bytes", "delta", "stream"}
    for fx in meta["fixtures"]:
        for key in ("raw", "blob", "base"):
            if key in fx:
                path = os.path.join(parity.FIXTURE_DIR, fx[key])
                assert os.path.getsize(path) > 0, fx[key]


def test_golden_decode_and_reencode():
    assert parity.check_golden() >= 5


@pytest.mark.parametrize("threads", [1, 4])
def test_golden_decode_backends(threads):
    """The acceptance sweep scoped to the frozen blobs: host, device and
    auto all reproduce the frozen raw bytes for 1 and 4 threads."""
    parity.check_golden(
        backends=("host", "device", "auto"), threads=(threads,)
    )
