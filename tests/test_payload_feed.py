"""Device-resident payload feed: zero per-token host→device payload traffic.

The contract under test: a :class:`~repro.core.device_entropy.PayloadFeed`
(and its per-leaf wrapper :class:`~repro.core.zipnn.ArrayFeed`) parses a
ZNN1 payload **once**, uploads the packed words to device memory **once**,
and every later :meth:`decode` re-runs the fused Huffman kernel straight
from those resident buffers — the module's transfer counters record zero
payload uploads per decode.  Residency and tiling are wall-clock/memory
knobs only: decoded bytes, ring logits and stream files stay bit-identical.

Rides along: the per-tile ring scheduler (`tiles=` in
``make_compressed_serve_step``), the bounded `_stacked_luts` cache, the
``ZIPNN_MAX_BATCH_BYTES`` env knob, the engine's ``pipeline_depth``, and
the encode-side resident-plane symbol gather.
"""

import io

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    codec,
    container,
    device_entropy,
    device_plane,
    engine,
    huffman,
    zipnn,
)
from test_serve_compressed import _lockstep, _tiny

from repro.serve import CompressedParamStore, make_compressed_serve_step

# fp32 + 1<<14 param bytes -> chunk_bytes 4096: word-aligned (feed-eligible)
# but *not* a CHUNK_ALIGN_BYTES multiple, so the plane stage runs on host —
# the decode feed must not care which encoder produced the blob.
HUFF = zipnn.ZipNNConfig(chunk_param_bytes=1 << 14, backend="huffman")
DEV = zipnn.CodecOptions(backend="device", entropy_backend="device")


def _feed_payloads(blob: bytes):
    """Container-parse ``blob`` into PayloadFeed's build inputs."""
    meta, mv = container.unpack_stream(blob)
    payloads = [
        [container.payload_view(meta, mv, p, c) for c in range(len(meta.entries[p]))]
        for p in range(meta.n_planes)
    ]
    return meta, payloads


# ---------------------------------------------------------------------------
# ArrayFeed / PayloadFeed: byte identity + the zero-upload decode contract
# ---------------------------------------------------------------------------

class TestArrayFeed:
    def test_round_trip_zero_decode_uploads(self):
        rng = np.random.default_rng(0)
        arr = rng.standard_normal(12_345).astype(np.float32)
        ct = zipnn.compress_array(arr, HUFF)
        feed = zipnn.build_array_feed(ct, HUFF)
        assert feed is not None
        assert feed.device_bytes > 0
        device_entropy.reset_transfer_stats()
        for _ in range(3):                      # every decode, not just the first
            out = feed.decode()
            assert not isinstance(out, np.ndarray)        # stayed on device
            assert np.asarray(out).tobytes() == arr.tobytes()
        assert device_entropy.transfer_stats()["payload_uploads"] == 0

    def test_mixed_methods_match_per_call_decode(self):
        """ZERO + STORE/ZLIB chunks ride the resident splice, HUFF chunks the
        resident words — reassembly equals the per-call decoder bit for bit."""
        rng = np.random.default_rng(1)
        arr = rng.standard_normal(3 * (1 << 12) + 777).astype(np.float32)
        arr[: 1 << 12] = 0.0                    # ZERO chunks in the top planes
        ct = zipnn.compress_array(arr, HUFF)
        feed = zipnn.build_array_feed(ct, HUFF)
        assert feed is not None
        want = zipnn.decompress_array(ct, HUFF, options=DEV.replace(device_resident=True))
        assert np.asarray(feed.decode()).tobytes() == np.asarray(want).tobytes()
        assert np.asarray(feed.decode()).tobytes() == arr.tobytes()

    def test_bf16_round_trip(self):
        import ml_dtypes

        rng = np.random.default_rng(2)
        arr = rng.standard_normal((96, 64)).astype(ml_dtypes.bfloat16)
        ct = zipnn.compress_array(arr, HUFF)
        feed = zipnn.build_array_feed(ct, HUFF)
        assert feed is not None
        out = feed.decode()
        assert out.shape == (96, 64)
        assert np.asarray(out).tobytes() == arr.tobytes()

    def test_empty_and_tail_and_foreign_blob_fall_back(self):
        """Ineligible leaves return None — the store then uses the per-call
        decoder, so None is a fallback signal, never an error."""
        empty = zipnn.compress_array(np.empty(0, np.float32), HUFF)
        assert zipnn.build_array_feed(empty, HUFF) is None
        # trailing bytes past the recorded payloads (TAIL remainder shape)
        ct = zipnn.compress_array(np.ones(64, np.float32), HUFF)
        tail = zipnn.CompressedTensor(ct.blob + b"\x00", ct.dtype, ct.shape)
        assert zipnn.build_array_feed(tail, HUFF) is None
        # non-word chunk geometry: whole feed build refuses up front
        meta, payloads = _feed_payloads(ct.blob)
        with pytest.raises(ValueError, match="whole-uint32-word"):
            device_entropy.PayloadFeed(
                meta.entries, payloads, meta.tables,
                codec.CodecParams(chunk_bytes=6),
            )

    def test_build_detects_corrupt_payload(self):
        """Integrity moves to build time: a flipped payload byte fails the
        CRC check while constructing the feed, not at some later decode."""
        rng = np.random.default_rng(3)
        arr = rng.standard_normal(1 << 12).astype(np.float32)
        ct = zipnn.compress_array(arr, HUFF)
        meta, payloads = _feed_payloads(ct.blob)
        params = codec.CodecParams(chunk_bytes=meta.chunk_bytes, backend="huffman")
        # unmutated build works
        device_entropy.PayloadFeed(meta.entries, payloads, meta.tables, params)
        victim = next(
            (p, c)
            for p in range(meta.n_planes)
            for c in range(len(payloads[p]))
            if len(payloads[p][c])
        )
        bad = bytearray(payloads[victim[0]][victim[1]])
        bad[0] ^= 0xFF
        payloads[victim[0]][victim[1]] = bytes(bad)
        with pytest.raises(IOError, match="CRC mismatch"):
            device_entropy.PayloadFeed(meta.entries, payloads, meta.tables, params)


# ---------------------------------------------------------------------------
# serving: the per-token transfer contract and per-tile decode
# ---------------------------------------------------------------------------

SERVE_CFG = zipnn.ZipNNConfig(chunk_param_bytes=1 << 15, backend="huffman")


class TestServeTransferContract:
    def test_zero_payload_uploads_after_warmup(self):
        """payload_feed=True: all uploads happen at store build; tokens after
        the jit warmup move zero payload bytes host→device.  The same ring
        without the feed re-uploads payloads every single token."""
        cfg, model, params = _tiny("repro_gpt_100m")
        store = CompressedParamStore.from_params(
            params, SERVE_CFG, options=DEV, payload_feed=True
        )
        assert store.device_payload_bytes > 0
        cstep = make_compressed_serve_step(model, store)
        B, steps = 1, 2
        state = model.init_decode_state(B, steps + 1, start_pos=0)
        toks = jnp.ones((B, 1), jnp.int32)
        _, state = cstep(state, toks)           # warmup: compile + first ring
        device_entropy.reset_transfer_stats()
        for _ in range(steps):
            _, state = cstep(state, toks)
        assert device_entropy.transfer_stats() == {
            "payload_uploads": 0,
            "payload_bytes": 0,
        }
        # contrast: the feed-less ring pays per-token payload uploads
        store2 = CompressedParamStore.from_params(params, SERVE_CFG, options=DEV)
        cstep2 = make_compressed_serve_step(model, store2)
        state = model.init_decode_state(B, steps + 1, start_pos=0)
        _, state = cstep2(state, toks)
        device_entropy.reset_transfer_stats()
        _, state = cstep2(state, toks)
        assert device_entropy.transfer_stats()["payload_uploads"] > 0

    @pytest.mark.parametrize(
        "arch",
        [
            "repro_gpt_100m",      # dense
            "olmoe_1b_7b",         # moe
            "deepseek_v2_236b",    # moe + dense prefix + MLA caches
        ],
    )
    def test_per_tile_ring_bit_identical(self, arch):
        cfg, model, params = _tiny(arch)
        store = CompressedParamStore.from_params(
            params, SERVE_CFG, options=DEV, payload_feed=True
        )
        ring, tiles = 2, 2
        cstep = make_compressed_serve_step(model, store, ring=ring, tiles=tiles)
        assert cstep.tiles == tiles
        assert _lockstep(cfg, model, params, cstep, steps=2)
        assert 1 <= store.peak_resident <= ring * tiles
        assert store.resident_count == 0

    def test_tiles_validation_and_geometry(self):
        cfg, model, params = _tiny("repro_gpt_100m")
        store = CompressedParamStore.from_params(params, SERVE_CFG)
        with pytest.raises(ValueError, match="tiles"):
            make_compressed_serve_step(model, store, tiles=0)
        key = store.stack_keys[0]
        n = store.n_leaves(key)
        for tiles in (1, 2, n, n + 3):          # more tiles than leaves is fine
            ids = [store.tile_leaf_ids(key, t, tiles) for t in range(tiles)]
            flat = [j for r in ids for j in r]
            assert flat == list(range(n))       # contiguous, complete, ordered

    def test_many_tiles_lockstep(self):
        """tiles > leaves-per-layer: trailing empty tiles are scheduled and
        released without affecting bytes."""
        cfg, model, params = _tiny("repro_gpt_100m")
        store = CompressedParamStore.from_params(params, SERVE_CFG)
        n = store.n_leaves(store.stack_keys[0])
        cstep = make_compressed_serve_step(model, store, ring=2, tiles=n + 2)
        assert _lockstep(cfg, model, params, cstep, steps=1)
        assert store.resident_count == 0


# ---------------------------------------------------------------------------
# satellite: bounded LUT cache
# ---------------------------------------------------------------------------

class TestLutCacheBound:
    def test_cache_is_bounded(self):
        info = device_entropy._stacked_luts_cached.cache_info()
        assert info.maxsize == device_entropy.LUT_CACHE_SIZE
        rng = np.random.default_rng(0)
        for i in range(device_entropy.LUT_CACHE_SIZE + 8):
            freqs = np.zeros(256, dtype=np.int64)
            hot = rng.choice(256, size=8, replace=False)
            freqs[hot] = rng.integers(1, 1000, size=8) + i
            tb = huffman.pack_table(huffman.code_lengths(freqs))
            device_entropy._stacked_luts((tb,))
        info = device_entropy._stacked_luts_cached.cache_info()
        assert info.currsize <= info.maxsize


# ---------------------------------------------------------------------------
# satellite: ZIPNN_MAX_BATCH_BYTES env knob
# ---------------------------------------------------------------------------

class TestBatchBytesEnv:
    def test_default_when_unset(self, monkeypatch):
        monkeypatch.delenv("ZIPNN_MAX_BATCH_BYTES", raising=False)
        assert (
            device_plane._batch_bytes_from_env()
            == device_plane.DEFAULT_BATCH_BYTES
        )

    @pytest.mark.parametrize(
        "raw,want", [("123456", 123456), ("0x100000", 1 << 20), ("1", 1)]
    )
    def test_accepts_positive_ints(self, monkeypatch, raw, want):
        monkeypatch.setenv("ZIPNN_MAX_BATCH_BYTES", raw)
        assert device_plane._batch_bytes_from_env() == want

    @pytest.mark.parametrize("raw", ["abc", "", "1.5", "0", "-4096"])
    def test_rejects_garbage(self, monkeypatch, raw):
        monkeypatch.setenv("ZIPNN_MAX_BATCH_BYTES", raw)
        with pytest.raises(ValueError, match="ZIPNN_MAX_BATCH_BYTES"):
            device_plane._batch_bytes_from_env()

    def test_entropy_stage_shares_the_cap(self):
        assert device_entropy.MAX_BATCH_BYTES is device_plane.MAX_BATCH_BYTES


# ---------------------------------------------------------------------------
# satellite: engine frame pipeline depth
# ---------------------------------------------------------------------------

class TestEnginePipelineDepth:
    def _stream(self, n=200_000, seed=0):
        rng = np.random.default_rng(seed)
        return rng.standard_normal(n // 4).astype(np.float32).tobytes()

    @pytest.mark.parametrize(
        "threads,depth", [(0, 1), (0, 2), (4, 1), (4, 2), (4, 3)]
    )
    def test_files_byte_identical_across_depths(self, threads, depth):
        raw = self._stream()
        ref = io.BytesIO()
        engine.compress_file(
            io.BytesIO(raw), ref, "float32", window_bytes=1 << 16
        )
        opts = zipnn.CodecOptions(threads=threads)
        out = io.BytesIO()
        engine.compress_file(
            io.BytesIO(raw), out, "float32", window_bytes=1 << 16,
            options=opts, pipeline_depth=depth,
        )
        assert out.getvalue() == ref.getvalue()
        back = io.BytesIO()
        engine.decompress_file(
            io.BytesIO(out.getvalue()), back,
            options=opts, pipeline_depth=depth,
        )
        assert back.getvalue() == raw

    def test_depth_validation(self):
        with pytest.raises(ValueError, match="pipeline_depth"):
            engine.CompressWriter(io.BytesIO(), "float32", pipeline_depth=0)
        raw = self._stream(n=4096)
        blob = io.BytesIO()
        engine.compress_file(io.BytesIO(raw), blob, "float32")
        with pytest.raises(ValueError, match="pipeline_depth"):
            engine.DecompressReader(
                io.BytesIO(blob.getvalue()), pipeline_depth=0
            )


# ---------------------------------------------------------------------------
# encode mirror: resident planes feed the symbol gather on device
# ---------------------------------------------------------------------------

class TestEncodeResidentGather:
    def test_device_planes_skip_symbol_upload(self):
        """With the device plane stage, HUFF symbols are sliced from the
        resident plane chunks — zero payload-sized uploads — while the host
        plane stage must upload them; blobs are identical either way."""
        # fp32 device plane stage needs chunk_bytes % 16384 == 0
        cfg = zipnn.ZipNNConfig(chunk_param_bytes=1 << 16, backend="huffman")
        rng = np.random.default_rng(7)
        arr = rng.standard_normal(1 << 15).astype(np.float32)
        device_entropy.reset_transfer_stats()
        ct_dev = zipnn.compress_array(arr, cfg, options=DEV)
        dev_stats = device_entropy.transfer_stats()
        device_entropy.reset_transfer_stats()
        ct_host = zipnn.compress_array(
            arr, cfg,
            options=zipnn.CodecOptions(backend="host", entropy_backend="device"),
        )
        host_stats = device_entropy.transfer_stats()
        assert dev_stats["payload_uploads"] == 0
        assert host_stats["payload_uploads"] > 0
        assert ct_dev.blob == ct_host.blob
        assert ct_dev.blob == zipnn.compress_array(arr, cfg).blob

    def test_plane_slices_lose_the_device_twin(self):
        """PlanedArray views/slices must not inherit a stale device twin."""
        host = np.arange(64, dtype=np.uint8).view(device_plane.PlanedArray)
        host.dev_chunks = jnp.zeros((2, 32), jnp.uint8)
        assert host[1:].dev_chunks is None
        assert host.copy().dev_chunks is None
