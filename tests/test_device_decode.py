"""Device entropy-decode backend (core/device_entropy.decode_planes +
kernels/huffdecode.py) and the zero-bounce decode pipeline.

Contract under test: every ``HUFF`` chunk of a canonical-coder container
decodes on device **bit-identically** to ``huffman.decode_many`` / the
host codec — across tables, chunk sizes, final partial chunks, and
``STORE``/``ZERO``/expansion-guard mixes — and corrupt payloads fail
cleanly (CRC / bit-cursor / pad-bit errors, never an out-of-bounds
gather).  The device-resident path feeds kernel-decoded symbols straight
into the fused un-plane consumer so restored leaves never bounce through
host memory.
"""

import dataclasses
import io
import tempfile

import numpy as np
import pytest

from repro.core import codec, device_entropy, engine, huffman, zipnn
from parity import make_array

HUFF_CFG = zipnn.ZipNNConfig(chunk_param_bytes=1 << 15, backend="huffman")


def _skewed_plane(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    p = np.r_[np.full(16, 0.05), np.full(240, 0.2 / 240)]
    return rng.choice(256, p=p, size=n).astype(np.uint8)


def _table_for(plane: np.ndarray) -> np.ndarray:
    return huffman.code_lengths(np.bincount(plane, minlength=256) + 1)


def _chunk(plane: np.ndarray, chunk_bytes: int):
    return [
        plane[o : o + chunk_bytes] for o in range(0, plane.size, chunk_bytes)
    ]


def _pack_words(payloads, chunk_bytes: int) -> np.ndarray:
    """Payloads → the kernel's per-chunk big-endian uint32 word lanes."""
    cw = chunk_bytes // 4
    words = np.zeros(len(payloads) * cw, dtype=np.uint32)
    for k, pay in enumerate(payloads):
        pad = -len(pay) % 4
        w = np.frombuffer(bytes(pay) + b"\x00" * pad, dtype=">u4")
        words[k * cw : k * cw + w.size] = w
    return words


# ---------------------------------------------------------------------------
# kernel-level parity: fused decode vs the lockstep host decoder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_bytes", [4096, 16384])
@pytest.mark.parametrize(
    "n", [4096, 16384 * 3, 16384 * 2 + 5_001, 1 << 15]
)  # whole chunks, multi-chunk, final partial chunk
def test_kernel_matches_decode_many(chunk_bytes, n):
    import jax.numpy as jnp

    from repro.kernels import huffdecode

    plane = _skewed_plane(n, seed=chunk_bytes + n)
    lens = _table_for(plane)
    codes = huffman.canonical_codes(lens)
    chunks = _chunk(plane, chunk_bytes)
    counts = np.asarray([c.size for c in chunks], dtype=np.int64)
    payloads = huffman.encode_chunks(plane, counts, lens, codes)
    want = huffman.decode_many(payloads, counts, lens)

    max_l = int(lens.max(initial=1))
    lut_sym, lut_len = huffman._build_lut(lens, codes, max_l)
    luts = ((lut_sym.astype(np.int32) << 8) | lut_len.astype(np.int32))[None, :]
    syms, cursors = huffdecode.huffdecode_chunks_multi(
        jnp.asarray(_pack_words(payloads, chunk_bytes)),
        jnp.zeros(len(chunks), jnp.int32),
        jnp.asarray(counts, dtype=jnp.int32),
        jnp.asarray(luts),
        chunk_bytes=chunk_bytes,
    )
    syms = np.asarray(syms)
    cursors = np.asarray(cursors)
    for k, w in enumerate(want):
        assert np.array_equal(syms[k, : counts[k]], w)
        # the bit cursor must land inside the final payload byte
        slack = 8 * len(payloads[k]) - int(cursors[k])
        assert 0 <= slack < 8


def test_kernel_multi_table_selection():
    """Chunks of different planes gather against their own LUT row at the
    shared stacked width."""
    import jax.numpy as jnp

    from repro.kernels import huffdecode

    cb = 4096
    planes = [
        _skewed_plane(cb * 2 + 777, seed=1),
        (np.arange(cb * 3) % 7).astype(np.uint8),      # much shorter codes
    ]
    tabs = [_table_for(p) for p in planes]
    all_payloads, all_counts, pids, want = [], [], [], []
    for p, (plane, lens) in enumerate(zip(planes, tabs)):
        codes = huffman.canonical_codes(lens)
        chunks = _chunk(plane, cb)
        counts = np.asarray([c.size for c in chunks], dtype=np.int64)
        payloads = huffman.encode_chunks(plane, counts, lens, codes)
        want += huffman.decode_many(payloads, counts, lens)
        all_payloads += payloads
        all_counts += counts.tolist()
        pids += [p] * len(chunks)

    max_l = max(int(t.max(initial=1)) for t in tabs)
    luts = np.zeros((len(tabs), 1 << max_l), dtype=np.int32)
    for p, lens in enumerate(tabs):
        ls, ll = huffman._build_lut(lens, huffman.canonical_codes(lens), max_l)
        luts[p] = (ls.astype(np.int32) << 8) | ll.astype(np.int32)
    syms, _ = huffdecode.huffdecode_chunks_multi(
        jnp.asarray(_pack_words(all_payloads, cb)),
        jnp.asarray(pids, dtype=jnp.int32),
        jnp.asarray(all_counts, dtype=jnp.int32),
        jnp.asarray(luts),
        chunk_bytes=cb,
    )
    syms = np.asarray(syms)
    for k, w in enumerate(want):
        assert np.array_equal(syms[k, : len(w)], w)


def test_kernel_truncated_words_never_oob():
    """A payload cut short mis-lands the bit cursor; the clamped gathers
    keep the kernel in bounds and the driver-level check catches it."""
    import jax.numpy as jnp

    from repro.kernels import huffdecode

    cb = 4096
    plane = _skewed_plane(cb, seed=7)
    lens = _table_for(plane)
    codes = huffman.canonical_codes(lens)
    payloads = huffman.encode_chunks(plane, np.asarray([cb]), lens, codes)
    cut = payloads[0][: len(payloads[0]) // 2]     # truncate: cursor overruns
    max_l = int(lens.max(initial=1))
    ls, ll = huffman._build_lut(lens, codes, max_l)
    luts = ((ls.astype(np.int32) << 8) | ll.astype(np.int32))[None, :]
    syms, cursors = huffdecode.huffdecode_chunks_multi(
        jnp.asarray(_pack_words([cut], cb)),
        jnp.zeros(1, jnp.int32),
        jnp.asarray([cb], dtype=jnp.int32),
        jnp.asarray(luts),
        chunk_bytes=cb,
    )
    # no crash/OOB; the cursor demonstrably ran past the truncated payload
    assert int(np.asarray(cursors)[0]) > 8 * len(cut) - 8
    assert np.asarray(syms).shape == (1, cb)


# ---------------------------------------------------------------------------
# decode_many hardening (host twin of the kernel's integrity checks)
# ---------------------------------------------------------------------------

def test_decode_many_rejects_nonzero_pad_bits():
    # find a stream whose final byte has pad slack, then dirty the pad
    for n in range(2048, 2080):
        plane = _skewed_plane(n, seed=3)
        lens = _table_for(plane)
        codes = huffman.canonical_codes(lens)
        payloads = huffman.encode_chunks(
            plane, np.asarray([plane.size]), lens, codes
        )
        assert np.array_equal(
            huffman.decode_many(payloads, [plane.size], lens)[0], plane
        )
        total_bits = int(huffman.estimate_encoded_bits(
            np.bincount(plane, minlength=256), lens
        ))
        slack = 8 * len(payloads[0]) - total_bits
        if 0 < slack < 8:
            break
    else:
        pytest.fail("no padded tail found in the sweep")
    bad = payloads[0][:-1] + bytes([payloads[0][-1] | 1])
    with pytest.raises(ValueError, match="pad bits"):
        huffman.decode_many([bad], [plane.size], lens)


def test_decode_many_rejects_tampered_count():
    plane = _skewed_plane(2048, seed=4)
    lens = _table_for(plane)
    codes = huffman.canonical_codes(lens)
    payloads = huffman.encode_chunks(plane, np.asarray([plane.size]), lens, codes)
    with pytest.raises(ValueError):
        huffman.decode_many(payloads, [plane.size - 100], lens)


# ---------------------------------------------------------------------------
# decode_planes: driver parity + corruption fuzz
# ---------------------------------------------------------------------------

def _compress_plane_all(planes, params):
    outs = [codec.compress_plane(p, params) for p in planes]
    return (
        [o[0] for o in outs],
        [o[1] for o in outs],
        [o[2] for o in outs],
    )


def _mixed_planes(cb):
    """STORE (incompressible), ZERO, HUFF, and a final partial chunk."""
    rng = np.random.default_rng(11)
    return [
        np.concatenate([
            rng.integers(0, 256, cb, dtype=np.uint8).astype(np.uint8),  # STORE
            np.zeros(cb, dtype=np.uint8),                               # ZERO
            _skewed_plane(cb + cb // 3, seed=5),                        # HUFF+partial
        ]),
        _skewed_plane(2 * cb, seed=6),
    ]


@pytest.mark.parametrize("device_resident", [False, True])
def test_decode_planes_matches_host_codec(device_resident):
    cb = 4096
    params = codec.CodecParams(chunk_bytes=cb, backend="huffman")
    planes = _mixed_planes(cb)
    entries, payloads, tables = _compress_plane_all(planes, params)
    methods = {e.method for pe in entries for e in pe}
    assert codec.Method.HUFF in methods and codec.Method.STORE in methods
    got = device_entropy.decode_planes(
        entries, payloads, tables, params, device_resident=device_resident
    )
    for g, p in zip(got, planes):
        if device_resident:
            assert not isinstance(g, np.ndarray)
        assert np.array_equal(np.asarray(g), p)


def test_decode_planes_expansion_guard_mix():
    """Chunks the encoder's expansion guard stored raw splice back in."""
    cb = 4096
    params = codec.CodecParams(
        chunk_bytes=cb, backend="huffman", incompressible=1.1
    )  # force the probe to plan HUFF even on random bytes → guard trips
    rng = np.random.default_rng(12)
    plane = np.concatenate([
        rng.integers(0, 256, cb, dtype=np.uint8).astype(np.uint8),
        _skewed_plane(cb, seed=13),
    ])
    entries, payloads, tables = _compress_plane_all([plane], params)
    assert any(e.method == codec.Method.STORE for e in entries[0])
    got = device_entropy.decode_planes(entries, payloads, tables, params)
    assert np.array_equal(np.asarray(got[0]), plane)


def test_decode_planes_corruption_rejected():
    cb = 4096
    params = codec.CodecParams(chunk_bytes=cb, backend="huffman")
    plane = _skewed_plane(2 * cb, seed=8)
    entries, payloads, tables = _compress_plane_all([plane], params)
    assert entries[0][0].method == codec.Method.HUFF

    # flipped byte → CRC error (same message as the host codec)
    bad = [bytearray(p) for p in payloads[0]]
    bad[0][3] ^= 0xFF
    with pytest.raises(IOError, match="CRC mismatch"):
        device_entropy.decode_planes(
            [entries[0]], [[bytes(b) for b in bad]], tables, params
        )

    # truncated payload with a recomputed CRC → bit-cursor integrity error
    import zlib

    cut = bytes(payloads[0][0][: entries[0][0].comp_len // 2])
    e0 = dataclasses.replace(
        entries[0][0], comp_len=len(cut), crc=zlib.crc32(cut)
    )
    with pytest.raises(ValueError, match="cursor|pad bits"):
        device_entropy.decode_planes(
            [[e0] + entries[0][1:]], [[cut] + payloads[0][1:]], tables, params
        )

    # nonzero pad bits with a recomputed CRC → pad integrity error
    p0 = bytes(payloads[0][0])
    dirty = p0[:-1] + bytes([p0[-1] | 1])
    slack = -huffman.estimate_encoded_bits(
        np.bincount(plane[:cb], minlength=256),
        huffman.unpack_table(tables[0]),
    ) % 8
    if slack:                     # only meaningful when the tail is padded
        e0 = dataclasses.replace(entries[0][0], crc=zlib.crc32(dirty))
        with pytest.raises(ValueError, match="pad bits"):
            device_entropy.decode_planes(
                [[e0] + entries[0][1:]], [[dirty] + payloads[0][1:]],
                tables, params,
            )

    # missing table → same corrupt-stream error as the host codec
    with pytest.raises(IOError, match="no plane table"):
        device_entropy.decode_planes([entries[0]], [payloads[0]], [None], params)


def test_decode_envelope():
    assert device_entropy.supports_decode(4096) == device_entropy.is_available()
    assert not device_entropy.supports_decode(4097)
    assert device_entropy.resolve_decode(None, 4096) == "host"
    assert device_entropy.resolve_decode("host", 4096) == "host"
    assert device_entropy.resolve_decode("device", 4097) == "host"
    if device_entropy.is_available():
        assert device_entropy.resolve_decode("device", 4096) == "device"
    with pytest.raises(ValueError, match="unknown entropy backend"):
        device_entropy.resolve_decode("gpu", 4096)


def test_consume_payloads_zero_bounce():
    import jax

    from repro.core import bitlayout, device_unplane

    layout = bitlayout.LAYOUTS["float32"]
    arr = make_array("float32", 50_000, seed=21)
    cb = HUFF_CFG.plane_params(layout.itemsize).chunk_bytes
    params = codec.CodecParams(chunk_bytes=cb, backend="huffman")
    planes = [np.ascontiguousarray(p) for p in bitlayout.to_planes(
        np.frombuffer(arr.tobytes(), dtype=np.uint8), layout
    )]
    entries, payloads, tables = _compress_plane_all(planes, params)
    elems = device_unplane.consume_payloads(
        entries, payloads, tables, params, layout, device_resident=True
    )
    assert isinstance(elems, jax.Array)
    got = np.asarray(jax.device_get(elems)).view(np.float32)
    assert np.array_equal(got, arr.reshape(-1))


# ---------------------------------------------------------------------------
# end-to-end: the entropy_backend knob across the decode surface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["bfloat16", "float32"])
def test_decompress_bytes_parity(dtype):
    raw = make_array(dtype, 60_001, seed=31).tobytes()
    blob = zipnn.compress_bytes(raw, dtype, HUFF_CFG)
    for backend in (None, "device"):
        assert zipnn.decompress_bytes(
            blob, HUFF_CFG, backend=backend, entropy_backend="device"
        ) == raw
    # config-field route
    cfg = dataclasses.replace(HUFF_CFG, entropy_backend="device")
    assert zipnn.decompress_bytes(blob, cfg) == raw


def test_decompress_array_device_resident():
    arr = make_array("float32", 40_001, seed=32)
    ct = zipnn.compress_array(arr, HUFF_CFG)
    host = zipnn.decompress_array(ct, HUFF_CFG)
    dev = zipnn.decompress_array(
        ct, HUFF_CFG, backend="device", entropy_backend="device",
        device_resident=True,
    )
    assert not isinstance(dev, np.ndarray)
    assert dev.dtype == arr.dtype and dev.shape == arr.shape
    assert np.array_equal(np.asarray(dev), host)
    # host-resolved request still returns numpy (safe fallback)
    out = zipnn.decompress_array(ct, HUFF_CFG, backend="host", device_resident=True)
    assert isinstance(out, np.ndarray) and np.array_equal(out, host)


def test_delta_decompress_device_entropy():
    import jax.numpy as jnp

    base = make_array("float32", 30_000, seed=33)
    new = (base.reshape(-1) + np.float32(1e-3)).reshape(base.shape)
    ct = zipnn.delta_compress(new, base, HUFF_CFG)
    host = zipnn.delta_decompress(ct, base, HUFF_CFG)
    dev = zipnn.delta_decompress(
        ct, jnp.asarray(base), HUFF_CFG,
        backend="device", entropy_backend="device", device_resident=True,
    )
    assert not isinstance(dev, np.ndarray)
    assert np.array_equal(np.asarray(dev), host) and np.array_equal(host, new)


def test_decompress_pytree_device_entropy():
    tree = {
        "w": make_array("float32", 20_000, seed=34),
        "b": make_array("bfloat16", 7_001, seed=35),
    }
    m = zipnn.compress_pytree(tree, HUFF_CFG)
    host = zipnn.decompress_pytree(m, HUFF_CFG)
    dev = zipnn.decompress_pytree(
        m, HUFF_CFG, backend="device", entropy_backend="device"
    )
    for k in tree:
        assert np.array_equal(np.asarray(host[k]), np.asarray(dev[k]))
        assert np.array_equal(np.asarray(dev[k]), np.asarray(tree[k]))


def test_stream_reader_device_entropy():
    raw = make_array("float32", 90_000, seed=36).tobytes()
    buf = io.BytesIO()
    with engine.CompressWriter(buf, "float32", HUFF_CFG, window_bytes=1 << 17) as w:
        w.write(raw)
    buf.seek(0)
    r = engine.DecompressReader(buf, HUFF_CFG, entropy_backend="device")
    assert r.read() == raw


def test_checkpoint_device_resident_restore(tmp_path):
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

    mgr = CheckpointManager(CheckpointConfig(
        directory=str(tmp_path), zipnn=HUFF_CFG,
        backend="device", entropy_backend="device",
    ))
    p1 = make_array("float32", 40_000, seed=37).reshape(200, 200)
    p2 = (p1 + np.float32(1e-3)).astype(np.float32)
    mgr.save(1, {"p": p1}, blocking=True)
    mgr.save(2, {"p": p2}, blocking=True)       # delta vs the step-1 base
    s, host_tree = mgr.restore()
    assert s == 2 and np.array_equal(host_tree["p"], p2)
    s, dev_tree = mgr.restore(device_resident=True)
    assert s == 2 and isinstance(dev_tree["p"], jax.Array)
    assert np.array_equal(np.asarray(dev_tree["p"]), p2)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("x",))
    s, sharded = mgr.shard_restore(None, mesh, {"p": P()})
    assert s == 2 and np.array_equal(np.asarray(sharded["p"]), p2)


def test_grad_sync_device_entropy():
    from repro.distributed.grad_sync import GradSync

    gs = GradSync(HUFF_CFG, entropy_backend="device")
    grads = {"g": make_array("float32", 25_000, seed=38)}
    manifest, _ = gs.pack(grads)
    back = gs.unpack(manifest)
    assert np.array_equal(np.asarray(back["g"]), np.asarray(grads["g"]))
