"""Model-hub transfer with ZipNN (paper §2.1.1 + Fig. 10): how much wire
time does lossless compression save on upload/download?

    PYTHONPATH=src python examples/hub_transfer_sim.py
"""

import ml_dtypes
import numpy as np

from repro.checkpoint.hub import CHANNELS, simulate_transfer


def main():
    rng = np.random.default_rng(0)
    model = (rng.standard_normal(8_000_000) * 0.02).astype(ml_dtypes.bfloat16)
    raw = np.ascontiguousarray(model).view(np.uint8).tobytes()
    print(f"model: {len(raw)/1e6:.0f} MB BF16 (regular category)\n")
    print(f"{'channel':26s} {'raw s':>8s} {'zipnn s':>8s} {'speedup':>8s}")
    for ch in CHANNELS:
        direction = "upload" if ch.startswith("upload") else "download"
        rep = simulate_transfer(raw, "bfloat16", ch, direction=direction)
        print(f"{ch:26s} {rep.total_raw_s:8.1f} {rep.total_comp_s:8.1f} "
              f"{rep.speedup:7.2f}x")
    print("\n(compression ratio "
          f"{100*rep.comp_bytes/rep.raw_bytes:.1f}% — paper: ~66% for BF16)")


if __name__ == "__main__":
    main()
