"""End-to-end training driver with ZipNN checkpointing, crash recovery and
delta chains — the paper's §2.1.3 use case as a running system.

    PYTHONPATH=src python examples/train_checkpoint_demo.py [--full-100m]

Default trains a small LM for 60 steps (CPU-friendly); --full-100m runs the
~100M-parameter config (same code path, longer wall time).
"""

import argparse
import os
import shutil
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(args):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    subprocess.run([sys.executable, "-m", "repro.launch.train", *args],
                   env=env, cwd=ROOT, check=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true",
                    help="train the full ~100M repro_gpt config")
    args = ap.parse_args()

    ckpt = "/tmp/zipnn_demo_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)

    common = ["--arch", "repro_gpt_100m", "--ckpt-dir", ckpt,
              "--ckpt-every", "10", "--base-every", "3"]
    if not args.full_100m:
        common += ["--reduced", "--batch", "8", "--seq", "128"]
    else:
        common += ["--batch", "4", "--seq", "256", "--lr", "1e-3"]

    print("=== phase 1: train to step 30 (checkpoints every 10) ===")
    run(common + ["--steps", "30"])

    print("\n=== phase 2: 'crash' + resume to step 60 (auto-restore) ===")
    run(common + ["--steps", "60"])

    print("\n=== phase 3: serve from the compressed checkpoint ===")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    serve = [sys.executable, "-m", "repro.launch.serve", "--arch",
             "repro_gpt_100m", "--ckpt-dir", ckpt, "--gen", "16"]
    if not args.full_100m:
        serve.append("--reduced")
    subprocess.run(serve, env=env, cwd=ROOT, check=True)


if __name__ == "__main__":
    main()
