"""Decentralized training gradient exchange with ZipNN (paper §2.1.2):
compress the gradient pytree before it crosses the slow inter-site link.

    PYTHONPATH=src python examples/decentralized_grad_sync.py
"""

import jax

from repro.configs import get_config
from repro.data import DataConfig, make_batch
from repro.distributed.grad_sync import GradSync
from repro.models import build_model


def main():
    cfg = get_config("repro_gpt_100m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, DataConfig(seq_len=128, global_batch=4), 0)
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)

    gs = GradSync()
    for peers, gbps in [(4, 1.0), (16, 1.0), (16, 10.0)]:
        rep = gs.exchange(grads, n_peers=peers, link_gbps=gbps)
        print(f"peers={peers:3d} link={gbps:4.0f}Gb/s  "
              f"raw={rep['raw_s']*1e3:7.1f}ms  zipnn={rep['zipnn_s']*1e3:7.1f}ms  "
              f"payload={rep['ratio_pct']:.1f}%  (lossless ✓)")


if __name__ == "__main__":
    main()
