"""Quickstart: compress a model with ZipNN, verify losslessness, see where
the savings come from.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import ml_dtypes
import numpy as np

from repro.core import stats, zipnn
from repro.configs import get_config
from repro.models import build_model


def main():
    # 1. A real (reduced) model from the zoo
    cfg = get_config("yi_6b").reduced()
    params = build_model(cfg).init(jax.random.key(0))

    # 2. Compress the whole pytree
    manifest = zipnn.compress_pytree(params)
    print(f"raw   : {manifest['raw_bytes']/1e6:8.2f} MB")
    print(f"zipnn : {manifest['comp_bytes']/1e6:8.2f} MB "
          f"({100*manifest['comp_bytes']/manifest['raw_bytes']:.1f}% — "
          f"paper BF16 models: ~66%)")

    # 3. Losslessness
    back = zipnn.decompress_pytree(manifest)
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(back)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    print("round-trip: bit-exact ✓")

    # 4. Why it compresses: the exponent byte is skewed (paper Fig. 2)
    w = np.asarray(jax.device_get(params["layers"]["mlp"]["w_gate"])).astype(
        ml_dtypes.bfloat16
    )
    h = stats.exponent_histogram(w)
    print(f"exponent: {h['distinct_values']} distinct values, "
          f"top-12 cover {100*h['top12_mass']:.2f}% of weights")
    rep = stats.plane_report(w)
    print(f"plane entropies (bits/byte): exponent={rep[0]['entropy_bits']:.2f} "
          f"fraction={rep[1]['entropy_bits']:.2f}  → only the exponent compresses")

    # 5. Delta compression (paper §4.2): a fine-tuning step away
    w2 = np.asarray(w, np.float32)
    idx = np.random.default_rng(0).integers(0, w2.size, w2.size // 50)
    w2.reshape(-1)[idx] *= 1.01
    w2 = w2.astype(ml_dtypes.bfloat16)
    d = zipnn.delta_compress(w2, w)
    print(f"delta of a 2%-changed tensor: {100*d.nbytes/w.nbytes:.1f}% "
          "(vs ~66% standalone)")

    # 6. The parallel streaming engine (paper §5.2): threads=-1 fans
    # (plane, chunk) work items across all cores — bytes are identical to
    # the serial path — and compress_file/decompress_file stream checkpoints
    # larger than RAM through a bounded window.
    # Execution knobs ride one frozen CodecOptions bag (core/options.py);
    # the old loose threads=/backend=/entropy_backend= kwargs still work
    # behind a DeprecationWarning and win over the bag when set.
    from repro.core.options import CodecOptions

    all_cores = CodecOptions(threads=-1)
    import tempfile, os, time
    raw = np.ascontiguousarray(w).view(np.uint8).tobytes()
    t0 = time.perf_counter()
    blob = zipnn.compress_bytes(raw, "bfloat16", options=all_cores)
    t_par = time.perf_counter() - t0
    assert blob == zipnn.compress_bytes(raw, "bfloat16")   # deterministic
    with tempfile.TemporaryDirectory() as td:
        src = os.path.join(td, "ckpt.bin")
        dst = os.path.join(td, "ckpt.znns")
        with open(src, "wb") as f:
            f.write(raw)
        raw_b, comp_b = zipnn.compress_file(
            src, dst, "bfloat16", window_bytes=1 << 20, options=all_cores
        )
        print(f"engine: {raw_b/1e6:.1f} MB streamed → {comp_b/1e6:.1f} MB "
              f"(all-core compress in {t_par*1e3:.0f} ms, O(window) memory)")

    # 7. The decode backend knob: restore-side mirror of the device
    # plane-producer.  backend="device" uploads the entropy-decoded planes
    # once and runs un-byte-group + inverse rotate (+ delta XOR) as one
    # fused Pallas dispatch (core/device_unplane.py); "auto" picks device
    # only when an accelerator is attached.  Decoded bytes are bit-exact
    # across backends — on a CPU host the kernels run in interpret mode, so
    # the timing below is a correctness demo, not a speed claim.
    t0 = time.perf_counter()
    host_out = zipnn.decompress_bytes(blob, options=all_cores.replace(backend="host"))
    t_host = time.perf_counter() - t0
    t0 = time.perf_counter()
    dev_out = zipnn.decompress_bytes(blob, options=all_cores.replace(backend="device"))
    t_dev = time.perf_counter() - t0
    assert host_out == dev_out == raw                  # bit-exact contract
    print(f"decode: host {t_host*1e3:.0f} ms, device-backend {t_dev*1e3:.0f} ms "
          f"(bit-exact; device timing is interpret-mode off-TPU)")
    # The same knob rides every restore path: decompress_pytree(...,
    # backend=...), DecompressReader(..., backend=...), and
    # CheckpointConfig(backend="device") for manager.restore/shard_restore.

    # 8. The full-device compress path.  With the canonical 'huffman' coder,
    # backend="device" now runs BOTH compression halves on device: the fused
    # plane producer (XOR-delta → rotate+byte-group → probe histograms) and
    # the fused Huffman bit-pack entropy stage (core/device_entropy.py) —
    # the host only builds the 256-entry canonical table, applies the
    # expansion guard, and frames the container.  entropy_backend= decouples
    # the two stages for mixed mode.  Blobs are byte-identical on every
    # combination — that's the contract tests/parity.py enforces.
    cfg_h = zipnn.ZipNNConfig(backend="huffman")
    ref = zipnn.compress_bytes(
        raw, "bfloat16", cfg_h, options=CodecOptions(backend="host")
    )
    full_dev = zipnn.compress_bytes(
        raw, "bfloat16", cfg_h,
        options=CodecOptions(backend="device"),        # plane + entropy
    )
    mixed = zipnn.compress_bytes(
        raw, "bfloat16", cfg_h,
        options=CodecOptions(                          # host probe, device pack
            backend="host", entropy_backend="device"
        ),
    )
    assert ref == full_dev == mixed
    print("full-device compress (plane + fused Huffman bit-pack): "
          f"{len(ref)/1e6:.2f} MB, byte-identical across backends ✓")
    # The hufflib (zlib) coder has no device bitstream — entropy_backend is
    # still safe to set there: it silently stays on the host path.

    # 9. Full-device DECODE and the zero-bounce restore.  The decode twin
    # of §8: entropy_backend="device" decodes every HUFF chunk through the
    # device Huffman decoder kernel (kernels/huffdecode.py — grid over
    # chunks, per-chunk LUT row against the stacked canonical tables), so
    # only *compressed* bytes cross host→device and the decoded planes feed
    # the fused un-plane consumer in place.  The envelope keys off the
    # container, not the config: any canonical-coder blob qualifies.
    full_device = CodecOptions(backend="device", entropy_backend="device")
    dev_dec = zipnn.decompress_bytes(ref, cfg_h, options=full_device)
    assert dev_dec == raw                              # bit-exact contract
    # decompress_array/delta_decompress additionally take
    # device_resident=True: the restored leaf stays on device as a
    # jax.Array (bitcast from the consumer's element output — zero
    # device→host bounce).  CheckpointManager.shard_restore uses exactly
    # this: leaves go compressed-bytes → device decode → device_put
    # re-shard without ever touching host memory.
    ct = zipnn.compress_array(
        np.frombuffer(raw, dtype=ml_dtypes.bfloat16), cfg_h
    )
    leaf = zipnn.decompress_array(
        ct, cfg_h, options=full_device, device_resident=True,
    )
    assert not isinstance(leaf, np.ndarray)            # jax.Array, on device
    assert bytes(np.asarray(leaf).tobytes()) == raw
    print("zero-bounce decode: compressed payload is the only host→device "
          "transfer; restored leaf is device-resident ✓")

    # 10. Compressed-resident serving: weights stay ZNN1 payloads AT REST
    # and decode just ahead of compute.  CompressedParamStore splits the
    # stacked layers into per-layer payload manifests; the serving step
    # (serve/step.py) runs a double-buffered prefetch/decode ring — while
    # layer i's matmuls run, a background worker decodes layer i+1 through
    # decompress_pytree(..., device_resident=True) — so at most 2 layers
    # of decoded weights are ever claimed.  Logits are bit-identical to
    # the plain decode step: the ring is a scheduling change, and the
    # payload decode itself is byte-identical on every knob combo.
    from repro.serve import CompressedParamStore, make_compressed_serve_step

    model = build_model(cfg)
    store = CompressedParamStore.from_params(params)
    cstep = make_compressed_serve_step(model, store, ring=2)
    step = jax.jit(model.decode_step)
    sa = model.init_decode_state(2, 4, start_pos=0)
    sb = model.init_decode_state(2, 4, start_pos=0)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 1))
    for _ in range(4):
        la, sa = step(params, sa, toks)
        lb, sb = cstep(sb, toks)
        assert np.asarray(la).tobytes() == np.asarray(lb).tobytes()
    assert store.peak_resident <= 2
    print(f"compressed-resident serving: weights at rest {store.ratio_pct:.1f}% "
          f"of raw, peak {store.peak_resident} decoded layers, logits "
          "bit-identical ✓")

    # 11. The unified options API + the KV-cache tier.  The knob sprawl
    # (threads=/backend=/entropy_backend= on ~20 entry points) collapses
    # into one frozen CodecOptions bag — legacy kwargs still work behind a
    # DeprecationWarning, and an explicit legacy kwarg wins over the bag.
    # ZipNNSession binds config + options once for the whole surface.
    from repro.core.options import ZipNNSession

    session = ZipNNSession(options=CodecOptions(threads=-1))
    assert session.decompress_bytes(session.compress_bytes(raw, "bfloat16")) == raw
    assert session.compress_bytes(raw, "bfloat16") == blob  # same bytes as §1
    print("ZipNNSession: one options bag, whole surface, bytes identical ✓")

    # The serving-side analogue of the weight store: the KV cache itself.
    # KVCacheStore keeps the newest hot_window positions uncompressed and
    # evicts older block_len-sized blocks to per-(key, layer) ZNN1
    # payloads; each decode step reassembles only the attending layer's
    # caches (decoded cold blocks + hot suffix + zero tail) — arrays
    # byte-identical to the untiered cache, so greedy decode logits are
    # bit-identical while peak cache residency drops to hot buffers +
    # compressed payloads + one layer in flight.
    from repro.serve import KVCacheStore, make_kv_tiered_serve_step

    steps = 8
    kv_store = KVCacheStore(
        model.init_decode_state(2, steps, start_pos=0),
        hot_window=3, block_len=2,
    )
    tstep = make_kv_tiered_serve_step(model, params, kv_store)
    su = model.init_decode_state(2, steps, start_pos=0)
    for _ in range(steps):
        lu, su = step(params, su, toks)
        lt = tstep(toks)
        assert np.asarray(lu).tobytes() == np.asarray(lt).tobytes()
    assert kv_store.peak_hot_positions <= kv_store.hot_window + kv_store.block_len
    print(f"KV-cache tier: {kv_store.n_cold_blocks} cold blocks/layer at "
          f"{100 * kv_store.cold_comp_bytes / max(kv_store.cold_raw_bytes, 1):.1f}% "
          "of raw, logits bit-identical ✓")

    # 12. Device-resident payload feed + per-tile decode: the last host
    # bounce goes away.  payload_feed=True parses every layer's ZNN1
    # payload ONCE at store build — CRC/cursor integrity checked up front,
    # packed Huffman words uploaded to device memory once — and each ring
    # decode re-runs the fused decoder straight from those resident
    # buffers: zero host→device payload traffic per token after warmup
    # (device_entropy's transfer counters are the proof hook).  tiles=2
    # additionally splits each layer into contiguous tensor-groups, so the
    # first group is compute-ready before the layer's last tensor decodes
    # and residency is accounted per tile slot (≤ ring × tiles).  Both are
    # wall-clock/memory knobs only: logits stay bit-identical.
    from repro.core import device_entropy

    feed_store = CompressedParamStore.from_params(params, payload_feed=True)
    fstep = make_compressed_serve_step(model, feed_store, ring=2, tiles=2)
    sc = model.init_decode_state(2, 4, start_pos=0)
    sd = model.init_decode_state(2, 4, start_pos=0)
    _, sc = fstep(sc, toks)                      # warmup: compile + first ring
    _, sd = step(params, sd, toks)
    device_entropy.reset_transfer_stats()
    for _ in range(3):
        lc, sc = fstep(sc, toks)
        ld, sd = step(params, sd, toks)
        assert np.asarray(lc).tobytes() == np.asarray(ld).tobytes()
    assert device_entropy.transfer_stats()["payload_uploads"] == 0
    assert feed_store.peak_resident <= 2 * 2     # ring × tiles tile slots
    print(f"payload feed: {feed_store.device_payload_bytes / 1e3:.0f} kB "
          "resident payloads, 0 per-token uploads, per-tile ring logits "
          "bit-identical ✓")

    # The byte-identity contract demonstrated above is also enforced
    # statically: `python -m repro.analysis --strict` (zipnn-lint) checks
    # determinism, knob threading, the container spec and the Pallas kernel
    # contracts on every PR — rule catalog in docs/INVARIANTS.md.


if __name__ == "__main__":
    main()
