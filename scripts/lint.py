#!/usr/bin/env python3
"""zipnn-lint CI entry point — thin wrapper over ``python -m repro.analysis``.

Exists so the lint gate runs identically from scripts/ci.sh, the dedicated
lint workflow job, and a bare checkout without PYTHONPATH set up:

    python scripts/lint.py --strict

The analyzer is pure stdlib (no jax/numpy import), so this runs on a bare
Python — the CI lint job skips dependency installation entirely.  GitHub
``::error file=...`` annotations are auto-emitted when GITHUB_ACTIONS is
set (see repro.analysis.driver).
"""

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.driver import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--root", REPO] + sys.argv[1:]))
