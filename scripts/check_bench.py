#!/usr/bin/env python
"""Bench-regression gate: compare a fresh ``BENCH_table3_smoke.json``
against the checked-in baseline (``benchmarks/baselines/table3_smoke.json``).

Two classes of check, matching what the numbers actually guarantee:

* **Compression ratio** (``comp_pct``) — deterministic: blobs are
  byte-identical across threads/backends, so the ratio must match the
  baseline **exactly**.  A drift means the encoder's output changed — the
  same class of regression the golden fixtures guard, caught here for the
  bench corpus.
* **Throughput** (``comp_gbps`` / ``decomp_gbps``) — machine-dependent:
  gated with a slack factor (current ≥ baseline / slack).  The default
  slack is generous because CI runners are noisy and heterogeneous; it
  still catches order-of-magnitude cliffs (an accidentally-serialized
  pool, an interpret-mode kernel on the host path, a quadratic probe).
  Rows whose baseline throughput is null/0 are skipped, as are device
  rows' timings (interpret-mode artifacts, flagged ``parity`` rows keep
  only their ratio check).

``--update-baseline`` copies the current results over the baseline —
run it (and commit the diff) when a deliberate change shifts the numbers.

Exit status: 0 = within gate, 1 = regression, 2 = bad invocation/files.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_CURRENT = os.path.join(REPO, "BENCH_table3_smoke.json")
DEFAULT_BASELINE = os.path.join(REPO, "benchmarks", "baselines", "table3_smoke.json")
DEFAULT_SLACK = 4.0


def _key(row: dict) -> tuple:
    return (row.get("model"), row.get("method"))


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def compare(current: dict, baseline: dict, slack: float) -> list:
    """Return a list of human-readable regression strings (empty ⇒ pass)."""
    problems = []
    cur_rows = {_key(r): r for r in current.get("rows", [])}
    base_rows = {_key(r): r for r in baseline.get("rows", [])}

    missing = sorted(set(base_rows) - set(cur_rows))
    for k in missing:
        problems.append(f"row missing from current results: {k[0]} / {k[1]}")
    extra = sorted(set(cur_rows) - set(base_rows))
    for k in extra:
        # New rows are not a regression, but flag them so the baseline gets
        # refreshed deliberately (--update-baseline) instead of rotting.
        print(f"note: new row not in baseline (update it): {k[0]} / {k[1]}")

    for k in sorted(set(cur_rows) & set(base_rows)):
        cur, base = cur_rows[k], base_rows[k]
        label = f"{k[0]} / {k[1]}"
        if cur.get("comp_pct") != base.get("comp_pct"):
            problems.append(
                f"{label}: ratio changed {base.get('comp_pct')} -> "
                f"{cur.get('comp_pct')} (must match exactly: blobs are "
                f"deterministic)"
            )
        if "interpret-mode" in (base.get("note") or ""):
            continue                     # device-row timings are artifacts
        for field in ("comp_gbps", "decomp_gbps"):
            b, c = base.get(field), cur.get(field)
            if not b:                    # baseline null / 0: unmeasured row
                continue
            if not c:
                # A falsy *current* value against a measured baseline IS the
                # regression (rounded-to-zero throughput = a >1000x cliff).
                problems.append(
                    f"{label}: {field} missing/zero in current results "
                    f"(baseline {b:.3f} GB/s)"
                )
            elif c < b / slack:
                problems.append(
                    f"{label}: {field} {c:.3f} GB/s < baseline {b:.3f} / "
                    f"slack {slack:g} = {b / slack:.3f}"
                )
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=DEFAULT_CURRENT,
                    help="fresh bench JSON written by scripts/ci.sh")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="checked-in baseline JSON")
    ap.add_argument("--slack", type=float,
                    default=float(os.environ.get("BENCH_SLACK", DEFAULT_SLACK)),
                    help="throughput slack factor (env BENCH_SLACK overrides "
                         "the default, flag overrides both)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="copy current results over the baseline and exit")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"error: current bench results not found: {args.current}")
        return 2
    if args.update_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return 0
    if not os.path.exists(args.baseline):
        print(
            f"error: baseline not found: {args.baseline}\n"
            f"seed it with: python scripts/check_bench.py --update-baseline"
        )
        return 2

    current, baseline = _load(args.current), _load(args.baseline)
    problems = compare(current, baseline, args.slack)
    if problems:
        print("BENCH REGRESSION:")
        baseline_rel = os.path.relpath(args.baseline, REPO)
        for p in problems:
            print(f"  - {p}")
            if os.environ.get("GITHUB_ACTIONS"):
                # clickable annotation on the checked-in baseline in the PR
                msg = p.replace("%", "%25").replace("\n", "%0A")
                print(f"::error file={baseline_rel},"
                      f"title=bench regression::{msg}")
        print(
            "If this shift is deliberate, refresh with:\n"
            "    python scripts/check_bench.py --update-baseline   # then commit"
        )
        return 1
    n = len(current.get("rows", []))
    print(f"bench gate OK: {n} rows, ratios exact, throughput within "
          f"{args.slack:g}x slack")
    return 0


if __name__ == "__main__":
    sys.exit(main())
