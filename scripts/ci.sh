#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md). Extra pytest args pass through:
#   scripts/ci.sh -k engine          # extra filters compose with the split
#   scripts/ci.sh -m "not slow"      # caller-supplied -m replaces the split
#
# ZIPNN_CI_SUITE selects which half runs (the GitHub Actions matrix splits
# the fast and slow suites into separate jobs — see .github/workflows/ci.yml):
#   lint  zipnn-lint only (pure-stdlib static analysis — no jax needed)
#   fast  zipnn-lint + pytest -m "not slow" + parity smoke +
#         fixture-staleness check + bench smoke + bench-regression gate
#   slow  pytest -m "slow" only (the heavyweight fuzz/property sweeps)
#   all   both, fast first (default — the local pre-push check)
set -euo pipefail
cd "$(dirname "$0")/.."

SUITE="${ZIPNN_CI_SUITE:-all}"
case "$SUITE" in
  lint|fast|slow|all) ;;
  *) echo "error: ZIPNN_CI_SUITE must be lint|fast|slow|all (got '$SUITE')" >&2; exit 2 ;;
esac

# zipnn-lint: the static invariant gate (determinism, knob threading,
# container spec, kernel contracts — docs/INVARIANTS.md).  First and
# blocking: it runs in milliseconds and catches the bug classes the
# runtime suites only sample.  The slow split skips it (its fast sibling
# already ran it).
if [[ "$SUITE" != "slow" ]]; then
  python scripts/lint.py --strict
fi
if [[ "$SUITE" == "lint" ]]; then
  exit 0
fi

# Fast suite first (fail fast on logic errors), then the slow split: the
# heavyweight fuzz/property sweeps (dense corruption flips, the full
# dtype × shape × payload × backend × threads parity sweep) run separately
# so a quick red signal never waits behind them.  A caller-supplied -m
# takes over marker selection entirely — pytest's last -m wins, so adding
# our own would silently override the caller's.
if [[ " $* " == *" -m"* ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
else
  if [[ "$SUITE" != "slow" ]]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q -m "not slow" "$@"
  fi
  if [[ "$SUITE" != "fast" ]]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m "slow" "$@"
  fi
fi

if [[ "$SUITE" == "slow" ]]; then
  exit 0
fi

# Fixture-staleness gate: regenerate the golden fixtures in memory and
# byte-compare against the checked-in blobs, so encoder drift is caught at
# PR time with a named diff instead of a downstream golden-test failure.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python tests/fixtures/generate_fixtures.py --check

# Decode-backend parity smoke: host vs device × threads 1 vs 4 through the
# shared harness (tests/parity.py), including the golden-blob fixtures and
# the device entropy stage on BOTH sides: the fused Huffman bit-pack on
# encode, and the device Huffman decoder kernel on decode (every sweep row
# and golden fixture also decodes with entropy_backend=device, asserted
# bit-exact against the raw bytes).  The payload-resident rows decode each
# eligible stream through the parse-once ArrayFeed and assert bit equality
# plus zero per-decode payload uploads.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python tests/parity.py --smoke

# Fast host/device backend parity smoke: small corpus through the Table 3
# sweep; asserts device blobs byte-identical to host blobs (including the
# full-device plane+entropy path) AND device decode — plane consumer and
# device-entropy decoder rows alike — bit-identical to the raw bytes
# (interpret mode on CPU-only hosts) and writes the result JSON.  The
# serve rows double as the serving smokes: ring logits bit-identical and
# residency <= 2 layers; the payload-feed rows rerun the ring with the
# compressed payloads resident in device memory (whole-layer and per-tile)
# and assert zero per-token payload uploads after warmup; and the KV-cache
# tier (serve/kvcache.py) decodes in lockstep with logits asserted
# bit-identical to the untiered step.
# The component rows pin the KV/moment/fp8/int8 payload ratios.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.table3_speed \
    --backend both --n 120000 --json BENCH_table3_smoke.json

# Bench-regression gate: ratios must match the checked-in baseline exactly
# (blobs are deterministic); throughput within a slack factor (BENCH_SLACK
# env overrides).  Refresh deliberately with --update-baseline.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python scripts/check_bench.py
