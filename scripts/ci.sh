#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md). Extra pytest args pass through:
#   scripts/ci.sh -k engine          # extra filters compose with the split
#   scripts/ci.sh -m "not slow"      # caller-supplied -m replaces the split
set -euo pipefail
cd "$(dirname "$0")/.."

# Fast suite first (fail fast on logic errors), then the slow split: the
# heavyweight fuzz/property sweeps (dense corruption flips, the full
# dtype × shape × payload × backend × threads parity sweep) run separately
# so a quick red signal never waits behind them.  A caller-supplied -m
# takes over marker selection entirely — pytest's last -m wins, so adding
# our own would silently override the caller's.
if [[ " $* " == *" -m"* ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
else
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q -m "not slow" "$@"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m "slow" "$@"
fi

# Decode-backend parity smoke: host vs device × threads 1 vs 4 through the
# shared harness (tests/parity.py), including the golden-blob fixtures.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python tests/parity.py --smoke

# Fast host/device backend parity smoke: small corpus through the Table 3
# sweep; asserts device blobs byte-identical to host blobs AND device
# decode bit-identical to the raw bytes (interpret mode on CPU-only hosts)
# and writes the result JSON.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.table3_speed \
    --backend both --n 120000 --json BENCH_table3_smoke.json
