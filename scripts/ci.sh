#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md). Extra pytest args pass through:
#   scripts/ci.sh -m "not slow"
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

# Fast host/device backend parity smoke: small corpus through the Table 3
# sweep; asserts device blobs byte-identical to host blobs (interpret mode
# on CPU-only hosts) and writes the result JSON.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.table3_speed \
    --backend both --n 120000 --json BENCH_table3_smoke.json
