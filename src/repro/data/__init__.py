from .pipeline import DataConfig, make_batch, batch_specs, data_stream

__all__ = ["DataConfig", "make_batch", "batch_specs", "data_stream"]
