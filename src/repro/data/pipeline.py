"""Deterministic synthetic data pipeline.

Every batch is a pure function of ``(arch, shape, step)`` via a counter-mode
PRNG — that determinism is a fault-tolerance primitive: after a node failure
or elastic re-shard, *any* host can regenerate *any* global batch shard with
no data-service coordination, and stragglers can be re-issued elsewhere
(DESIGN.md §5).  Token streams follow a Zipf law over the vocab so CE curves
behave like text rather than uniform noise.

``batch_specs`` returns ShapeDtypeStructs for the dry-run (shannon/kernels
pattern: weak-type-correct, shardable, no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2            # token distribution skew
    vlm_img_frac: float = 0.25     # fraction of the sequence that is patches


def _vlm_split(cfg: ModelConfig, dc: DataConfig):
    s_img = max(int(dc.seq_len * dc.vlm_img_frac) // 4 * 4, 4)
    return s_img, dc.seq_len - s_img


def batch_specs(cfg: ModelConfig, dc: DataConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Dry-run stand-ins for one global training batch."""
    B, S = dc.global_batch, dc.seq_len
    i32 = jnp.int32
    if cfg.family == "vlm":
        s_img, s_txt = _vlm_split(cfg, dc)
        return {
            "tokens": jax.ShapeDtypeStruct((B, s_txt), i32),
            "patches": jax.ShapeDtypeStruct((B, s_img, cfg.frontend_dim), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, s_txt), i32),
            "pos_thw": jax.ShapeDtypeStruct((B, S, 3), i32),
        }
    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> Dict[str, Any]:
    """Materialize the global batch for ``step`` (host numpy, deterministic)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, hash(cfg.name) & 0x7FFFFFFF])
    )
    B, S = dc.global_batch, dc.seq_len

    def zipf_tokens(shape):
        # zipf over vocab, clipped; cheap + heavy-tailed like text
        z = rng.zipf(dc.zipf_a, size=shape)
        return np.minimum(z - 1, cfg.vocab_size - 1).astype(np.int32)

    if cfg.family == "vlm":
        s_img, s_txt = _vlm_split(cfg, dc)
        toks = zipf_tokens((B, s_txt))
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        # stub M-RoPE positions: a h×w grid for patches, then text continues
        g = int(np.sqrt(s_img))
        hh, ww = np.meshgrid(np.arange(g), np.arange(g), indexing="ij")
        grid = np.stack([np.zeros_like(hh), hh, ww], -1).reshape(-1, 3)
        grid = np.resize(grid, (s_img, 3))
        txt0 = grid[:, 1].max() + 1
        tpos = txt0 + np.arange(s_txt)
        txt = np.stack([tpos, tpos, tpos], -1)
        pos = np.concatenate([grid, txt], 0)
        return {
            "tokens": jnp.asarray(toks),
            "patches": jnp.asarray(
                rng.standard_normal((B, s_img, cfg.frontend_dim)) * 0.5, jnp.bfloat16
            ),
            "labels": jnp.asarray(labels),
            "pos_thw": jnp.asarray(np.broadcast_to(pos[None], (B, S, 3)).copy(), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "frames": jnp.asarray(
                rng.standard_normal((B, S, cfg.frontend_dim)) * 0.5, jnp.bfloat16
            ),
            "labels": jnp.asarray(zipf_tokens((B, S))),
        }
    toks = zipf_tokens((B, S + 1))
    return {
        "tokens": jnp.asarray(toks[:, :-1]),
        "labels": jnp.asarray(toks[:, 1:]),
    }


def data_stream(
    cfg: ModelConfig, dc: DataConfig, start_step: int = 0
) -> Iterator[Dict[str, Any]]:
    """Resumable stream: restart at any step and get identical batches."""
    step = start_step
    while True:
        yield make_batch(cfg, dc, step)
        step += 1
