"""Transformer / Mamba block composition: init + train apply + decode apply.

Blocks are pure functions over plain-dict params so layer stacks can be
jax.vmap-initialized and lax.scan-applied (bounded compile time at 60–81
layers).  Every block returns ``(x, aux)`` in training (aux = MoE load
balancing loss, 0 elsewhere).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention, layers, moe, ssm

Array = jax.Array


def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    return (layers.init_layernorm if cfg.norm == "layernorm" else layers.init_rmsnorm)(
        d, cfg.dtype
    )


def norm_apply(cfg, p, x):
    fn = layers.layernorm if cfg.norm == "layernorm" else layers.rmsnorm
    return fn(p, x, cfg.norm_eps)


def _mlp_init(cfg, key, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    if cfg.mlp == "gelu":
        return layers.init_gelu_mlp(key, cfg.d_model, d_ff, cfg.dtype)
    return layers.init_swiglu(key, cfg.d_model, d_ff, cfg.dtype)


def mlp_apply(cfg, p, x):
    return (layers.gelu_mlp if cfg.mlp == "gelu" else layers.swiglu)(p, x)


# ---------------------------------------------------------------------------
# dense transformer block (GQA or MLA attention + MLP)
# ---------------------------------------------------------------------------

def init_dense_block(key, cfg, d_ff=None):
    k1, k2 = jax.random.split(key)
    attn_init = attention.init_mla if cfg.mla else attention.init_gqa
    return {
        "attn_norm": _norm_init(cfg),
        "attn": attn_init(k1, cfg),
        "mlp_norm": _norm_init(cfg),
        "mlp": _mlp_init(cfg, k2, d_ff),
    }


def dense_block_train(p, x, cfg, positions, pos_thw=None):
    h = norm_apply(cfg, p["attn_norm"], x)
    if cfg.mla:
        a = attention.mla_train(p["attn"], h, cfg, positions)
    else:
        a = attention.gqa_train(p["attn"], h, cfg, positions, pos_thw)
    x = x + a
    h = norm_apply(cfg, p["mlp_norm"], x)
    x = x + mlp_apply(cfg, p["mlp"], h)
    return x, jnp.zeros((), jnp.float32)


def dense_block_decode(p, x, caches, pos, cfg):
    h = norm_apply(cfg, p["attn_norm"], x)
    if cfg.mla:
        a, ckv, kr = attention.mla_decode(p["attn"], h, caches[0], caches[1], pos, cfg)
        new_caches = (ckv, kr)
    else:
        a, ck, cv = attention.gqa_decode(p["attn"], h, caches[0], caches[1], pos, cfg)
        new_caches = (ck, cv)
    x = x + a
    h = norm_apply(cfg, p["mlp_norm"], x)
    x = x + mlp_apply(cfg, p["mlp"], h)
    return x, new_caches


# ---------------------------------------------------------------------------
# MoE block (attention + routed experts)
# ---------------------------------------------------------------------------

def init_moe_block(key, cfg):
    k1, k2 = jax.random.split(key)
    attn_init = attention.init_mla if cfg.mla else attention.init_gqa
    return {
        "attn_norm": _norm_init(cfg),
        "attn": attn_init(k1, cfg),
        "mlp_norm": _norm_init(cfg),
        "moe": moe.init_moe(k2, cfg),
    }


def moe_block_train(p, x, cfg, positions, pos_thw=None):
    h = norm_apply(cfg, p["attn_norm"], x)
    if cfg.mla:
        a = attention.mla_train(p["attn"], h, cfg, positions)
    else:
        a = attention.gqa_train(p["attn"], h, cfg, positions, pos_thw)
    x = x + a
    h = norm_apply(cfg, p["mlp_norm"], x)
    y, aux = moe.moe_apply(p["moe"], h, cfg)
    return x + y, aux


def moe_block_decode(p, x, caches, pos, cfg):
    h = norm_apply(cfg, p["attn_norm"], x)
    if cfg.mla:
        a, c0, c1 = attention.mla_decode(p["attn"], h, caches[0], caches[1], pos, cfg)
    else:
        a, c0, c1 = attention.gqa_decode(p["attn"], h, caches[0], caches[1], pos, cfg)
    x = x + a
    h = norm_apply(cfg, p["mlp_norm"], x)
    y, _ = moe.moe_apply(p["moe"], h, cfg)
    return x + y, (c0, c1)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def init_mamba_block(key, cfg):
    return {"norm": _norm_init(cfg), "mamba": ssm.init_mamba2(key, cfg)}


def mamba_block_train(p, x, cfg, positions=None, pos_thw=None):
    h = norm_apply(cfg, p["norm"], x)
    return x + ssm.mamba2_train(p["mamba"], h, cfg), jnp.zeros((), jnp.float32)


def mamba_block_decode(p, x, caches, pos, cfg):
    h = norm_apply(cfg, p["norm"], x)
    y, state, conv = ssm.mamba2_decode(p["mamba"], h, caches[0], caches[1], cfg)
    return x + y, (state, conv)
