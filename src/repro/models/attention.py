"""Attention: GQA (full / sliding-window), chunked online-softmax for long
sequences, decode-step with KV cache, and MLA (DeepSeek-V2 latent attention).

Memory strategy: training/prefill always run the chunked (flash-style)
double-scan — scores never materialize beyond (q_block × kv_block) per
step — so 32 k prefill fits without attention kernels; decode computes
one-row attention against the cache (linear in cache length).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers

Array = jax.Array
NEG_INF = -1e30


def init_gqa(key, cfg):
    """cfg: needs d_model, n_heads, n_kv_heads, head_dim, qkv_bias."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    p = {
        "wq": layers.init_dense(kq, d, cfg.n_heads * hd, cfg.dtype, bias=cfg.qkv_bias),
        "wk": layers.init_dense(kk, d, cfg.n_kv_heads * hd, cfg.dtype, bias=cfg.qkv_bias),
        "wv": layers.init_dense(kv, d, cfg.n_kv_heads * hd, cfg.dtype, bias=cfg.qkv_bias),
        "wo": layers.init_dense(ko, cfg.n_heads * hd, d, cfg.dtype),
    }
    return p


def _qkv(p, x, cfg, positions, pos_thw=None):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = layers.dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = layers.dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = layers.dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.mrope and pos_thw is not None:
        q = layers.apply_mrope(q, pos_thw, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, pos_thw, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


import functools


def _block_mask(qpos, kpos, S, causal, window):
    mask = kpos[None, :] < S                       # padding
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    return mask


def _flash_fwd_impl(q, k, v, S, causal, window, q_block, kv_block):
    """Returns (out (nq,B,G,rep,qb,hdv), lse (nq,B,G,rep,qb))."""
    B, Sq, H, hd = q.shape
    G = k.shape[2]
    hd_v = v.shape[-1]
    rep = H // G
    nq, nk = Sq // q_block, k.shape[1] // kv_block
    scale = hd ** -0.5
    qr = q.reshape(B, nq, q_block, G, rep, hd)
    kr = k.reshape(B, nk, kv_block, G, hd)
    vr = v.reshape(B, nk, kv_block, G, hd_v)

    def q_step(_, qi):
        qb = qr[:, qi] * scale
        qpos = qi * q_block + jnp.arange(q_block)

        def kv_step(carry, kj):
            m, l, acc = carry
            kb, vb = kr[:, kj], vr[:, kj]
            kpos = kj * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb,
                           preferred_element_type=jnp.float32)
            mask = _block_mask(qpos, kpos, S, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, rep, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, rep, q_block), jnp.float32)
        a0 = jnp.zeros((B, G, rep, q_block, hd_v), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))   # (B,G,rep,qb)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    return outs, lses


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_core(q, k, v, S, causal, window, q_block, kv_block):
    outs, _ = _flash_fwd_impl(q, k, v, S, causal, window, q_block, kv_block)
    return outs


def _flash_core_fwd(q, k, v, S, causal, window, q_block, kv_block):
    outs, lses = _flash_fwd_impl(q, k, v, S, causal, window, q_block, kv_block)
    return outs, (q, k, v, outs, lses)


def _flash_core_bwd(S, causal, window, q_block, kv_block, res, douts):
    """FlashAttention-2-style backward: recompute block probabilities from
    the saved logsumexp instead of storing O(nq*nk*qb*kb) probability and
    mask tensors (observed ~10 GiB/layer at 4k before this)."""
    q, k, v, outs, lses = res
    B, Sq, H, hd = q.shape
    G = k.shape[2]
    hd_v = v.shape[-1]
    rep = H // G
    nq, nk = Sq // q_block, k.shape[1] // kv_block
    scale = hd ** -0.5
    qr = q.reshape(B, nq, q_block, G, rep, hd)
    kr = k.reshape(B, nk, kv_block, G, hd)
    vr = v.reshape(B, nk, kv_block, G, hd_v)
    # D_i = rowsum(dout * out): (nq, B, G, rep, qb)
    delta = jnp.sum(douts.astype(jnp.float32) * outs.astype(jnp.float32), axis=-1)

    def kv_step(dq_acc, kj):
        kb, vb = kr[:, kj], vr[:, kj]
        kpos = kj * kv_block + jnp.arange(kv_block)

        def q_step(carry, qi):
            dk_j, dv_j = carry
            qb = qr[:, qi] * scale
            qpos = qi * q_block + jnp.arange(q_block)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb,
                           preferred_element_type=jnp.float32)
            mask = _block_mask(qpos, kpos, S, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lses[qi][..., None])             # (B,G,rep,qb,kb)
            do = douts[qi].astype(jnp.float32)               # (B,G,rep,qb,hdv)
            dv_blk = jnp.einsum("bgrqk,bgrqd->bkgd", p, do)
            dp = jnp.einsum("bgrqd,bkgd->bgrqk", do, vb.astype(jnp.float32))
            ds = p * (dp - delta[qi][..., None])
            dq_blk = jnp.einsum("bgrqk,bkgd->bqgrd", ds,
                                kb.astype(jnp.float32)) * scale
            dk_blk = jnp.einsum("bgrqk,bqgrd->bkgd", ds,
                                qr[:, qi].astype(jnp.float32)) * scale
            return (dk_j + dk_blk, dv_j + dv_blk), dq_blk

        z_dk = jnp.zeros((B, kv_block, G, hd), jnp.float32)
        z_dv = jnp.zeros((B, kv_block, G, hd_v), jnp.float32)
        (dk_j, dv_j), dq_contrib = jax.lax.scan(
            q_step, (z_dk, z_dv), jnp.arange(nq)
        )
        return dq_acc + dq_contrib, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, q_block, G, rep, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, G * rep, hd)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nk * kv_block, G, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nk * kv_block, G, hd_v)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
) -> Array:
    """Chunked online-softmax attention with a FlashAttention-2-style
    custom VJP (backward recomputes probabilities blockwise from the saved
    logsumexp; plain autodiff of the double scan saves O(S^2/blocks)
    probability/mask tensors).

    q: (B, S, H, hd); k: (B, S, G, hd); v: (B, S, G, hd_v) with H % G == 0
    (hd_v may differ from hd - MLA has 192-dim qk, 128-dim v).
    window > 0 = sliding-window attention (causal, kpos > qpos - window).
    """
    B, S, H, hd = q.shape
    hd_v = v.shape[-1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    pad_q = (-S) % q_block
    pad_k = (-S) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq = S + pad_q
    outs = _flash_core(q, k, v, S, causal, window, q_block, kv_block)
    # outs: (nq, B, G, rep, qb, hd_v) -> (B, S, H, hd_v)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, H, Sq, hd_v)[:, :, :S]
    return jnp.moveaxis(out, 1, 2)


def dense_attention(
    q: Array, k: Array, v: Array, *, causal: bool, window: int = 0
) -> Array:
    """Unblocked masked attention — flop-identical to the masked flash path
    (every S×S block is computed there too), with all einsums outside any
    scan.  Used by the dry-run's accounting variant, where lax.scan bodies
    would be cost-counted once (see launch/dryrun.py)."""
    B, S, H, hd = q.shape
    G = k.shape[2]
    rep = H // G
    qr = q.reshape(B, S, G, rep, hd) * hd ** -0.5
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qr, k, preferred_element_type=jnp.float32)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgrqk,bkgd->bqgrd", w.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
    return out.reshape(B, S, H, v.shape[-1])


def _attend(q, k, v, cfg, *, causal):
    if cfg.attn_impl == "dense":
        return dense_attention(q, k, v, causal=causal, window=cfg.window)
    return flash_attention(
        q, k, v, causal=causal, window=cfg.window,
        q_block=cfg.q_block, kv_block=cfg.kv_block,
    )


def gqa_train(p, x, cfg, positions, pos_thw=None) -> Array:
    q, k, v = _qkv(p, x, cfg, positions, pos_thw)
    out = _attend(q, k, v, cfg, causal=not cfg.encoder_only)
    B, S = x.shape[:2]
    return layers.dense(p["wo"], out.reshape(B, S, -1))


class KVCache(NamedTuple):
    k: Array      # (B, L, G, hd)
    v: Array      # (B, L, G, hd)


def init_kv_cache(cfg, batch: int, length: int, n_layers: int) -> KVCache:
    shape = (n_layers, batch, length, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, jnp.bfloat16), jnp.zeros(shape, jnp.bfloat16))


def gqa_decode(p, x, cache_k, cache_v, pos, cfg) -> Tuple[Array, Array, Array]:
    """One-token decode. x: (B, 1, D); cache_[kv]: (B, L, G, hd); pos: int32[].

    Returns (out (B, 1, D), k_new (B, 1, G, hd), v_new) — the cache itself
    is READ-ONLY here; the caller writes all layers' new-token slots with a
    single dynamic_update_slice outside the layer scan.  (Threading the
    multi-GiB cache stacks through scan ys made XLA materialize f32 copies
    of the whole cache — §Perf cell 2.)  The new token attends to itself via
    an explicit extra score column; a ring buffer wraps at L (= window for
    SWA archs), and the stale slot being replaced is masked out.
    """
    B, _, _ = x.shape
    hd = cfg.head_dim
    L = cache_k.shape[1]
    q = layers.dense(p["wq"], x).reshape(B, 1, cfg.n_heads, hd)
    k = layers.dense(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    v = layers.dense(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.use_rope:
        posb = jnp.full((B, 1), pos, jnp.int32)
        q = layers.apply_rope(q, posb, cfg.rope_theta)
        k = layers.apply_rope(k, posb, cfg.rope_theta)

    from repro.distributed.sharding import axis_size, lshard

    G = cfg.n_kv_heads
    rep = cfg.n_heads // G
    slot = (pos % L).astype(jnp.int32)
    qr = q.reshape(B, G, rep, hd) * hd ** -0.5
    # Score/context constraints must MATCH the cache layout
    # (serve.decode_state_specs): kv-head-sharded when G divides the model
    # axis, else cache-length-sharded.  A mismatched constraint makes GSPMD
    # "involuntarily rematerialize" (all-gather) the whole cache per layer
    # (§Perf cell 2).
    g_sharded = G % max(axis_size("model"), 1) == 0
    s = jnp.einsum("bgrd,blgd->bgrl", qr, cache_k, preferred_element_type=jnp.float32)
    if g_sharded:
        s = lshard(s, "batch", "kv_heads", None, None)
    else:
        s = lshard(s, "batch", None, None, "seq_sp")
    s_self = jnp.einsum("bgrd,bogd->bgro", qr, k, preferred_element_type=jnp.float32)
    idx = jnp.arange(L)
    written = jnp.where(pos >= L, idx != slot, idx < pos)
    s = jnp.where(written[None, None, None, :], s, NEG_INF)
    lse_c = jax.nn.logsumexp(s, axis=-1, keepdims=True)
    lse = jnp.logaddexp(lse_c, jax.nn.logsumexp(s_self, axis=-1, keepdims=True))
    w_cache = jnp.exp(s - lse)
    w_self = jnp.exp(s_self - lse)
    ctx = jnp.einsum(
        "bgrl,blgd->bgrd", w_cache.astype(cache_v.dtype), cache_v,
        preferred_element_type=jnp.float32,
    )
    ctx = lshard(ctx, "batch", "kv_heads" if g_sharded else None, None, None)
    ctx = ctx + jnp.einsum(
        "bgro,bogd->bgrd", w_self.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    out = layers.dense(p["wo"], ctx.reshape(B, 1, cfg.n_heads * hd).astype(x.dtype))
    return out, k.astype(cache_k.dtype), v.astype(cache_v.dtype)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2 §2.1)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    p = {
        "w_dq": layers.init_dense(ks[0], d, cfg.q_lora_rank, cfg.dtype),
        "q_norm": layers.init_rmsnorm(cfg.q_lora_rank, cfg.dtype),
        "w_uq": layers.init_dense(
            ks[1], cfg.q_lora_rank, H * (cfg.qk_nope_dim + cfg.qk_rope_dim), cfg.dtype
        ),
        "w_dkv": layers.init_dense(ks[2], d, cfg.kv_lora_rank, cfg.dtype),
        "kv_norm": layers.init_rmsnorm(cfg.kv_lora_rank, cfg.dtype),
        "w_uk": layers.init_dense(ks[3], cfg.kv_lora_rank, H * cfg.qk_nope_dim, cfg.dtype),
        "w_uv": layers.init_dense(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim, cfg.dtype),
        "w_kr": layers.init_dense(ks[5], d, cfg.qk_rope_dim, cfg.dtype),
        "wo": layers.init_dense(ks[6], H * cfg.v_head_dim, d, cfg.dtype),
    }
    return p


def _mla_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = layers.rmsnorm(p["q_norm"], layers.dense(p["w_dq"], x))
    q = layers.dense(p["w_uq"], cq).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    from repro.distributed.sharding import lshard

    c_kv = layers.rmsnorm(p["kv_norm"], layers.dense(p["w_dkv"], x))   # (B,S,r)
    c_kv = lshard(c_kv, "batch", None, None)       # latent replicated over TP
    k_rope = layers.dense(p["w_kr"], x).reshape(B, S, 1, dr)
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)      # shared head
    k_nope = layers.dense(p["w_uk"], c_kv).reshape(B, S, H, dn)
    val = layers.dense(p["w_uv"], c_kv).reshape(B, S, H, dv)
    # pin head sharding through attention: the up-projections' outputs are
    # H-sharded (column-parallel); without the constraints GSPMD mixes
    # H-sharded and SP-seq-sharded layouts in backward and materializes
    # (B,H,r,S)-sized f32 reshard buffers (§Perf cell 1)
    k_nope = lshard(k_nope, "batch", None, "heads", None)
    val = lshard(val, "batch", None, "heads", None)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_full = lshard(q_full, "batch", None, "heads", None)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1
    )
    return q_full, k_full, val, c_kv, k_rope


def mla_train(p, x, cfg, positions) -> Array:
    q, k, v, _, _ = _mla_qkv(p, x, cfg, positions)
    out = _attend(q, k, v, cfg, causal=True)
    B, S = x.shape[:2]
    return layers.dense(p["wo"], out.reshape(B, S, -1))


def init_mla_cache(cfg, batch: int, length: int, n_layers: int):
    """MLA caches the compressed latent + shared rope key — the whole point
    of MLA is this tiny cache: (kv_lora + rope) per token vs 2·H·hd."""
    return {
        "c_kv": jnp.zeros((n_layers, batch, length, cfg.kv_lora_rank), jnp.bfloat16),
        "k_rope": jnp.zeros((n_layers, batch, length, cfg.qk_rope_dim), jnp.bfloat16),
    }


def mla_decode(p, x, c_kv_cache, k_rope_cache, pos, cfg):
    """Absorbed-matmul decode: scores/context via the latent space directly.

    x: (B, 1, D); c_kv_cache: (B, L, r); k_rope_cache: (B, L, dr).
    Cache is read-only; returns (out, c_kv_new (B,1,r), k_rope_new (B,1,dr))
    for the caller's single out-of-scan slot write (see gqa_decode).
    """
    B = x.shape[0]
    H, dn, dr, dv, r = (
        cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    )
    L = c_kv_cache.shape[1]
    cq = layers.rmsnorm(p["q_norm"], layers.dense(p["w_dq"], x))
    q = layers.dense(p["w_uq"], cq).reshape(B, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posb = jnp.full((B, 1), pos, jnp.int32)
    q_rope = layers.apply_rope(q_rope.reshape(B, 1, H, dr), posb, cfg.rope_theta).reshape(B, H, dr)

    c_kv_new = layers.rmsnorm(p["kv_norm"], layers.dense(p["w_dkv"], x))  # (B,1,r)
    k_rope_new = layers.apply_rope(
        layers.dense(p["w_kr"], x).reshape(B, 1, 1, dr), posb, cfg.rope_theta
    ).reshape(B, 1, dr)
    slot = (pos % L).astype(jnp.int32)

    # Absorb W_uk into the query: q_lat (B, H, r).  fp32 here: absorption
    # reassociates the train-side matmul chain, so keep the extra rounding
    # out of the (tiny) per-token absorbed products.
    w_uk = p["w_uk"]["w"].reshape(r, H, dn).astype(jnp.float32)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32), w_uk).astype(jnp.bfloat16)
    s = jnp.einsum("bhr,blr->bhl", q_lat, c_kv_cache, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhd,bld->bhl", q_rope.astype(jnp.bfloat16), k_rope_cache,
                       preferred_element_type=jnp.float32)
    s_self = jnp.einsum("bhr,bor->bho", q_lat, c_kv_new.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    s_self = s_self + jnp.einsum(
        "bhd,bod->bho", q_rope.astype(jnp.bfloat16), k_rope_new.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    from repro.distributed.sharding import lshard

    scale = (dn + dr) ** -0.5
    idx = jnp.arange(L)
    written = jnp.where(pos >= L, idx != slot, idx < pos)
    s = jnp.where(written[None, None, :], s * scale, NEG_INF)
    s = lshard(s, "batch", None, "seq_sp")        # keep length-sharded
    s_self = s_self * scale
    lse = jnp.logaddexp(
        jax.nn.logsumexp(s, axis=-1, keepdims=True),
        jax.nn.logsumexp(s_self, axis=-1, keepdims=True),
    )
    w_cache = jnp.exp(s - lse)
    w_self = jnp.exp(s_self - lse)
    ctx_lat = jnp.einsum("bhl,blr->bhr", w_cache.astype(jnp.bfloat16), c_kv_cache,
                         preferred_element_type=jnp.float32)
    ctx_lat = lshard(ctx_lat, "batch", None, None)
    ctx_lat = ctx_lat + jnp.einsum(
        "bho,bor->bhr", w_self.astype(jnp.bfloat16), c_kv_new.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    w_uv = p["w_uv"]["w"].reshape(r, H, dv).astype(jnp.bfloat16)
    ctx = jnp.einsum("bhr,rhv->bhv", ctx_lat.astype(jnp.bfloat16), w_uv)
    out = layers.dense(p["wo"], ctx.reshape(B, 1, H * dv))
    return out, c_kv_new.astype(c_kv_cache.dtype), k_rope_new.astype(k_rope_cache.dtype)
