"""Model: config → init / train-loss / forward / decode-step.

One class serves all six families (dense, moe, ssm, hybrid, vlm, audio):
layer stacks are vmap-initialized and lax.scan-applied; decode threads the
per-layer caches through the same scan.  All full-size instantiation happens
under jax.eval_shape — only reduced configs ever allocate on this host.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard, param_pspecs
from . import attention, blocks, layers

Array = jax.Array
PyTree = Any


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init

    def init(self, rng: Array) -> PyTree:
        cfg = self.cfg
        r_embed, r_stack, r_head, r_front, r_shared = jax.random.split(rng, 5)
        params: Dict[str, Any] = {}
        params["embed"] = layers.init_embedding(
            r_embed, cfg.vocab_size, cfg.d_model, cfg.dtype
        )
        if cfg.pos_embedding == "learned":
            params["pos"] = {
                "table": (
                    jax.random.normal(r_head, (cfg.max_position, cfg.d_model), jnp.float32)
                    * 0.02
                ).astype(cfg.dtype)
            }
        if cfg.frontend != "none":
            params["frontend_proj"] = layers.init_dense(
                r_front, cfg.frontend_dim, cfg.d_model, cfg.dtype
            )
        params.update(self._init_stacks(r_stack))
        params["final_norm"] = blocks._norm_init(cfg)
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.init_embedding(
                r_head, cfg.vocab_size, cfg.d_model, cfg.dtype
            )
        return params

    def _init_stacks(self, rng: Array) -> Dict[str, Any]:
        cfg = self.cfg
        if cfg.family in ("dense", "vlm", "audio"):
            keys = jax.random.split(rng, cfg.n_layers)
            return {
                "layers": jax.vmap(lambda k: blocks.init_dense_block(k, cfg))(keys)
            }
        if cfg.family == "moe":
            out: Dict[str, Any] = {}
            fk = cfg.first_k_dense
            r1, r2 = jax.random.split(rng)
            if fk:
                keys = jax.random.split(r1, fk)
                out["dense_layers"] = jax.vmap(
                    lambda k: blocks.init_dense_block(k, cfg, d_ff=cfg.dense_d_ff)
                )(keys)
            keys = jax.random.split(r2, cfg.n_layers - fk)
            out["moe_layers"] = jax.vmap(lambda k: blocks.init_moe_block(k, cfg))(keys)
            return out
        if cfg.family == "ssm":
            keys = jax.random.split(rng, cfg.n_layers)
            return {
                "layers": jax.vmap(lambda k: blocks.init_mamba_block(k, cfg))(keys)
            }
        if cfg.family == "hybrid":
            every = cfg.shared_attn_every
            n_groups = cfg.n_layers // every
            tail = cfg.n_layers - n_groups * every
            r1, r2, r3 = jax.random.split(rng, 3)
            gkeys = jax.random.split(r1, (n_groups, every))
            out = {
                "mamba_groups": jax.vmap(
                    jax.vmap(lambda k: blocks.init_mamba_block(k, cfg))
                )(gkeys),
                "shared_attn": blocks.init_dense_block(r3, cfg),
            }
            if tail:
                tkeys = jax.random.split(r2, tail)
                out["mamba_tail"] = jax.vmap(
                    lambda k: blocks.init_mamba_block(k, cfg)
                )(tkeys)
            return out
        raise ValueError(f"unknown family {cfg.family}")

    def abstract_params(self) -> PyTree:
        key = jax.random.key(0)
        return jax.eval_shape(lambda: self.init(key))

    def param_specs(self, mesh=None) -> PyTree:
        return param_pspecs(self.abstract_params(), zero3=self.cfg.zero3, mesh=mesh)

    # --------------------------------------------------------------- forward

    def _remat(self, fn):
        if self.cfg.remat == "full":
            return jax.checkpoint(fn)
        if self.cfg.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            )
        return fn

    def _sp_shard(self, x: Array) -> Array:
        """Sequence-parallel residual constraint (Megatron-SP via GSPMD):
        the per-layer saved carry shards (batch, seq) over (data, model) —
        without this, L × (B_loc·S·D) saved residuals overflow HBM on the
        deep archs.  GSPMD inserts the all-gather at attention/MLP use."""
        if self.cfg.sp:
            return lshard(x, "batch", "seq_sp", None)
        return x

    def _scan_stack(self, stack: PyTree, x: Array, apply_fn) -> Tuple[Array, Array]:
        base_fn = apply_fn

        def apply_sp(lp, h):
            h, a = base_fn(lp, h)
            return self._sp_shard(h), a

        fn = self._remat(apply_sp)
        if not self.cfg.scan_layers:
            # unrolled: the dry-run's accounting variant (cost_analysis
            # counts lax.scan bodies once — see launch/dryrun.py)
            aux = jnp.zeros((), jnp.float32)
            n = jax.tree_util.tree_leaves(stack)[0].shape[0]
            for i in range(n):
                lp = jax.tree_util.tree_map(lambda a: a[i], stack)
                x, a = fn(lp, x)
                aux = aux + a
            return x, aux

        def body(carry, lp):
            h, aux = carry
            h, a = fn(lp, h)
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
        return x, aux

    def forward(self, params: PyTree, batch: Dict[str, Array]) -> Tuple[Array, Array]:
        """Full-sequence forward. Returns (logits, aux_loss)."""
        x, aux = self._trunk(params, batch)
        cfg = self.cfg
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.unembed(head, x)
        logits = lshard(logits, "batch", None, "vocab")
        return logits, aux

    def _trunk(self, params: PyTree, batch: Dict[str, Array]) -> Tuple[Array, Array]:
        """Everything up to (and including) the final norm."""
        cfg = self.cfg
        pos_thw = None
        if cfg.family == "vlm":
            img = layers.dense(params["frontend_proj"], batch["patches"])
            txt = layers.embed(params["embed"], batch["tokens"])
            x = jnp.concatenate([img.astype(jnp.bfloat16), txt], axis=1)
            pos_thw = batch["pos_thw"]
        elif cfg.family == "audio":
            x = layers.dense(params["frontend_proj"], batch["frames"])
        else:
            x = layers.embed(params["embed"], batch["tokens"])
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        if cfg.pos_embedding == "learned":
            x = x + params["pos"]["table"][:S][None].astype(x.dtype)
        x = lshard(x, "batch", None, None)

        aux = jnp.zeros((), jnp.float32)
        if cfg.family in ("dense", "vlm", "audio"):
            x, aux = self._scan_stack(
                params["layers"],
                x,
                lambda lp, h: blocks.dense_block_train(lp, h, cfg, positions, pos_thw),
            )
        elif cfg.family == "moe":
            if "dense_layers" in params:
                x, a1 = self._scan_stack(
                    params["dense_layers"],
                    x,
                    lambda lp, h: blocks.dense_block_train(lp, h, cfg, positions),
                )
                aux = aux + a1
            x, a2 = self._scan_stack(
                params["moe_layers"],
                x,
                lambda lp, h: blocks.moe_block_train(lp, h, cfg, positions),
            )
            aux = aux + a2
        elif cfg.family == "ssm":
            x, aux = self._scan_stack(
                params["layers"],
                x,
                lambda lp, h: blocks.mamba_block_train(lp, h, cfg),
            )
        elif cfg.family == "hybrid":
            x, aux = self._hybrid_forward(params, x, positions)

        x = blocks.norm_apply(cfg, params["final_norm"], x)
        return x, aux

    def _hybrid_forward(self, params, x, positions):
        cfg = self.cfg
        shared = params["shared_attn"]
        mamba_fn = self._remat(
            lambda lp, h: self._sp_shard(blocks.mamba_block_train(lp, h, cfg)[0])
        )
        shared_fn = self._remat(
            lambda h: self._sp_shard(
                blocks.dense_block_train(shared, h, cfg, positions)[0]
            )
        )

        if not cfg.scan_layers:
            ng = jax.tree_util.tree_leaves(params["mamba_groups"])[0].shape[0]
            for g in range(ng):
                glp = jax.tree_util.tree_map(lambda a: a[g], params["mamba_groups"])
                ne = jax.tree_util.tree_leaves(glp)[0].shape[0]
                for i in range(ne):
                    lp = jax.tree_util.tree_map(lambda a: a[i], glp)
                    x = mamba_fn(lp, x)
                x = shared_fn(x)
            if "mamba_tail" in params:
                nt = jax.tree_util.tree_leaves(params["mamba_tail"])[0].shape[0]
                for i in range(nt):
                    lp = jax.tree_util.tree_map(lambda a: a[i], params["mamba_tail"])
                    x = mamba_fn(lp, x)
            return x, jnp.zeros((), jnp.float32)

        def group(h, glp):
            def inner(hh, lp):
                return mamba_fn(lp, hh), None

            h, _ = jax.lax.scan(inner, h, glp)
            return shared_fn(h), None

        x, _ = jax.lax.scan(group, x, params["mamba_groups"])
        if "mamba_tail" in params:
            def inner(hh, lp):
                return mamba_fn(lp, hh), None

            x, _ = jax.lax.scan(inner, x, params["mamba_tail"])
        return x, jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------------ loss

    def loss(self, params: PyTree, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
        cfg = self.cfg
        if cfg.family == "vlm":
            # VLM slices text positions out of mixed logits — small model,
            # keep the explicit-logits path.
            logits, aux = self.forward(params, batch)
            s_img = batch["patches"].shape[1]
            s_txt = batch["tokens"].shape[1]
            txt_logits = logits[:, s_img - 1 : s_img - 1 + s_txt]
            ce = layers.cross_entropy(txt_logits, batch["labels"], batch.get("mask"))
        else:
            # fused chunked unembed+CE: (B,S,V) logits never materialize
            x, aux = self._trunk(params, batch)
            head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
            labels = batch["labels"]
            mask = batch.get("mask")
            if mask is None:
                mask = jnp.ones(labels.shape, jnp.float32)
            ce = layers.fused_cross_entropy(
                head["table"], x, labels, mask, cfg.ce_chunks
            )
        total = ce + cfg.aux_loss_coef * aux
        return total, {"ce": ce, "aux": aux}

    # ---------------------------------------------------------------- decode

    def cache_len(self, seq_len: int) -> int:
        if self.cfg.window:
            return min(seq_len, self.cfg.window)
        return seq_len

    def init_decode_state(
        self, batch: int, seq_len: int, start_pos: Optional[int] = None
    ) -> Dict[str, Any]:
        """Decode state with a cache sized for ``seq_len``.

        ``start_pos`` defaults to ``seq_len`` (the dry-run cell semantics:
        a full context already processed, decoding the next token); pass 0
        to generate from scratch.
        """
        cfg = self.cfg
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode state")
        L = self.cache_len(seq_len)
        sp = seq_len if start_pos is None else start_pos
        state: Dict[str, Any] = {"pos": jnp.asarray(sp, jnp.int32)}
        nl = cfg.n_layers
        if cfg.family in ("dense", "vlm"):
            if cfg.mla:
                c = attention.init_mla_cache(cfg, batch, L, nl)
                state.update({"mla_ckv": c["c_kv"], "mla_kr": c["k_rope"]})
            else:
                kv = attention.init_kv_cache(cfg, batch, L, nl)
                state.update({"kv_k": kv.k, "kv_v": kv.v})
        elif cfg.family == "moe":
            if cfg.mla:
                c = attention.init_mla_cache(cfg, batch, L, nl)
                state.update({"mla_ckv": c["c_kv"], "mla_kr": c["k_rope"]})
            else:
                kv = attention.init_kv_cache(cfg, batch, L, nl)
                state.update({"kv_k": kv.k, "kv_v": kv.v})
        elif cfg.family == "ssm":
            from . import ssm as ssm_mod

            sc = ssm_mod.init_ssm_cache(cfg, batch, nl)
            state.update({"ssm_state": sc.state, "ssm_conv": sc.conv})
        elif cfg.family == "hybrid":
            from . import ssm as ssm_mod

            every = cfg.shared_attn_every
            n_groups = nl // every
            tail = nl - n_groups * every
            sc = ssm_mod.init_ssm_cache(cfg, batch, n_groups * every)
            state.update(
                {
                    "ssm_state": sc.state.reshape(
                        n_groups, every, *sc.state.shape[1:]
                    ),
                    "ssm_conv": sc.conv.reshape(n_groups, every, *sc.conv.shape[1:]),
                }
            )
            if tail:
                tc = ssm_mod.init_ssm_cache(cfg, batch, tail)
                state.update({"ssm_state_tail": tc.state, "ssm_conv_tail": tc.conv})
            kv = attention.init_kv_cache(cfg, batch, L, n_groups)
            state.update({"kv_k": kv.k, "kv_v": kv.v})
        return state

    def decode_step(
        self, params: PyTree, state: Dict[str, Any], tokens: Array
    ) -> Tuple[Array, Dict[str, Any]]:
        """One token for every sequence. tokens: (B, 1) int32."""
        cfg = self.cfg
        pos = state["pos"]
        x = layers.embed(params["embed"], tokens)
        if cfg.pos_embedding == "learned":
            pe = jax.lax.dynamic_slice_in_dim(
                params["pos"]["table"], jnp.minimum(pos, cfg.max_position - 1), 1
            )
            x = x + pe[None].astype(x.dtype)
        x = lshard(x, "batch", None, None)
        new_state = dict(state)

        if cfg.family in ("dense", "vlm", "moe"):
            fk = cfg.first_k_dense if cfg.family == "moe" else 0
            c0, c1 = (
                (state["mla_ckv"], state["mla_kr"])
                if cfg.mla
                else (state["kv_k"], state["kv_v"])
            )
            Lc = c0.shape[2]
            slot = (pos % Lc).astype(jnp.int32)

            def run(stack, x, caches, block_decode):
                """Caches are read-only scan xs; ys = each layer's new-token
                entries (B, 1, …) — the slot write happens once, below, so
                the multi-GiB stacks never thread through scan carries/ys."""
                if not cfg.scan_layers:
                    n = jax.tree_util.tree_leaves(stack)[0].shape[0]
                    outs0, outs1 = [], []
                    for i in range(n):
                        lp = jax.tree_util.tree_map(lambda a: a[i], stack)
                        x, (u0, u1) = block_decode(
                            lp, x, (caches[0][i], caches[1][i]), pos, cfg
                        )
                        outs0.append(u0)
                        outs1.append(u1)
                    return x, (jnp.stack(outs0), jnp.stack(outs1))

                def body(h, xs):
                    lp, ck, cv = xs
                    h, news = block_decode(lp, h, (ck, cv), pos, cfg)
                    return h, news

                return jax.lax.scan(body, x, (stack, *caches))

            if cfg.family == "moe":
                if fk:
                    x, (d0, d1) = run(
                        params["dense_layers"], x, (c0[:fk], c1[:fk]),
                        blocks.dense_block_decode,
                    )
                x, (m0, m1) = run(
                    params["moe_layers"], x, (c0[fk:], c1[fk:]),
                    blocks.moe_block_decode,
                )
                n0 = jnp.concatenate([d0, m0]) if fk else m0
                n1 = jnp.concatenate([d1, m1]) if fk else m1
            else:
                x, (n0, n1) = run(
                    params["layers"], x, (c0, c1), blocks.dense_block_decode
                )
            # single slot write for all layers
            if cfg.mla:
                new_state["mla_ckv"] = _slot_write(c0, n0, slot)
                new_state["mla_kr"] = _slot_write(c1, n1, slot)
            else:
                new_state["kv_k"] = _slot_write(c0, n0, slot)
                new_state["kv_v"] = _slot_write(c1, n1, slot)

        elif cfg.family == "ssm":
            if not cfg.scan_layers:
                outs_s, outs_c = [], []
                for i in range(cfg.n_layers):
                    lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
                    x, (st, cv) = blocks.mamba_block_decode(
                        lp, x, (state["ssm_state"][i], state["ssm_conv"][i]), pos, cfg
                    )
                    outs_s.append(st)
                    outs_c.append(cv)
                new_state.update(
                    {"ssm_state": jnp.stack(outs_s), "ssm_conv": jnp.stack(outs_c)}
                )
            else:
                def body(h, xs):
                    lp, st, cv = xs
                    h, (st, cv) = blocks.mamba_block_decode(lp, h, (st, cv), pos, cfg)
                    return h, (st, cv)

                x, (ns, nc) = jax.lax.scan(
                    body, x, (params["layers"], state["ssm_state"], state["ssm_conv"])
                )
                new_state.update({"ssm_state": ns, "ssm_conv": nc})

        elif cfg.family == "hybrid" and not cfg.scan_layers:
            shared = params["shared_attn"]
            Lc = state["kv_k"].shape[2]
            slot = (pos % Lc).astype(jnp.int32)
            ng = jax.tree_util.tree_leaves(params["mamba_groups"])[0].shape[0]
            gs, gc, gk, gv = [], [], [], []
            for g in range(ng):
                glp = jax.tree_util.tree_map(lambda a: a[g], params["mamba_groups"])
                ne = jax.tree_util.tree_leaves(glp)[0].shape[0]
                ss, cc = [], []
                for i in range(ne):
                    lp = jax.tree_util.tree_map(lambda a: a[i], glp)
                    x, (st, cv) = blocks.mamba_block_decode(
                        lp, x,
                        (state["ssm_state"][g, i], state["ssm_conv"][g, i]),
                        pos, cfg,
                    )
                    ss.append(st)
                    cc.append(cv)
                x, (kn, vn) = blocks.dense_block_decode(
                    shared, x, (state["kv_k"][g], state["kv_v"][g]), pos, cfg
                )
                gs.append(jnp.stack(ss))
                gc.append(jnp.stack(cc))
                gk.append(kn)
                gv.append(vn)
            new_state.update(
                {
                    "ssm_state": jnp.stack(gs),
                    "ssm_conv": jnp.stack(gc),
                    "kv_k": _slot_write(state["kv_k"], jnp.stack(gk), slot),
                    "kv_v": _slot_write(state["kv_v"], jnp.stack(gv), slot),
                }
            )
            if "mamba_tail" in params:
                ts, tc = [], []
                nt = jax.tree_util.tree_leaves(params["mamba_tail"])[0].shape[0]
                for i in range(nt):
                    lp = jax.tree_util.tree_map(lambda a: a[i], params["mamba_tail"])
                    x, (st, cv) = blocks.mamba_block_decode(
                        lp, x,
                        (state["ssm_state_tail"][i], state["ssm_conv_tail"][i]),
                        pos, cfg,
                    )
                    ts.append(st)
                    tc.append(cv)
                new_state.update(
                    {"ssm_state_tail": jnp.stack(ts), "ssm_conv_tail": jnp.stack(tc)}
                )

        elif cfg.family == "hybrid":
            shared = params["shared_attn"]
            Lc = state["kv_k"].shape[2]
            slot = (pos % Lc).astype(jnp.int32)

            def group(h, xs):
                glp, st_g, cv_g, ck, cvv = xs

                def inner(hh, ys):
                    lp, st, cv = ys
                    hh, (st, cv) = blocks.mamba_block_decode(lp, hh, (st, cv), pos, cfg)
                    return hh, (st, cv)

                h, (st_g, cv_g) = jax.lax.scan(inner, h, (glp, st_g, cv_g))
                h, (kn, vn) = blocks.dense_block_decode(
                    shared, h, (ck, cvv), pos, cfg
                )
                return h, (st_g, cv_g, kn, vn)

            x, (ns, nc, nk, nv) = jax.lax.scan(
                group,
                x,
                (
                    params["mamba_groups"],
                    state["ssm_state"],
                    state["ssm_conv"],
                    state["kv_k"],
                    state["kv_v"],
                ),
            )
            new_state.update(
                {
                    "ssm_state": ns,
                    "ssm_conv": nc,
                    "kv_k": _slot_write(state["kv_k"], nk, slot),
                    "kv_v": _slot_write(state["kv_v"], nv, slot),
                }
            )
            if "mamba_tail" in params:
                def inner(hh, ys):
                    lp, st, cv = ys
                    hh, (st, cv) = blocks.mamba_block_decode(lp, hh, (st, cv), pos, cfg)
                    return hh, (st, cv)

                x, (ts, tc) = jax.lax.scan(
                    inner,
                    x,
                    (
                        params["mamba_tail"],
                        state["ssm_state_tail"],
                        state["ssm_conv_tail"],
                    ),
                )
                new_state.update({"ssm_state_tail": ts, "ssm_conv_tail": tc})

        x = blocks.norm_apply(cfg, params["final_norm"], x)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = layers.unembed(head, x)
        new_state["pos"] = pos + 1
        return logits, new_state


def _slot_write(cache: Array, new: Array, slot: Array, axis: int = 2) -> Array:
    """Write the new-token entries at ``slot`` along the cache-length axis
    as a masked select.  dynamic_update_slice with a dynamic index on a
    SHARDED dim makes GSPMD replicate the whole cache ("involuntary full
    rematerialization"); an elementwise one-hot select stays shard-local."""
    idx = jax.lax.broadcasted_iota(jnp.int32, cache.shape, axis)
    return jnp.where(idx == slot, new.astype(cache.dtype), cache)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    model = Model(cfg)
    tree = model.abstract_params()
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        n = math.prod(leaf.shape)
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        if active_only and "experts/" in pstr and cfg.n_experts:
            n = n * cfg.experts_per_token // cfg.n_experts
        total += n
    return total
