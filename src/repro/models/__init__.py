"""Composable pure-JAX model zoo for the assigned architectures.

Families: dense decoder LMs (GQA/SWA/QKV-bias), MoE (top-k, sorted capacity
dispatch), MLA (DeepSeek-V2), SSM (Mamba2 SSD), hybrid (Zamba2), encoder-only
(HuBERT), VLM backbone (Qwen2-VL with M-RoPE).  All layers scan-stacked for
bounded compile time; sharding via logical-axis PartitionSpec rules.
"""

from .model import Model, build_model

__all__ = ["Model", "build_model"]
