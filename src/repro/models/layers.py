"""Shared primitive layers: norms, projections, RoPE/M-RoPE, MLPs.

Conventions:
  * params are plain nested dicts of jnp arrays;
  * every ``init_*`` has a matching ``*_spec`` entry in sharding.py via
    path-name rules (wq/wk/... names are load-bearing);
  * compute dtype is bf16 (fp32 for norms/softmax/logits), param dtype per
    config.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _he(key, shape, scale, dtype):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, *, bias: bool = False, scale=1.0):
    p = {"w": _he(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x: Array, compute_dtype=jnp.bfloat16) -> Array:
    y = x.astype(compute_dtype) @ p["w"].astype(compute_dtype)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def init_rmsnorm(d: int, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * p["g"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32) + p["b"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE — standard and multimodal (M-RoPE, Qwen2-VL §2.1)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]               # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array, positions_thw: Array, theta: float, sections: Tuple[int, int, int]
) -> Array:
    """M-RoPE: rotary sections driven by (temporal, height, width) positions.

    x: (B, S, H, hd); positions_thw: (B, S, 3) int32.  ``sections`` gives the
    number of *frequency pairs* assigned to each of t/h/w (sums to hd/2).
    For text tokens the stub frontend sets t == h == w == sequence position,
    which reduces M-RoPE to standard RoPE (as in the paper).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=hd // 2
    )                                                  # (hd/2,) ∈ {0,1,2}
    pos = jnp.take_along_axis(
        positions_thw.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], positions_thw.shape[:2] + (hd // 2,)).astype(jnp.int32),
        axis=-1,
    )                                                  # (B, S, hd/2)
    angles = pos * freqs[None, None, :]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _he(k1, (d_model, d_ff), 1.0, dtype),
        "w_up": _he(k2, (d_model, d_ff), 1.0, dtype),
        "w_down": _he(k3, (d_ff, d_model), 1.0, dtype),
    }


def swiglu(p, x: Array) -> Array:
    xc = x.astype(jnp.bfloat16)
    g = xc @ p["w_gate"].astype(jnp.bfloat16)
    u = xc @ p["w_up"].astype(jnp.bfloat16)
    return (jax.nn.silu(g) * u) @ p["w_down"].astype(jnp.bfloat16)


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": _he(k1, (d_model, d_ff), 1.0, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": _he(k2, (d_ff, d_model), 1.0, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p, x: Array) -> Array:
    xc = x.astype(jnp.bfloat16)
    h = jax.nn.gelu(xc @ p["w_in"].astype(jnp.bfloat16) + p["b_in"].astype(jnp.bfloat16))
    return h @ p["w_out"].astype(jnp.bfloat16) + p["b_out"].astype(jnp.bfloat16)


def init_embedding(key, vocab: int, d_model: int, dtype):
    return {"table": _he(key, (vocab, d_model), 1.0, dtype)}


def embed(p, tokens: Array) -> Array:
    return p["table"][tokens].astype(jnp.bfloat16)


def unembed(p, x: Array) -> Array:
    """Logits in fp32 (stable softmax/CE)."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.bfloat16), p["table"].astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )


def cross_entropy(logits: Array, labels: Array, mask: Optional[Array] = None) -> Array:
    """Mean CE over valid positions. logits fp32 (…, V), labels int (…)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Fused (chunked) unembed + cross-entropy: full (B,S,V) logits never
# materialize — forward scans sequence chunks keeping only per-position
# logsumexp; backward recomputes each chunk's logits and emits
# (softmax − onehot) gradients.  At V≈50–150k this removes the dominant
# f32 activation of the training step (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def fused_cross_entropy(
    table: Array, x: Array, labels: Array, mask: Array, chunk: int = 8
) -> Array:
    loss, _ = _fused_ce_fwd_impl(table, x, labels, mask, chunk)
    return loss


def _fused_ce_fwd_impl(table, x, labels, mask, n_chunks):
    B, S, D = x.shape
    n = n_chunks if S % n_chunks == 0 else 1
    c = S // n
    xc = x.reshape(B, n, c, D)
    lc = labels.reshape(B, n, c)
    mc = mask.reshape(B, n, c).astype(jnp.float32)

    def body(acc, i):
        nll_sum, msum = acc
        logits = jnp.einsum(
            "bcd,vd->bcv", xc[:, i].astype(jnp.bfloat16),
            table.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
        )
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lc[:, i][..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mc[:, i]
        return (nll_sum + jnp.sum(nll), msum + jnp.sum(mc[:, i])), lse

    (nll_sum, msum), lses = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n),
    )
    loss = nll_sum / jnp.maximum(msum, 1.0)
    return loss, (lses, msum)          # lses: (n, B, c)


def _fused_ce_fwd(table, x, labels, mask, n_chunks):
    loss, (lses, msum) = _fused_ce_fwd_impl(table, x, labels, mask, n_chunks)
    return loss, (table, x, labels, mask, lses, msum)


def _fused_ce_bwd(n_chunks, res, g):
    table, x, labels, mask, lses, msum = res
    B, S, D = x.shape
    n = n_chunks if S % n_chunks == 0 else 1
    c = S // n
    xc = x.reshape(B, n, c, D)
    lc = labels.reshape(B, n, c)
    mc = mask.reshape(B, n, c).astype(jnp.float32)
    scale = g / jnp.maximum(msum, 1.0)

    def body(dtable, i):
        logits = jnp.einsum(
            "bcd,vd->bcv", xc[:, i].astype(jnp.bfloat16),
            table.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
        )
        p = jnp.exp(logits - lses[i][..., None])
        onehot = jax.nn.one_hot(lc[:, i], table.shape[0], dtype=jnp.float32)
        dlogits = (p - onehot) * (mc[:, i] * scale)[..., None]
        dx_i = jnp.einsum(
            "bcv,vd->bcd", dlogits.astype(jnp.bfloat16),
            table.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
        )
        dt_i = jnp.einsum(
            "bcv,bcd->vd", dlogits.astype(jnp.bfloat16),
            xc[:, i].astype(jnp.bfloat16), preferred_element_type=jnp.float32,
        )
        return dtable + dt_i, dx_i

    dt0 = jnp.zeros((table.shape[0], D), jnp.float32)
    dtable, dxs = jax.lax.scan(body, dt0, jnp.arange(n))
    dx = jnp.moveaxis(dxs, 0, 1).reshape(B, S, D).astype(x.dtype)
    return dtable.astype(table.dtype), dx, None, None


fused_cross_entropy.defvjp(_fused_ce_fwd, _fused_ce_bwd)
