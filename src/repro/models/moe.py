"""Mixture-of-Experts: top-k router + shard-local sorted capacity dispatch.

Design notes (the two decisions that dominate MoE roofline behaviour):

* **Shard-local dispatch.** Token sorting/dispatch happens independently
  per data shard: tokens reshape to ``(dispatch_shards, T_loc, D)`` with
  dim 0 sharded over the batch mesh axes, and the sort/scatter/gather run
  under ``jax.vmap`` over that dim.  GSPMD keeps every per-row op local —
  a *global* argsort over 10⁶ tokens would otherwise lower to all-gathers
  of the whole activation buffer (observed: >100 GiB/device before this
  change).  This is the standard per-shard dispatch of production MoE
  stacks.
* **Capacity-based dropping, not dense all-experts einsum.** HLO FLOPs stay
  ≈ active FLOPs × capacity_factor, keeping the roofline's useful-compute
  ratio honest for 64–160-expert models.

Expert weights carry a leading E axis sharded over 'model' (expert
parallelism); the ``lshard`` on the dispatch buffer makes GSPMD insert the
token all-to-all at the dispatch/combine boundaries.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import layers

Array = jax.Array


def init_moe(key, cfg):
    """Experts as stacked SwiGLU: (E, d_model, moe_d_ff) / (E, moe_d_ff, d_model)."""
    kr, ke, ks = jax.random.split(key, 3)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    expert_keys = jax.random.split(ke, E)
    experts = jax.vmap(
        lambda k: layers.init_swiglu(k, d, f, cfg.dtype)
    )(expert_keys)
    p = {
        "router": layers.init_dense(kr, d, E, jnp.float32),
        "experts": experts,
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_swiglu(
            ks, d, f * cfg.n_shared_experts, cfg.dtype
        )
    return p


def _dispatch_one(xt: Array, idx: Array, C: int, E: int):
    """One shard: build the (E, C, D) expert buffer by GATHER.

    xt: (T, D); idx: (T, K). Returns (buf, sort, pos) where ``sort`` is the
    token-expert permutation and ``pos`` the capacity slot (−1 = dropped).

    Gather-based construction (slot → source token) instead of scatter-add
    (token → slot): XLA float-normalizes bf16 scatters to f32, which
    materialized f32 (E,C,D) buffers (10 GiB/layer for deepseek); with the
    gather form the only scatter left is the backward into the K×-smaller
    (T,D) token gradient (§Perf C5).
    """
    T, K = idx.shape
    flat_e = idx.reshape(-1)
    sort = jnp.argsort(flat_e)                  # local, stable
    sorted_e = flat_e[sort]
    bounds = jnp.searchsorted(sorted_e, jnp.arange(E + 1))
    group_start, group_end = bounds[:-1], bounds[1:]
    pos_in_group = jnp.arange(T * K) - group_start[sorted_e]
    keep = pos_in_group < C

    # slot (e, c) ← token sort[group_start[e] + c] when c < group size
    slot_src = group_start[:, None] + jnp.arange(C)[None, :]      # (E, C)
    valid = slot_src < group_end[:, None]
    src_tok = sort[jnp.clip(slot_src, 0, T * K - 1)] // K
    buf = jnp.where(valid[..., None], xt[src_tok], 0)
    return buf, sort, jnp.where(keep, pos_in_group, -1)


def _combine_rows(out_e, sort, pos, idx, gate):
    sorted_e = idx.reshape(-1)[sort]
    keep = pos >= 0
    rows = out_e[sorted_e, jnp.where(keep, pos, 0)]
    rows = jnp.where(keep[:, None], rows, 0)
    unsort = jnp.argsort(sort)
    return rows[unsort].reshape(-1, gate.shape[-1], out_e.shape[-1])


@jax.custom_vjp
def _combine_one(out_e: Array, sort: Array, pos: Array, idx: Array, gate: Array):
    """One shard: gather expert outputs back to token order, gate-mix.

    Custom VJP: the slot→token map is injective (each (e, c) slot holds at
    most one token), so d(out_e) is a pure GATHER of the token cotangents —
    plain autodiff would scatter-add into an (E, C, D) buffer, which XLA
    float-normalizes into multi-GiB f32 temporaries (§Perf C5)."""
    contrib = _combine_rows(out_e, sort, pos, idx, gate)
    return jnp.sum(contrib * gate[..., None].astype(contrib.dtype), axis=1)


def _combine_one_fwd(out_e, sort, pos, idx, gate):
    return _combine_one(out_e, sort, pos, idx, gate), (out_e, sort, pos, idx, gate)


def _combine_one_bwd(res, dy):
    out_e, sort, pos, idx, gate = res
    T, K = gate.shape
    E, C, D = out_e.shape
    sorted_e = idx.reshape(-1)[sort]
    keep = pos >= 0
    # d_gate needs the forward rows — recompute by gather (cheap)
    contrib = _combine_rows(out_e, sort, pos, idx, gate)
    d_gate = jnp.sum(
        contrib.astype(jnp.float32) * dy[:, None, :].astype(jnp.float32), axis=-1
    ).astype(gate.dtype)
    # token cotangents in sorted order
    d_rows = (dy[:, None, :] * gate[..., None].astype(dy.dtype)).reshape(T * K, D)
    d_rows_sorted = jnp.where(keep[:, None], d_rows[sort], 0)
    # d_out_e[e, c] = d_rows_sorted[group_start[e] + c] when the slot is live
    bounds = jnp.searchsorted(sorted_e, jnp.arange(E + 1))
    slot_src = bounds[:-1][:, None] + jnp.arange(C)[None, :]
    valid = slot_src < bounds[1:][:, None]
    d_out_e = jnp.where(
        valid[..., None],
        d_rows_sorted[jnp.clip(slot_src, 0, T * K - 1)],
        0,
    ).astype(out_e.dtype)
    return d_out_e, None, None, None, d_gate


_combine_one.defvjp(_combine_one_fwd, _combine_one_bwd)


def moe_apply(p, x: Array, cfg) -> Tuple[Array, Array]:
    """x: (B, S, D) → (y (B, S, D), aux_loss scalar)."""
    from repro.distributed.sharding import lshard

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    DS = max(1, cfg.dispatch_shards)
    T = B * S
    assert T % DS == 0, (T, DS)
    T_loc = T // DS
    xt = lshard(x.reshape(DS, T_loc, D), "batch", None, None)

    logits = layers.dense(p["router"], xt, compute_dtype=jnp.float32)  # (DS,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                                # (DS,T,K)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (global means — cheap scalars)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    C = int(T_loc * K / E * cfg.capacity_factor) + 1

    buf, sort, pos = jax.vmap(lambda xx, ii: _dispatch_one(xx, ii, C, E))(xt, idx)
    # Scatter stays token-sharded (local; replicated within the model group).
    buf = lshard(buf, "batch", None, None, None)
    # EP layout is token-count-adaptive (§Perf):
    #  * train (T_loc large): experts over 'model'; slicing the
    #    group-replicated buffer is free, the combine re-shard carries
    #    ≈ capacity_factor × the optimal all-to-all;
    #  * decode (T_loc tiny): experts over 'data' matching the serving
    #    weight layout (serve/step.inference_param_specs) — the tiny token
    #    buffers all-to-all to the experts and back, weights never move
    #    (the train layout would all-gather GiBs of expert weights per
    #    layer to process a handful of tokens).
    serving = T_loc < 4096
    e_axis = "experts_serve" if serving else "experts"
    bufE = lshard(buf, None if serving else "batch", e_axis, None, None)

    we = p["experts"]
    h = jnp.einsum("secd,edf->secf", bufE.astype(jnp.bfloat16),
                   we["w_gate"].astype(jnp.bfloat16))
    u = jnp.einsum("secd,edf->secf", bufE.astype(jnp.bfloat16),
                   we["w_up"].astype(jnp.bfloat16))
    act = jax.nn.silu(h) * u
    out_e = jnp.einsum("secf,efd->secd", act, we["w_down"].astype(jnp.bfloat16))
    if serving:
        out_e = lshard(out_e, None, e_axis, None, None)
    else:
        out_e = lshard(out_e, "batch", None, None, None)

    y = jax.vmap(_combine_one)(out_e, sort, pos, idx, gate)   # (DS, T_loc, D)
    y = lshard(y, "batch", None, None)

    if "shared" in p:
        y = y + layers.swiglu(p["shared"], xt)
    return y.reshape(B, S, D).astype(x.dtype), aux
