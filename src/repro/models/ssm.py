"""Mamba2 (SSD — state-space duality) block: chunked training scan and
recurrent decode.

Training uses the SSD chunked algorithm (Dao & Gu 2024): the sequence is
split into chunks of ``ssd_chunk``; within a chunk the quadratic "attention
form" runs (MXU-friendly), across chunks a linear recurrence on the (H, P, N)
state carries context — O(S·Q) instead of O(S²).  Decode is the pure
recurrence: state ← state·exp(dtA) + dt·x⊗B, y = C·state — O(1) per token,
which is what makes the 500 k-token decode cell feasible.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import layers

Array = jax.Array


def init_mamba2(key, cfg):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": layers.init_dense(
            k1, d, 2 * d_inner + 2 * N + H, cfg.dtype
        ),
        "conv": {
            "w": (jax.random.normal(k2, (cfg.ssm_conv, conv_dim), jnp.float32) * 0.1
                  ).astype(cfg.dtype),
            "b": jnp.zeros((conv_dim,), cfg.dtype),
        },
        "ssm": {
            "A_log": jnp.log(
                jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
            ).astype(jnp.float32),
            "D": jnp.ones((H,), jnp.float32),
            "dt_bias": jnp.zeros((H,), jnp.float32),
        },
        "norm": layers.init_rmsnorm(d_inner, cfg.dtype),
        "out_proj": layers.init_dense(k4, d_inner, d, cfg.dtype),
    }


def _split_proj(p, x, cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    zxbcdt = layers.dense(p["in_proj"], x)
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return z, xin, Bc, Cc, dt, d_inner, H, N


def _causal_conv(p, u: Array) -> Array:
    """Depthwise causal conv via shifted adds (width is tiny, e.g. 4)."""
    w = p["w"].astype(jnp.float32)
    width = w.shape[0]
    uf = u.astype(jnp.float32)
    y = jnp.zeros_like(uf)
    for i in range(width):
        shift = width - 1 - i
        ui = jnp.pad(uf, ((0, 0), (shift, 0), (0, 0)))[:, : uf.shape[1]]
        y = y + ui * w[i][None, None, :]
    return jax.nn.silu(y + p["b"].astype(jnp.float32)).astype(u.dtype)


def ssd_scan(
    xh: Array, dt: Array, A: Array, Bc: Array, Cc: Array, D: Array, chunk: int
) -> Array:
    """Chunked SSD. xh: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) < 0;
    Bc, Cc: (B,S,N) (single group); D: (H,). Returns (B,S,H,P)."""
    Bsz, S, H, P = xh.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xc = xh.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bcc = Bc.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Ccc = Cc.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]                     # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(dA, axis=2)

    # intra-chunk (quadratic within Q). Mask *before* exp: upper-triangle
    # segments are positive and would overflow to inf, which turns the
    # where() gradient into NaN (valid entries are always ≤ 0).
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(tri[None, None, :, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)
    scores = jnp.einsum("bcqn,bckn->bcqk", Ccc, Bcc)      # (B,nc,Q,Q)
    att = scores[..., None] * L * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", att, xc)

    # chunk states: (B,nc,H,P,N)
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    states = jnp.einsum(
        "bckn,bckh,bckhp->bchpn", Bcc, dtc * decay_out, xc
    )
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))            # (B,nc,H)

    def step(s, inp):
        st_c, dec_c = inp
        s_new = s * dec_c[:, :, None, None] + st_c
        return s_new, s                                    # emit state *before* chunk

    s0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, s_prev = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_prev = jnp.moveaxis(s_prev, 0, 1)                   # (B,nc,H,P,N)

    y_off = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", Ccc, s_prev, jnp.exp(cum)
    )
    y = (y_diag + y_off).reshape(Bsz, Sp, H, P)[:, :S]
    return (y + xh[:, :S].astype(jnp.float32) * D[None, None, :, None]).astype(
        jnp.bfloat16
    )


def mamba2_train(p, x: Array, cfg) -> Array:
    """Full Mamba2 mixer over (B, S, D)."""
    z, xin, Bc, Cc, dt, d_inner, H, N = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out = _causal_conv(p["conv"], conv_in)
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    A = -jnp.exp(p["ssm"]["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["ssm"]["dt_bias"][None, None, :])
    xh = xin.reshape(*xin.shape[:2], H, cfg.ssm_head_dim)
    y = ssd_scan(xh, dt, A, Bc, Cc, p["ssm"]["D"], cfg.ssd_chunk)
    y = y.reshape(*x.shape[:2], d_inner)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    return layers.dense(p["out_proj"], y)


class SSMCache(NamedTuple):
    state: Array      # (B, H, P, N) fp32
    conv: Array       # (B, width-1, conv_dim)


def init_ssm_cache(cfg, batch: int, n_layers: int) -> SSMCache:
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    return SSMCache(
        jnp.zeros((n_layers, batch, H, cfg.ssm_head_dim, N), jnp.float32),
        jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), jnp.bfloat16),
    )


def mamba2_decode(p, x: Array, state: Array, conv_cache: Array, cfg):
    """One-token recurrent step. x: (B, 1, D); state: (B,H,P,N);
    conv_cache: (B, width-1, conv_dim). Returns (y, state, conv_cache)."""
    z, xin, Bc, Cc, dt, d_inner, H, N = _split_proj(p, x, cfg)
    u = jnp.concatenate([xin, Bc, Cc], axis=-1)[:, 0]      # (B, conv_dim)
    w = p["conv"]["w"].astype(jnp.float32)
    width = w.shape[0]
    hist = jnp.concatenate([conv_cache.astype(jnp.float32),
                            u.astype(jnp.float32)[:, None]], axis=1)  # (B,w,conv)
    conv_out = jnp.sum(hist * w[None, :, :], axis=1) + p["conv"]["b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out)
    conv_cache = hist[:, 1:].astype(conv_cache.dtype)

    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    A = -jnp.exp(p["ssm"]["A_log"])
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["ssm"]["dt_bias"][None, :])
    dA = jnp.exp(dtv * A[None, :])                        # (B, H)
    xh = xin.reshape(-1, H, cfg.ssm_head_dim)              # (B,H,P)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dtv, Bc, xh)
    state = state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, Cc)
    y = y + xh * p["ssm"]["D"][None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(jnp.bfloat16)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(jnp.bfloat16))
    return layers.dense(p["out_proj"], y), state, conv_cache
