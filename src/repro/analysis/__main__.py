"""``python -m repro.analysis`` — run zipnn-lint over the repo."""

import sys

from .driver import main

sys.exit(main())
