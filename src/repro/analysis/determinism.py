"""Determinism lint: nondeterminism sources on codec paths.

Scope: ``src/repro/{core,kernels,checkpoint,distributed}``.  Everything in
these trees sits on (or next to) the path that produces compressed bytes,
where the repo invariant is *byte-identical output across backend x
entropy_backend x threads — and across runs*.  Benchmarks and tests live
outside the scope and may use clocks/RNGs freely.

Rules
-----
det-wallclock   calendar-time calls (``time.time``, ``datetime.now`` ...).
                ``time.perf_counter``/``monotonic`` are allowed: they are
                measurement clocks whose values feed reports, not bytes.
det-random      RNG / entropy sources: ``random.*``, ``np.random.*``,
                ``os.urandom``, ``uuid.*``, ``secrets.*``.
det-hash        builtin ``hash()`` — salted per process (PYTHONHASHSEED).
det-set-order   iterating a set (literal, comprehension, ``set()`` /
                ``frozenset()`` call) without ``sorted()`` — iteration
                order varies run to run.
det-id-key      ``id(x)`` used as a subscript/dict key — address-derived
                keys reorder dicts run to run.
det-fs-order    iterating ``os.listdir`` / ``os.scandir`` / ``glob.glob``
                / ``.iterdir()`` without ``sorted()`` — directory order is
                filesystem-dependent.
det-float-size  float division feeding a byte count, slice bound,
                ``range()`` or array allocation — sizes on byte-exact
                paths must stay in integer arithmetic (``//``, ``-(-a//b)``).
"""

from __future__ import annotations

import ast
from typing import List, Optional

from .base import Project, SourceFile, Violation, dotted_name, is_call_to

FAMILY = "determinism"
RULES = (
    "det-wallclock",
    "det-random",
    "det-hash",
    "det-set-order",
    "det-id-key",
    "det-fs-order",
    "det-float-size",
)

SCOPE = (
    "src/repro/core/",
    "src/repro/kernels/",
    "src/repro/checkpoint/",
    "src/repro/distributed/",
)

_WALLCLOCK = (
    "time.time",
    "time.time_ns",
    "time.ctime",
    "time.localtime",
    "time.gmtime",
    "datetime.now",
    "datetime.utcnow",
    "datetime.today",
    "date.today",
)

_RANDOM_PREFIXES = ("random.", "np.random.", "numpy.random.", "secrets.", "uuid.")
_RANDOM_EXACT = ("os.urandom",)

_FS_LISTING = ("os.listdir", "os.scandir", "glob.glob", "glob.iglob")

# Allocation-ish call targets whose size argument must be integer-exact.
_SIZE_SINKS = ("range", "bytes", "bytearray", "memoryview")
_NP_ALLOC_TAILS = ("empty", "zeros", "ones", "full")


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


def _is_fs_listing(node: ast.AST) -> bool:
    if is_call_to(node, *_FS_LISTING):
        return True
    # path.iterdir() / path.glob("*") on a Path-like receiver
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        if node.func.attr in ("iterdir", "glob", "rglob", "scandir"):
            return True
    return False


def _iteration_context(sf: SourceFile, node: ast.AST) -> Optional[ast.AST]:
    """If ``node`` is directly iterated, return the iterating node.

    Covers ``for x in node``, comprehension generators, and wrapping in
    ``list()`` / ``tuple()`` / ``enumerate()`` (which freeze the order into
    output-feeding sequences).  ``sorted(node)`` neutralizes the order and
    returns None.
    """
    parent = sf.parent(node)
    if isinstance(parent, ast.Call) and isinstance(parent.func, ast.Name):
        if parent.func.id == "sorted":
            return None
        if parent.func.id in ("list", "tuple", "enumerate") and parent.args and parent.args[0] is node:
            return parent
    if isinstance(parent, (ast.For, ast.AsyncFor)) and parent.iter is node:
        return parent
    if isinstance(parent, ast.comprehension) and parent.iter is node:
        return parent
    return None


def _float_size_context(sf: SourceFile, div: ast.BinOp) -> Optional[str]:
    """Climb from a ``/`` BinOp; return a description if it feeds a size."""
    cur: ast.AST = div
    parent = sf.parent(cur)
    # Climb through arithmetic wrappers that keep it float (e.g. a / b + 1).
    while isinstance(parent, (ast.BinOp, ast.UnaryOp)):
        cur = parent
        parent = sf.parent(cur)
    if isinstance(parent, (ast.Slice,)):
        return "slice bound"
    if isinstance(parent, ast.Subscript) and parent.slice is cur:
        return "subscript index"
    if isinstance(parent, ast.Call):
        fn = parent.func
        if isinstance(fn, ast.Name) and fn.id in _SIZE_SINKS and cur in parent.args:
            return f"argument of {fn.id}()"
        if isinstance(fn, ast.Name) and fn.id == "int" and cur in parent.args:
            # int(a / b) truncates a float — rounding drift under
            # fast-math/accumulation; sizes must use //.
            return "int() truncation of a float quotient (use //)"
        name = dotted_name(fn)
        if (
            name is not None
            and name.split(".")[-1] in _NP_ALLOC_TAILS
            and parent.args
            and cur is parent.args[0]
        ):
            return f"shape argument of {name}()"
    return None


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    for sf in project.under(*SCOPE):
        out.extend(_check_file(sf))
    return out


def _check_file(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(sf.tree):
        # --- clocks / RNG / hash ------------------------------------------
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name is not None:
                if any(name == w or name.endswith("." + w) for w in _WALLCLOCK):
                    out.append(
                        Violation(
                            "det-wallclock",
                            sf.rel,
                            node.lineno,
                            f"wall-clock call {name}() on a codec path — "
                            "use time.perf_counter() for measurements; "
                            "clock values must never feed output bytes",
                        )
                    )
                if name in _RANDOM_EXACT or any(
                    name.startswith(p) for p in _RANDOM_PREFIXES
                ):
                    out.append(
                        Violation(
                            "det-random",
                            sf.rel,
                            node.lineno,
                            f"entropy source {name}() on a codec path — "
                            "compressed bytes must be a pure function of "
                            "the input",
                        )
                    )
            if isinstance(node.func, ast.Name) and node.func.id == "hash":
                out.append(
                    Violation(
                        "det-hash",
                        sf.rel,
                        node.lineno,
                        "builtin hash() is salted per process "
                        "(PYTHONHASHSEED) — use a content hash "
                        "(zlib.crc32, hashlib) instead",
                    )
                )
            # id() as a key
            if isinstance(node.func, ast.Name) and node.func.id == "id":
                parent = sf.parent(node)
                in_subscript = (
                    isinstance(parent, ast.Subscript) and parent.slice is node
                )
                in_dict_key = isinstance(parent, ast.Dict) and node in parent.keys
                in_map_call = (
                    isinstance(parent, ast.Call)
                    and isinstance(parent.func, ast.Attribute)
                    and parent.func.attr in ("get", "setdefault", "pop")
                    and parent.args
                    and parent.args[0] is node
                )
                if in_subscript or in_dict_key or in_map_call:
                    out.append(
                        Violation(
                            "det-id-key",
                            sf.rel,
                            node.lineno,
                            "id()-keyed mapping — addresses vary run to "
                            "run, so iteration order (and any bytes "
                            "derived from it) is nondeterministic",
                        )
                    )

        # --- iteration order ----------------------------------------------
        if _is_set_expr(node):
            ctx = _iteration_context(sf, node)
            if ctx is not None:
                out.append(
                    Violation(
                        "det-set-order",
                        sf.rel,
                        node.lineno,
                        "iterating a set — order varies run to run; wrap "
                        "in sorted(...) before anything that feeds output",
                    )
                )
        if _is_fs_listing(node):
            ctx = _iteration_context(sf, node)
            if ctx is not None:
                out.append(
                    Violation(
                        "det-fs-order",
                        sf.rel,
                        node.lineno,
                        "iterating a directory listing — order is "
                        "filesystem-dependent; wrap in sorted(...)",
                    )
                )

        # --- float-derived sizes ------------------------------------------
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
            desc = _float_size_context(sf, node)
            if desc is not None:
                out.append(
                    Violation(
                        "det-float-size",
                        sf.rel,
                        node.lineno,
                        f"float division feeds a {desc} — byte-exact "
                        "paths must size with integer arithmetic "
                        "(// or -(-a // b))",
                    )
                )
    return out
