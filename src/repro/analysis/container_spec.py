"""Container-spec checker: the ZNN1/ZNS1 wire layouts, declared once.

The on-disk formats (core/container.py's single-blob ZNN1, core/engine.py's
framed ZNS1) are hand-written ``struct`` code; the golden fixtures freeze
the bytes but can't point at *which line* drifted.  This family declares
each layout once as a field table and cross-checks every ``struct`` use in
the two format-owning modules against it.

Rules
-----
spec-format            ``struct.Struct(...)`` assignments in the
                       format-owning modules must bind a declared layout
                       name to exactly its declared format string; any
                       other ``struct`` framing in ``src/repro`` is
                       undeclared and flagged (declare it here first).
spec-magic             the module owning a layout must carry its magic
                       literal (b"ZNN1" / b"ZNS1").
spec-arity             ``<layout>.pack(...)`` argument counts and tuple
                       targets of ``<layout>.unpack[_from](...)`` must
                       match the field count (pad fields carry no value).
spec-unchecked-length  a multi-byte integer field bound from ``unpack``
                       (e.g. a u64 ``comp_len``) must not drive an
                       allocation (``fp.read(n)``, ``bytes(n)``,
                       ``bytearray(n)``) before a bounds check: a flipped
                       header byte must never become a giant upfront
                       allocation.  A prior ``Compare`` mentioning the
                       name, or a ``min()`` clamp, counts as the check.
"""

from __future__ import annotations

import ast
import struct as _struct
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .base import Project, SourceFile, Violation, dotted_name

FAMILY = "container_spec"
RULES = ("spec-format", "spec-magic", "spec-arity", "spec-unchecked-length")


@dataclass(frozen=True)
class FieldSpec:
    name: str
    fmt: str  # single struct format unit, e.g. "Q", "4s", "3x"

    @property
    def width(self) -> int:
        return _struct.calcsize("<" + self.fmt)

    @property
    def is_pad(self) -> bool:
        return self.fmt.endswith("x")


@dataclass(frozen=True)
class LayoutSpec:
    var: str
    fields: Tuple[FieldSpec, ...]
    magic: Optional[bytes] = None

    @property
    def format(self) -> str:
        return "<" + "".join(f.fmt for f in self.fields)

    @property
    def value_fields(self) -> Tuple[FieldSpec, ...]:
        return tuple(f for f in self.fields if not f.is_pad)


def _layout(var: str, fields: Sequence[Tuple[str, str]], magic=None) -> LayoutSpec:
    return LayoutSpec(var, tuple(FieldSpec(n, f) for n, f in fields), magic)


# --- The single source of truth for the wire formats -----------------------
ZNN1_HEADER = _layout(
    "_HDR",
    [
        ("magic", "4s"),
        ("version", "H"),
        ("flags", "H"),
        ("layout", "16s"),
        ("n_bytes", "Q"),
        ("chunk_bytes", "I"),
        ("n_planes", "B"),
        ("_pad", "3x"),
    ],
    magic=b"ZNN1",
)
ZNN1_RECORD = _layout(
    "_REC", [("method", "B"), ("comp_len", "I"), ("crc", "I")]
)
ZNS1_HEADER = _layout(
    "_SHDR",
    [
        ("magic", "4s"),
        ("version", "H"),
        ("flags", "H"),
        ("dtype", "16s"),
        ("window", "Q"),
    ],
    magic=b"ZNS1",
)
ZNS1_FRAME = _layout(
    "_FRAME",
    [("kind", "B"), ("raw_len", "Q"), ("comp_len", "Q"), ("crc", "I")],
)

SPEC: Dict[str, Dict[str, LayoutSpec]] = {
    "src/repro/core/container.py": {"_HDR": ZNN1_HEADER, "_REC": ZNN1_RECORD},
    "src/repro/core/engine.py": {"_SHDR": ZNS1_HEADER, "_FRAME": ZNS1_FRAME},
}

# Any struct use outside these modules is undeclared framing.
STRUCT_SCOPE_PREFIX = "src/repro/"

_ALLOC_BUILTINS = ("bytes", "bytearray")


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    for sf in project.files:
        layouts = SPEC.get(sf.rel)
        if layouts is not None:
            out.extend(_check_format_module(sf, layouts))
        elif sf.rel.startswith(STRUCT_SCOPE_PREFIX):
            out.extend(_check_no_struct(sf))
    return out


def _check_no_struct(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func) or ""
            if name.startswith("struct."):
                out.append(
                    Violation(
                        "spec-format",
                        sf.rel,
                        node.lineno,
                        f"{name}() outside the format-owning modules — "
                        "wire framing lives in core/container.py / "
                        "core/engine.py with a layout declared in "
                        "analysis/container_spec.py",
                    )
                )
    return out


def _check_format_module(
    sf: SourceFile, layouts: Dict[str, LayoutSpec]
) -> List[Violation]:
    out: List[Violation] = []
    seen_vars: Dict[str, LayoutSpec] = {}

    for node in ast.walk(sf.tree):
        # --- struct.Struct("<fmt>") assignments ---------------------------
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if dotted_name(call.func) in ("struct.Struct", "Struct"):
                target = (
                    node.targets[0].id
                    if len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    else None
                )
                fmt = (
                    call.args[0].value
                    if call.args
                    and isinstance(call.args[0], ast.Constant)
                    and isinstance(call.args[0].value, str)
                    else None
                )
                spec = layouts.get(target) if target else None
                if spec is None:
                    out.append(
                        Violation(
                            "spec-format",
                            sf.rel,
                            node.lineno,
                            f"struct.Struct bound to "
                            f"{target or '<non-name target>'} has no "
                            "declared layout — add a field table to "
                            "analysis/container_spec.py",
                        )
                    )
                elif fmt != spec.format:
                    out.append(
                        Violation(
                            "spec-format",
                            sf.rel,
                            node.lineno,
                            f"{target} format {fmt!r} != declared "
                            f"{spec.format!r} "
                            f"({', '.join(f.name + ':' + f.fmt for f in spec.fields)})",
                        )
                    )
                else:
                    seen_vars[target] = spec

        # --- bare struct.pack/unpack with inline formats ------------------
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name in ("struct.pack", "struct.unpack", "struct.pack_into", "struct.unpack_from"):
                out.append(
                    Violation(
                        "spec-format",
                        sf.rel,
                        node.lineno,
                        f"inline {name}() bypasses the declared layout "
                        "Structs — use the module-level layout objects",
                    )
                )

        # --- pack arity ---------------------------------------------------
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            recv = node.func.value
            if isinstance(recv, ast.Name) and recv.id in layouts:
                spec = layouts[recv.id]
                n_fields = len(spec.value_fields)
                if node.func.attr == "pack":
                    if not any(isinstance(a, ast.Starred) for a in node.args):
                        if len(node.args) != n_fields:
                            out.append(
                                Violation(
                                    "spec-arity",
                                    sf.rel,
                                    node.lineno,
                                    f"{recv.id}.pack() takes "
                                    f"{len(node.args)} args but the layout "
                                    f"declares {n_fields} value fields",
                                )
                            )

    # --- declared layouts must all be bound ------------------------------
    for var, spec in layouts.items():
        if var not in seen_vars:
            out.append(
                Violation(
                    "spec-format",
                    sf.rel,
                    1,
                    f"declared layout {var} ({spec.format!r}) is not bound "
                    "via struct.Struct in this module",
                )
            )
        if spec.magic is not None and not _has_bytes_literal(sf, spec.magic):
            out.append(
                Violation(
                    "spec-magic",
                    sf.rel,
                    1,
                    f"magic literal {spec.magic!r} for layout {var} not "
                    "found in this module",
                )
            )

    # --- unpack arity + unchecked length-driven allocation ----------------
    for fn in _functions(sf):
        out.extend(_check_parse_site(sf, fn, layouts))
    return out


def _has_bytes_literal(sf: SourceFile, value: bytes) -> bool:
    return any(
        isinstance(n, ast.Constant) and n.value == value
        for n in ast.walk(sf.tree)
    )


def _functions(sf: SourceFile):
    for node in ast.walk(sf.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _unpack_call_layout(
    node: ast.AST, layouts: Dict[str, LayoutSpec]
) -> Optional[LayoutSpec]:
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in ("unpack", "unpack_from")
        and isinstance(node.func.value, ast.Name)
    ):
        return layouts.get(node.func.value.id)
    return None


def _check_parse_site(
    sf: SourceFile, fn: ast.AST, layouts: Dict[str, LayoutSpec]
) -> List[Violation]:
    out: List[Violation] = []
    # name -> (field, bound_line) for names bound by tuple-unpack of a layout
    bound: Dict[str, Tuple[FieldSpec, int]] = {}

    for node in ast.walk(fn):
        if not isinstance(node, ast.Assign):
            continue
        spec = _unpack_call_layout(node.value, layouts)
        if spec is None:
            continue
        target = node.targets[0] if len(node.targets) == 1 else None
        if isinstance(target, ast.Tuple):
            names = target.elts
            if len(names) != len(spec.value_fields):
                out.append(
                    Violation(
                        "spec-arity",
                        sf.rel,
                        node.lineno,
                        f"{spec.var}.unpack target unpacks "
                        f"{len(names)} names but the layout declares "
                        f"{len(spec.value_fields)} value fields",
                    )
                )
                continue
            for name_node, fld in zip(names, spec.value_fields):
                if isinstance(name_node, ast.Name):
                    bound[name_node.id] = (fld, node.lineno)

    if not bound:
        return out

    # Guards: lines of Compare nodes / min() calls mentioning a bound name.
    guard_lines: Dict[str, List[int]] = {n: [] for n in bound}

    def names_in(e: ast.AST):
        return {
            n.id for n in ast.walk(e) if isinstance(n, ast.Name)
        } & set(bound)

    for node in ast.walk(fn):
        if isinstance(node, ast.Compare):
            for n in names_in(node):
                guard_lines[n].append(node.lineno)
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            if node.func.id == "min":
                for n in names_in(node):
                    guard_lines[n].append(node.lineno)

    def guarded(name: str, before_line: int) -> bool:
        return any(line <= before_line for line in guard_lines[name])

    # Allocation sinks fed directly by a bound wide-integer name.
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        sink = None
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "read"
            and node.args
        ):
            sink = node.args[0]
            what = "a .read() of"
        elif (
            isinstance(node.func, ast.Name)
            and node.func.id in _ALLOC_BUILTINS
            and node.args
        ):
            sink = node.args[0]
            what = f"a {node.func.id}() of"
        if sink is None or not isinstance(sink, ast.Name):
            continue
        info = bound.get(sink.id)
        if info is None:
            continue
        fld, _bline = info
        if fld.is_pad or fld.fmt.endswith("s") or fld.width <= 1:
            continue  # strings / 1-byte fields can't drive huge allocations
        if guarded(sink.id, node.lineno):
            continue
        out.append(
            Violation(
                "spec-unchecked-length",
                sf.rel,
                node.lineno,
                f"{what} {sink.id} (u{fld.width * 8} wire field "
                f"'{fld.name}') with no prior bounds check — a corrupt "
                "length field drives an unbounded allocation; clamp "
                "(min) or validate first",
            )
        )
    return out
