"""Knob-threading checker: ``threads`` / ``backend`` / ``entropy_backend``
forwarded end-to-end.

The repo's invariant only holds if every entry point threads the three
execution knobs down to ``engine`` / ``device_*`` unchanged — a dropped
kwarg silently re-defaults a layer and the parity suite catches it only if
a test happens to cross that edge with a non-default value.  This family
checks the whole call graph statically.

Scope: the modules that form the public compression surface and its
plumbing (``core/zipnn.py``, ``core/engine.py``, ``checkpoint/manager.py``,
``checkpoint/hub.py``, ``distributed/grad_sync.py``,
``serve/compressed.py`` + the ring scheduler in ``serve/step.py``).

Model
-----
* A function *has* a knob K if K is among its parameters, or it is a
  method of a class whose ``__init__`` takes K (instance-carried, e.g.
  ``CompressWriter._compress`` via ``self._backend``).
* A call edge caller→callee where the caller has K and the callee accepts
  K must pass K — by keyword, positionally, or via ``**kwargs``:

  - passes nothing for K           → ``knob-dropped``
  - passes a non-None literal      → ``knob-redefault`` (overrides the
    caller's knob with a constant; if intentional, suppress with a reason)
  - explicit ``K=None``            → allowed (None is the "derive from
    config" default everywhere on this surface)

* The unified bag (``core/options.py``) is a knob too: ``options`` rides
  the same model, so dropping it on an edge is caught like any other.  An
  edge that binds ``options=`` to a non-None value *supersedes* the three
  legacy knob checks on that edge — the bag carries them, which is the
  whole point of the redesign.  ``CodecOptions(...)`` constructor calls
  themselves are exempt: building a bag from locals (or an intentional
  constant, e.g. the host fallback for device-skipped leaves) IS the
  forwarding mechanism, and the edge that consumes the bag is where
  threading is enforced.

* Callers *without* K in scope are exempt: passing knobs via a config
  object (``CheckpointManager`` / ``CheckpointConfig.zipnn``) is the
  sanctioned config-carried path.

``knob-surface`` pins the public contract: the declared entry points must
keep accepting their knob sets, so a signature regression is caught even
though no in-repo call exercises it.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .base import Project, SourceFile, Violation

FAMILY = "knobs"
RULES = ("knob-dropped", "knob-redefault", "knob-surface")

LEGACY_KNOBS = ("threads", "backend", "entropy_backend")
BAG = "options"
KNOBS = LEGACY_KNOBS + (BAG,)

SCOPE = (
    "src/repro/core/zipnn.py",
    "src/repro/core/engine.py",
    "src/repro/core/options.py",
    "src/repro/checkpoint/",
    "src/repro/distributed/",
    "src/repro/serve/",
)

# The bag constructor: building a CodecOptions from knob locals (or an
# intentional constant) is itself the forwarding act — its edges are exempt.
_BAG_CLASS = "CodecOptions"

# The public-surface contract: entry point -> knobs it must accept.
# Decompression takes entropy_backend too: the container records the
# *coder*, but the knob picks where its Huffman chunks decode (host work
# items vs the device decoder kernel) — bytes identical either way.
# Every legacy entry point must now ALSO accept the unified options= bag
# (the api_redesign contract); new surfaces (session, KV tier) are
# bag-only — they never grew the loose kwargs.
_CBE = frozenset(("threads", "backend", "entropy_backend"))
_CBEO = _CBE | frozenset((BAG,))
_O = frozenset((BAG,))
SURFACE: Dict[str, Dict[str, frozenset]] = {
    "src/repro/core/zipnn.py": {
        "compress_bytes": _CBEO,
        "compress_array": _CBEO,
        "compress_pytree": _CBEO,
        "delta_compress": _CBEO,
        "delta_compress_batched": _CBEO,
        "decompress_bytes": _CBEO,
        "decompress_array": _CBEO,
        "decompress_pytree": _CBEO,
        "delta_decompress": _CBEO,
    },
    "src/repro/core/engine.py": {
        "compress_file": _CBEO,
        "CompressWriter": _CBEO,
        "decompress_file": _CBEO,
        "DecompressReader": _CBEO,
    },
    # The bag itself: CodecOptions must keep its three codec-knob fields
    # (device_resident is a semantic flag, outside the knob set), the shim
    # must accept bag + legacy kwargs, the session is bag-only.
    "src/repro/core/options.py": {
        "CodecOptions": _CBE,
        "resolve_options": _CBEO,
        "ZipNNSession": _O,
    },
    "src/repro/checkpoint/hub.py": {
        "simulate_transfer": _CBEO,
        "simulate_file_transfer": _CBEO,
    },
    "src/repro/checkpoint/manager.py": {
        "CheckpointConfig": _CBEO,
    },
    "src/repro/distributed/grad_sync.py": {
        "GradSync": _CBEO,
    },
    # The compressed-resident serving store carries the knobs for every
    # ring decode; the ring scheduler itself is knob-free (store-carried,
    # like CheckpointManager's config-carried path).
    "src/repro/serve/compressed.py": {
        "CompressedParamStore": _CBEO,
    },
    "src/repro/serve/kvcache.py": {
        "KVCacheStore": _O,
    },
}


@dataclass
class Callable_:
    """A resolvable call target: function, method, or class constructor."""

    name: str
    rel: str
    lineno: int
    params: Tuple[str, ...]  # positional+kw params, self/cls stripped
    has_kwargs: bool
    knob_fields: Set[str] = field(default_factory=set)  # classes: knob fields

    def knobs(self) -> Set[str]:
        return {k for k in KNOBS if k in self.params} | self.knob_fields


def _func_params(fn: ast.FunctionDef) -> Tuple[Tuple[str, ...], bool]:
    a = fn.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    if names and names[0] in ("self", "cls"):
        names = names[1:]
    return tuple(names), a.kwarg is not None


def _collect(project: Project) -> Dict[str, List[Callable_]]:
    """Registry: bare name -> candidates, across all scope modules."""
    reg: Dict[str, List[Callable_]] = {}

    def add(c: Callable_) -> None:
        reg.setdefault(c.name, []).append(c)

    for sf in project.under(*SCOPE):
        for node in sf.tree.body:
            if isinstance(node, ast.FunctionDef):
                params, kw = _func_params(node)
                add(Callable_(node.name, sf.rel, node.lineno, params, kw))
            elif isinstance(node, ast.ClassDef):
                fields: Set[str] = set()
                init: Optional[ast.FunctionDef] = None
                for item in node.body:
                    if isinstance(item, ast.AnnAssign) and isinstance(
                        item.target, ast.Name
                    ):
                        if item.target.id in KNOBS:
                            fields.add(item.target.id)
                    elif isinstance(item, ast.FunctionDef):
                        if item.name == "__init__":
                            init = item
                        params, kw = _func_params(item)
                        add(
                            Callable_(
                                item.name, sf.rel, item.lineno, params, kw
                            )
                        )
                if init is not None:
                    params, kw = _func_params(init)
                else:
                    params, kw = tuple(sorted(fields)), False
                add(
                    Callable_(
                        node.name, sf.rel, node.lineno, params, kw, fields
                    )
                )
    return reg


def _class_init_knobs(sf: SourceFile, cls: ast.ClassDef) -> Set[str]:
    knobs: Set[str] = set()
    for item in cls.body:
        if isinstance(item, ast.FunctionDef) and item.name == "__init__":
            params, _ = _func_params(item)
            knobs |= {k for k in KNOBS if k in params}
        if isinstance(item, ast.AnnAssign) and isinstance(item.target, ast.Name):
            if item.target.id in KNOBS:
                knobs.add(item.target.id)
    return knobs


def _caller_knobs(sf: SourceFile, node: ast.AST) -> Set[str]:
    """Knobs in scope at ``node``: enclosing function params + the class's
    instance-carried knobs (its ``__init__`` params / annotated fields)."""
    fn = sf.enclosing_function(node)
    if fn is None:
        return set()
    params, _ = _func_params(fn)
    knobs = {k for k in KNOBS if k in params}
    cur = sf.parent(fn)
    while cur is not None:
        if isinstance(cur, ast.ClassDef):
            knobs |= _class_init_knobs(sf, cur)
            break
        cur = sf.parent(cur)
    return knobs


def _passed_value(call: ast.Call, callee: Callable_, knob: str):
    """(found, value): how this call binds ``knob`` in the callee.

    Returns (True, node-or-None) when bound (None value = bound via
    ``**kwargs`` or unmappable positionals — treated as forwarded), else
    (False, None).
    """
    for kw in call.keywords:
        if kw.arg == knob:
            return True, kw.value
        if kw.arg is None:  # **kwargs forwarding
            return True, None
    try:
        idx = callee.params.index(knob)
    except ValueError:
        return False, None
    if any(isinstance(a, ast.Starred) for a in call.args[: idx + 1]):
        return True, None  # *args before the slot: not statically mappable
    if idx < len(call.args):
        return True, call.args[idx]
    return False, None


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    reg = _collect(project)

    # --- call-edge checks --------------------------------------------------
    for sf in project.under(*SCOPE):
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Attribute):
                tail = fn.attr
            elif isinstance(fn, ast.Name):
                tail = fn.id
            else:
                continue
            if tail == _BAG_CLASS:
                continue  # bag construction IS the forwarding mechanism
            candidates = reg.get(tail, ())
            caller = _caller_knobs(sf, node)
            for cand in candidates:
                # An edge that forwards a non-None options= bag satisfies
                # the three legacy knobs — they ride inside it.
                bag_bound = False
                if BAG in cand.params or cand.has_kwargs:
                    bfound, bval = _passed_value(node, cand, BAG)
                    bag_bound = bfound and not (
                        isinstance(bval, ast.Constant) and bval.value is None
                    )
                for knob in KNOBS:
                    if knob not in caller or knob not in cand.params:
                        continue
                    if bag_bound and knob != BAG:
                        continue
                    found, value = _passed_value(node, cand, knob)
                    if not found:
                        out.append(
                            Violation(
                                "knob-dropped",
                                sf.rel,
                                node.lineno,
                                f"call to {cand.name}() drops {knob}= even "
                                f"though {knob} is in scope here — the "
                                "callee silently falls back to its default",
                            )
                        )
                    elif (
                        isinstance(value, ast.Constant)
                        and value.value is not None
                    ):
                        out.append(
                            Violation(
                                "knob-redefault",
                                sf.rel,
                                node.lineno,
                                f"call to {cand.name}() re-defaults "
                                f"{knob}={value.value!r} while the caller's "
                                f"{knob} is in scope — forward it, or "
                                "suppress with a reason if the constant is "
                                "intentional",
                            )
                        )

    # --- public-surface contract ------------------------------------------
    for rel, wanted in SURFACE.items():
        sf = project.get(rel)
        if sf is None:
            continue  # partial project (unit tests) — only check present files
        present = {
            c.name: c for c in sum(reg.values(), []) if c.rel == rel
        }
        for name, knobs in wanted.items():
            cand = present.get(name)
            if cand is None:
                out.append(
                    Violation(
                        "knob-surface",
                        rel,
                        1,
                        f"public entry point {name}() is missing from the "
                        "compression surface",
                    )
                )
                continue
            missing = knobs - set(cand.params) - cand.knob_fields
            if missing:
                out.append(
                    Violation(
                        "knob-surface",
                        rel,
                        cand.lineno,
                        f"{name}() must accept knob(s) "
                        f"{', '.join(sorted(missing))} — the public "
                        "surface contract (docs/INVARIANTS.md)",
                    )
                )
    return out
