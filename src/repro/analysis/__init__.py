"""zipnn-lint: repo-specific static analysis for the ZipNN reproduction.

The test suite can only *sample* the repo's central invariant — compressed
blobs byte-identical across ``backend`` x ``entropy_backend`` x ``threads``
(ROADMAP "Invariant to preserve").  This package checks, on every line of
every PR, the bug classes that would silently break it:

* :mod:`.determinism`   — nondeterminism sources on codec paths
                          (wall clocks, RNGs, set/fs iteration order,
                          ``id()`` keys, float-derived byte sizes).
* :mod:`.knobs`         — ``backend`` / ``entropy_backend`` / ``threads``
                          kwargs forwarded end-to-end from the public
                          compression surface down to the engine, with no
                          call edge dropping or re-defaulting them.
* :mod:`.container_spec`— the ZNN1/ZNS1 wire layouts declared once as
                          field tables, cross-checked against every
                          ``struct`` format string, plus bounds checks
                          before length-driven allocations at parse sites.
* :mod:`.kernel_contract`— Pallas kernel contracts: arity, ``index_map``
                          vs grid rank, block coverage, declared dtypes.

Pure stdlib (``ast``) — importing this package must never pull in jax or
numpy, so the lint CI job runs on a bare Python.

Suppressions: ``# zipnn: allow(<rule>): <reason>`` on the flagged line or
the line above.  The reason is mandatory.  See docs/INVARIANTS.md.
"""

from __future__ import annotations

from .base import Project, SourceFile, Violation, analyze_project, analyze_source
from .driver import find_repo_root, run_repo

__all__ = [
    "Project",
    "SourceFile",
    "Violation",
    "analyze_project",
    "analyze_source",
    "find_repo_root",
    "run_repo",
]
