"""Shared infrastructure for zipnn-lint rules.

A *rule family* is a module exposing ``FAMILY`` (str) and
``check(project) -> list[Violation]``.  Families see the whole
:class:`Project` so cross-file rules (the knob-threading call graph) get
the same interface as single-file ones.

Suppression syntax (docs/INVARIANTS.md)::

    something_flagged()  # zipnn: allow(det-wallclock): reason why this is ok

A suppression covers its own line and the line directly below it (so a
comment placed above a long call suppresses the call).  The reason after
the colon is mandatory — an allow() without one is itself reported as
``bad-suppression`` and is ignored as a suppression.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_ALLOW_RE = re.compile(
    r"#\s*zipnn:\s*allow\(\s*(?P<rules>[a-zA-Z0-9_\-,\s]+)\s*\)\s*(?P<colon>:)?\s*(?P<reason>.*)$"
)


@dataclass(frozen=True)
class Violation:
    """One finding: ``path:line: [rule] message``."""

    rule: str
    path: str  # repo-relative, forward slashes
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]
    reason: str


@dataclass
class SourceFile:
    """A parsed module plus its suppression comments and a parent map."""

    rel: str  # repo-relative path, forward slashes
    text: str
    tree: ast.AST
    suppressions: List[Suppression] = field(default_factory=list)
    _parents: Optional[Dict[ast.AST, ast.AST]] = None

    @classmethod
    def parse(cls, rel: str, text: str) -> "SourceFile":
        tree = ast.parse(text, filename=rel)
        sf = cls(rel=rel, text=text, tree=tree)
        for lineno, line in enumerate(text.splitlines(), start=1):
            m = _ALLOW_RE.search(line)
            if m is None:
                continue
            rules = tuple(
                r.strip() for r in m.group("rules").split(",") if r.strip()
            )
            reason = (m.group("reason") or "").strip()
            if not m.group("colon"):
                reason = ""
            sf.suppressions.append(Suppression(lineno, rules, reason))
        return sf

    @property
    def name(self) -> str:
        return self.rel.rsplit("/", 1)[-1]

    def parents(self) -> Dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {}
            for parent in ast.walk(self.tree):
                for child in ast.iter_child_nodes(parent):
                    self._parents[child] = parent
        return self._parents

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents().get(node)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parent(cur)
        return None


@dataclass
class Project:
    """The set of files under analysis, keyed by repo-relative path."""

    files: List[SourceFile]

    def __post_init__(self) -> None:
        self.by_rel = {f.rel: f for f in self.files}

    def under(self, *prefixes: str) -> List[SourceFile]:
        return [
            f for f in self.files if any(f.rel.startswith(p) for p in prefixes)
        ]

    def get(self, rel: str) -> Optional[SourceFile]:
        return self.by_rel.get(rel)


# ---------------------------------------------------------------------------
# AST helpers shared by rule families
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``np.random.default_rng`` -> that string; None if not a name chain."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def call_tail(node: ast.Call) -> Optional[str]:
    """Final attribute/name of a call target: ``a.b.c(...)`` -> ``c``."""
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def is_call_to(node: ast.AST, *dotted: str) -> bool:
    """True if ``node`` is a Call whose dotted target ends with any of
    ``dotted`` (so ``numpy.random.random`` matches ``np.random.random``
    via the suffix ``random.random``)."""
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func)
    if name is None:
        return False
    return any(name == d or name.endswith("." + d) for d in dotted)


def const_int(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) and not isinstance(node.value, bool):
        return node.value
    return None


def node_fingerprint(node: ast.AST) -> str:
    """Location/ctx-insensitive dump for symbolic expression equality."""
    class _Strip(ast.NodeTransformer):
        def visit(self, n: ast.AST) -> ast.AST:  # noqa: D102
            self.generic_visit(n)
            for attr in ("lineno", "col_offset", "end_lineno", "end_col_offset"):
                if hasattr(n, attr):
                    try:
                        delattr(n, attr)
                    except AttributeError:
                        pass
            if isinstance(n, (ast.Load, ast.Store, ast.Del)):
                return ast.Load()
            return n

    import copy

    return ast.dump(_Strip().visit(copy.deepcopy(node)))


# ---------------------------------------------------------------------------
# Running families + suppression filtering
# ---------------------------------------------------------------------------

def _suppressed(sf: SourceFile, v: Violation) -> bool:
    for sup in sf.suppressions:
        if not sup.reason:
            continue  # reason-less allow() never suppresses
        if sup.line in (v.line, v.line - 1) and v.rule in sup.rules:
            return True
    return False


def suppression_violations(
    project: Project, known_rules: Optional[Set[str]] = None
) -> List[Violation]:
    """``bad-suppression`` findings: missing reason, or unknown rule name."""
    out: List[Violation] = []
    for sf in project.files:
        for sup in sf.suppressions:
            if not sup.reason:
                out.append(
                    Violation(
                        "bad-suppression",
                        sf.rel,
                        sup.line,
                        "zipnn: allow(...) requires a reason — write "
                        "'# zipnn: allow(<rule>): <why this is safe>'",
                    )
                )
            if known_rules is not None:
                for r in sup.rules:
                    if r not in known_rules:
                        out.append(
                            Violation(
                                "bad-suppression",
                                sf.rel,
                                sup.line,
                                f"allow({r}) names an unknown rule",
                            )
                        )
    return out


def analyze_project(
    project: Project,
    families: Optional[Sequence] = None,
    known_rules: Optional[Set[str]] = None,
) -> List[Violation]:
    """Run rule families over ``project``; returns unsuppressed violations
    plus any ``bad-suppression`` findings, sorted by (path, line, rule)."""
    if families is None:
        families = default_families()
    raw: List[Violation] = []
    for fam in families:
        raw.extend(fam.check(project))
    if known_rules is None:
        known_rules = set()
        for fam in families:
            known_rules.update(getattr(fam, "RULES", ()))
    out: List[Violation] = []
    for v in raw:
        sf = project.get(v.path)
        if sf is not None and _suppressed(sf, v):
            continue
        out.append(v)
    out.extend(suppression_violations(project, known_rules))
    out.sort(key=lambda v: (v.path, v.line, v.rule, v.message))
    return out


def default_families() -> List:
    from . import container_spec, determinism, kernel_contract, knobs

    return [determinism, knobs, container_spec, kernel_contract]


def analyze_source(
    code: str, rel: str, families: Optional[Sequence] = None
) -> List[Violation]:
    """Analyze a single in-memory module as if it lived at repo path ``rel``.

    Test entry point: rule scoping is path-prefix based, so fixtures pick
    their rule exposure via the virtual path (e.g.
    ``src/repro/core/fake.py`` opts into the determinism + spec scopes).
    """
    project = Project([SourceFile.parse(rel, code)])
    return analyze_project(project, families=families)
