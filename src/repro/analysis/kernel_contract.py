"""Pallas kernel-contract checker for ``src/repro/kernels``.

Pallas failure modes are silent in exactly the way a lossless codec cannot
afford: a block shape that does not tile the grid quietly reads garbage
rows, an ``index_map`` with the wrong arity dies only at trace time on the
path that exercises it, and a dtype mismatch between a kernel store and
its declared ``out_shape`` truncates bytes.  Every wrapper here is checked
against a declared contract table.

Rules
-----
kernel-registry     every module-level function in ``kernels/`` that
                    issues a ``pl.pallas_call`` must be registered in
                    ``KERNEL_CONTRACT`` (the declared output dtypes).
kernel-arity        kernel function parameter count must equal
                    ``len(in_specs) + len(out_specs)`` (refs are passed
                    inputs-then-outputs).
kernel-index-map    each ``BlockSpec`` index lambda takes exactly one
                    argument per grid dimension and returns one index per
                    block dimension.
kernel-block-shape  a spec indexed by a bare grid variable must tile its
                    array exactly: under ``grid=(E // D,)`` the block dim
                    must be ``D`` (for outputs, the declared shape must
                    equal grid x block).  Composite / constant index
                    expressions (revisit-and-accumulate patterns) are
                    skipped.
kernel-dtype        ``astype`` stores into output refs and declared
                    ``ShapeDtypeStruct`` dtypes must match the contract
                    table.
kernel-interpret    every ``pallas_call`` must thread ``interpret=`` from
                    a wrapper parameter — CPU CI runs interpret mode, so a
                    hardcoded value would silently pin one backend.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .base import Project, SourceFile, Violation, dotted_name, node_fingerprint

FAMILY = "kernel_contract"
RULES = (
    "kernel-registry",
    "kernel-arity",
    "kernel-index-map",
    "kernel-block-shape",
    "kernel-dtype",
    "kernel-interpret",
)

SCOPE = ("src/repro/kernels/",)

# Declared output dtypes per public kernel wrapper (None = runtime-selected
# or input-following; unchecked).  A new pallas_call wrapper must be
# registered here — that IS the contract declaration.
KERNEL_CONTRACT: Dict[str, Tuple[Optional[str], ...]] = {
    "bytegroup_bf16_2d": ("uint8", "uint8"),
    "ungroup_bf16_2d": ("uint16",),
    "bytegroup_fp32_2d": ("uint8", "uint8", "uint8", "uint8"),
    "ungroup_fp32_2d": ("uint32",),
    "histogram_2d": ("int32",),
    "chunk_histogram_2d": ("int32",),
    "xor_elems_2d": (None,),
    "xor_delta_2d": ("uint32", "int32"),
    "bitpack_encode_chunks": ("uint32", "int32"),
    "bitpack_encode_chunks_multi": ("uint32", "int32"),
    "huffdecode_chunks_multi": ("uint8", "int32"),
    "plane_consumer": (None,),
}


@dataclass
class Spec:
    """A resolved BlockSpec: shape dim nodes + index lambda, after helper
    parameter substitution."""

    shape: Optional[List[ast.AST]]  # None if not a tuple literal
    index: Optional[ast.Lambda]
    lineno: int


@dataclass
class SpecList:
    specs: List[Spec] = field(default_factory=list)  # distinct spec exprs
    count: Optional[int] = None  # total entries, None if unresolvable


def _module_functions(sf: SourceFile) -> Dict[str, ast.FunctionDef]:
    return {
        n.name: n for n in sf.tree.body if isinstance(n, ast.FunctionDef)
    }


def _substitute(node: ast.AST, subst: Dict[str, ast.AST]) -> ast.AST:
    if isinstance(node, ast.Name) and node.id in subst:
        return subst[node.id]
    return node


def _resolve_blockspec(
    node: ast.AST, helpers: Dict[str, ast.FunctionDef]
) -> Optional[Spec]:
    """A ``pl.BlockSpec(shape, index)`` call or a call to a one-line helper
    that returns one (``_spec(rows)``) -> a :class:`Spec`."""
    if not isinstance(node, ast.Call):
        return None
    name = dotted_name(node.func)
    if name is not None and name.split(".")[-1] == "BlockSpec":
        shape_node = node.args[0] if node.args else None
        index_node = node.args[1] if len(node.args) > 1 else None
        shape = (
            list(shape_node.elts) if isinstance(shape_node, ast.Tuple) else None
        )
        index = index_node if isinstance(index_node, ast.Lambda) else None
        return Spec(shape, index, node.lineno)
    # helper function returning a single BlockSpec
    if isinstance(node.func, ast.Name) and node.func.id in helpers:
        fn = helpers[node.func.id]
        body = [s for s in fn.body if not isinstance(s, ast.Expr)]
        if len(body) == 1 and isinstance(body[0], ast.Return):
            inner = _resolve_blockspec(body[0].value, {})
            if inner is not None:
                params = [a.arg for a in fn.args.args]
                subst = {
                    p: arg for p, arg in zip(params, node.args)
                }
                if inner.shape is not None:
                    inner.shape = [_substitute(d, subst) for d in inner.shape]
                inner.lineno = node.lineno
                return inner
    return None


def _resolve_spec_list(
    node: Optional[ast.AST], helpers: Dict[str, ast.FunctionDef]
) -> SpecList:
    out = SpecList()
    if node is None:
        return out
    spec = _resolve_blockspec(node, helpers)
    if spec is not None:
        out.specs = [spec]
        out.count = 1
        return out
    if isinstance(node, ast.List):
        total = 0
        for elt in node.elts:
            s = _resolve_blockspec(elt, helpers)
            if s is None:
                return SpecList(out.specs, None)
            out.specs.append(s)
            total += 1
        out.count = total
        return out
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        base, mult = node.left, node.right
        if isinstance(base, ast.Constant):
            base, mult = mult, base
        inner = _resolve_spec_list(base, helpers)
        out.specs = inner.specs
        if (
            inner.count is not None
            and isinstance(mult, ast.Constant)
            and isinstance(mult.value, int)
        ):
            out.count = inner.count * mult.value
        return out
    return out


@dataclass
class OutShape:
    shape: Optional[List[ast.AST]]
    dtype: Optional[str]  # tail name of the dtype expr, e.g. "uint8"
    lineno: int


def _resolve_out_shapes(node: Optional[ast.AST]) -> Tuple[List[OutShape], Optional[int]]:
    if node is None:
        return [], None

    def one(n: ast.AST) -> Optional[OutShape]:
        if isinstance(n, ast.Call):
            name = dotted_name(n.func) or ""
            if name.split(".")[-1] == "ShapeDtypeStruct":
                shape_node = n.args[0] if n.args else None
                dtype_node = n.args[1] if len(n.args) > 1 else None
                shape = (
                    list(shape_node.elts)
                    if isinstance(shape_node, ast.Tuple)
                    else None
                )
                dname = dotted_name(dtype_node) if dtype_node is not None else None
                dtype = dname.split(".")[-1] if dname else None
                return OutShape(shape, dtype, n.lineno)
        return None

    s = one(node)
    if s is not None:
        return [s], 1
    if isinstance(node, ast.List):
        outs = []
        for elt in node.elts:
            s = one(elt)
            if s is None:
                return [], None
            outs.append(s)
        return outs, len(outs)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        base, mult = node.left, node.right
        if isinstance(base, ast.Constant):
            base, mult = mult, base
        inner, n_inner = _resolve_out_shapes(base)
        if (
            n_inner is not None
            and isinstance(mult, ast.Constant)
            and isinstance(mult.value, int)
        ):
            return inner, n_inner * mult.value
        return inner, None
    return [], None


def _resolve_kernel_fns(
    arg: ast.AST, sf: SourceFile, wrapper: ast.FunctionDef
) -> List[ast.FunctionDef]:
    mod_fns = _module_functions(sf)
    if isinstance(arg, ast.Name):
        if arg.id in mod_fns:
            return [mod_fns[arg.id]]
        # local variable: kern = A if cond else B (or plain kern = A)
        cands: List[ast.FunctionDef] = []
        for node in ast.walk(wrapper):
            if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == arg.id
                for t in node.targets
            ):
                v = node.value
                exprs = (
                    [v.body, v.orelse] if isinstance(v, ast.IfExp) else [v]
                )
                for e in exprs:
                    if isinstance(e, ast.Name) and e.id in mod_fns:
                        cands.append(mod_fns[e.id])
        return cands
    return []


def _one_hop(name_node: ast.AST, wrapper: ast.FunctionDef) -> ast.AST:
    """Resolve a Name grid dim through a single local assignment."""
    if not isinstance(name_node, ast.Name):
        return name_node
    assigns = [
        n.value
        for n in ast.walk(wrapper)
        if isinstance(n, ast.Assign)
        and any(
            isinstance(t, ast.Name) and t.id == name_node.id
            for t in n.targets
        )
    ]
    if len(assigns) == 1:
        return assigns[0]
    return name_node


def _dim_equal(a: ast.AST, b: ast.AST) -> bool:
    return node_fingerprint(a) == node_fingerprint(b)


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    for sf in project.under(*SCOPE):
        out.extend(_check_file(sf))
    return out


def _check_file(sf: SourceFile) -> List[Violation]:
    out: List[Violation] = []
    helpers = _module_functions(sf)

    for wrapper in sf.tree.body:
        if not isinstance(wrapper, ast.FunctionDef):
            continue
        calls = [
            n
            for n in ast.walk(wrapper)
            if isinstance(n, ast.Call)
            and (dotted_name(n.func) or "").split(".")[-1] == "pallas_call"
        ]
        if not calls:
            continue
        contract = KERNEL_CONTRACT.get(wrapper.name)
        if contract is None:
            out.append(
                Violation(
                    "kernel-registry",
                    sf.rel,
                    wrapper.lineno,
                    f"{wrapper.name}() issues a pallas_call but is not "
                    "registered in analysis.kernel_contract."
                    "KERNEL_CONTRACT — declare its output dtypes",
                )
            )
            contract = ()
        for call in calls:
            out.extend(_check_call(sf, wrapper, call, contract, helpers))
    return out


def _check_call(
    sf: SourceFile,
    wrapper: ast.FunctionDef,
    call: ast.Call,
    contract: Tuple[Optional[str], ...],
    helpers: Dict[str, ast.FunctionDef],
) -> List[Violation]:
    out: List[Violation] = []
    kw = {k.arg: k.value for k in call.keywords if k.arg is not None}
    grid = kw.get("grid")
    grid_dims: Optional[List[ast.AST]] = (
        list(grid.elts) if isinstance(grid, ast.Tuple) else None
    )
    in_specs = _resolve_spec_list(kw.get("in_specs"), helpers)
    out_specs = _resolve_spec_list(kw.get("out_specs"), helpers)
    out_shapes, n_shapes = _resolve_out_shapes(kw.get("out_shape"))

    # --- interpret threading ---------------------------------------------
    interp = kw.get("interpret")
    wrapper_params = {a.arg for a in (
        wrapper.args.posonlyargs + wrapper.args.args + wrapper.args.kwonlyargs
    )}
    if interp is None:
        out.append(
            Violation(
                "kernel-interpret",
                sf.rel,
                call.lineno,
                "pallas_call without interpret= — thread the wrapper's "
                "interpret parameter (CPU CI runs interpret mode)",
            )
        )
    elif isinstance(interp, ast.Constant) or not (
        isinstance(interp, ast.Name) and interp.id in wrapper_params
    ):
        out.append(
            Violation(
                "kernel-interpret",
                sf.rel,
                call.lineno,
                "interpret= must come from a wrapper parameter, not a "
                "hardcoded value — CPU CI and TPU runs share this code",
            )
        )

    # --- kernel arity -----------------------------------------------------
    n_out = out_specs.count if out_specs.count is not None else n_shapes
    if in_specs.count is not None and n_out is not None and call.args:
        expected = in_specs.count + n_out
        for fn in _resolve_kernel_fns(call.args[0], sf, wrapper):
            n_params = len(
                fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
            )
            if n_params != expected:
                out.append(
                    Violation(
                        "kernel-arity",
                        sf.rel,
                        call.lineno,
                        f"kernel {fn.name}() takes {n_params} refs but "
                        f"this pallas_call passes {in_specs.count} inputs "
                        f"+ {n_out} outputs",
                    )
                )

    # --- declared output count / dtypes vs contract ------------------------
    if contract:
        if n_shapes is not None and n_shapes != len(contract):
            out.append(
                Violation(
                    "kernel-dtype",
                    sf.rel,
                    call.lineno,
                    f"{wrapper.name}() declares {n_shapes} outputs but "
                    f"KERNEL_CONTRACT registers {len(contract)}",
                )
            )
        elif n_shapes is not None:
            for i, (shape, want) in enumerate(zip(out_shapes, contract)):
                if want is not None and shape.dtype is not None and shape.dtype != want:
                    out.append(
                        Violation(
                            "kernel-dtype",
                            sf.rel,
                            shape.lineno,
                            f"{wrapper.name}() output {i} declared as "
                            f"{shape.dtype} but KERNEL_CONTRACT says {want}",
                        )
                    )

    # --- astype stores inside the kernel vs contract ------------------------
    if contract and in_specs.count is not None and call.args:
        for fn in _resolve_kernel_fns(call.args[0], sf, wrapper):
            params = [
                a.arg
                for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
            ]
            out_params = params[in_specs.count :]
            for node in ast.walk(fn):
                if not (
                    isinstance(node, ast.Assign)
                    and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Subscript)
                    and isinstance(node.targets[0].value, ast.Name)
                    and node.targets[0].value.id in out_params
                ):
                    continue
                idx = out_params.index(node.targets[0].value.id)
                want = contract[idx] if idx < len(contract) else None
                v = node.value
                if (
                    want is not None
                    and isinstance(v, ast.Call)
                    and isinstance(v.func, ast.Attribute)
                    and v.func.attr == "astype"
                    and v.args
                ):
                    dname = dotted_name(v.args[0])
                    got = dname.split(".")[-1] if dname else None
                    if got is not None and got != want:
                        out.append(
                            Violation(
                                "kernel-dtype",
                                sf.rel,
                                node.lineno,
                                f"kernel {fn.name}() stores "
                                f"{got} into output {idx} but "
                                f"KERNEL_CONTRACT declares {want}",
                            )
                        )

    # --- index_map arity + block coverage ----------------------------------
    grid_rank = len(grid_dims) if grid_dims is not None else None
    all_specs = [(s, None) for s in in_specs.specs] + [
        (s, i) for i, s in enumerate(out_specs.specs)
    ]
    for spec, out_idx in all_specs:
        if spec.index is None:
            continue
        lam_params = [a.arg for a in spec.index.args.args]
        if grid_rank is not None and len(lam_params) != grid_rank:
            out.append(
                Violation(
                    "kernel-index-map",
                    sf.rel,
                    spec.lineno,
                    f"index_map takes {len(lam_params)} args but the grid "
                    f"has rank {grid_rank}",
                )
            )
            continue
        body = spec.index.body
        idx_elts = list(body.elts) if isinstance(body, ast.Tuple) else None
        if (
            idx_elts is not None
            and spec.shape is not None
            and len(idx_elts) != len(spec.shape)
        ):
            out.append(
                Violation(
                    "kernel-index-map",
                    sf.rel,
                    spec.lineno,
                    f"index_map returns {len(idx_elts)} indices but the "
                    f"block shape has rank {len(spec.shape)}",
                )
            )
            continue
        if idx_elts is None or spec.shape is None or grid_dims is None:
            continue
        for k, idx in enumerate(idx_elts):
            # only bare grid variables are statically checkable; composite
            # expressions (i * blocks + j) and constants (revisit blocks)
            # are skipped by design
            if not (isinstance(idx, ast.Name) and idx.id in lam_params):
                continue
            d = lam_params.index(idx.id)
            if d >= len(grid_dims):
                continue
            block_dim = spec.shape[k]
            grid_expr = grid_dims[d]
            resolved = _one_hop(grid_expr, wrapper)
            divisor = (
                resolved.right
                if isinstance(resolved, ast.BinOp)
                and isinstance(resolved.op, ast.FloorDiv)
                else None
            )
            if out_idx is not None:
                # outputs: declared shape must equal grid x block
                shape = (
                    out_shapes[out_idx].shape
                    if out_idx < len(out_shapes)
                    else None
                )
                if shape is None or k >= len(shape):
                    continue
                sdim = shape[k]
                prod_ok = (
                    _dim_equal(
                        sdim,
                        ast.BinOp(grid_expr, ast.Mult(), block_dim),
                    )
                    or _dim_equal(
                        sdim,
                        ast.BinOp(block_dim, ast.Mult(), grid_expr),
                    )
                )
                one_ok = (
                    isinstance(block_dim, ast.Constant)
                    and block_dim.value == 1
                    and _dim_equal(sdim, grid_expr)
                )
                div_ok = (
                    divisor is not None
                    and isinstance(resolved, ast.BinOp)
                    and _dim_equal(block_dim, divisor)
                    and _dim_equal(sdim, resolved.left)
                )
                if not (prod_ok or one_ok or div_ok):
                    out.append(
                        Violation(
                            "kernel-block-shape",
                            sf.rel,
                            spec.lineno,
                            f"output {out_idx} dim {k}: declared shape "
                            "must equal grid x block for a bare-index "
                            "spec — partial blocks would read/write "
                            "out of range",
                        )
                    )
            else:
                # inputs: catch the cross-constant copy-paste class
                if (
                    divisor is not None
                    and isinstance(divisor, ast.Name)
                    and isinstance(block_dim, ast.Name)
                    and block_dim.id != divisor.id
                ):
                    out.append(
                        Violation(
                            "kernel-block-shape",
                            sf.rel,
                            spec.lineno,
                            f"input block dim {k} is {block_dim.id} but "
                            f"the grid steps by {divisor.id} — the block "
                            "does not tile the grid",
                        )
                    )
    return out
