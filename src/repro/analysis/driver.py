"""Repo discovery + the zipnn-lint CLI (``python -m repro.analysis``)."""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional, Sequence

from .base import Project, SourceFile, Violation, analyze_project, default_families

# Trees scanned for analysis.  Rule families narrow further by prefix; the
# project still loads all of src/repro so cross-file rules see everything.
SCAN_PREFIX = os.path.join("src", "repro")


def find_repo_root(start: Optional[str] = None) -> str:
    """Repo root = the directory holding ``src/repro`` for this package."""
    here = start or os.path.dirname(os.path.abspath(__file__))
    cur = here
    for _ in range(8):
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        nxt = os.path.dirname(cur)
        if nxt == cur:
            break
        cur = nxt
    raise SystemExit("zipnn-lint: cannot locate repo root (src/repro)")


def load_project(root: str) -> Project:
    files: List[SourceFile] = []
    scan_dir = os.path.join(root, SCAN_PREFIX)
    for dirpath, dirnames, filenames in os.walk(scan_dir):
        dirnames.sort()
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, "r", encoding="utf-8") as f:
                text = f.read()
            try:
                files.append(SourceFile.parse(rel, text))
            except SyntaxError as e:
                raise SystemExit(f"zipnn-lint: cannot parse {rel}: {e}")
    return Project(files)


def run_repo(root: Optional[str] = None) -> List[Violation]:
    root = root or find_repo_root()
    return analyze_project(load_project(root))


def _emit_github(v: Violation) -> str:
    # GitHub Actions annotation: clickable in the PR "Files changed" view.
    msg = v.message.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")
    return f"::error file={v.path},line={v.line},title=zipnn-lint {v.rule}::{msg}"


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="zipnn-lint: static checks for the ZipNN repo invariants "
        "(determinism, knob threading, container spec, kernel contracts). "
        "See docs/INVARIANTS.md.",
    )
    ap.add_argument(
        "--root", default=None, help="repo root (default: auto-detected)"
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="CI mode: exit 1 on any finding, including bad suppressions "
        "(currently identical to the default — reserved so the gate can "
        "stay strict if advisory rules are added)",
    )
    ap.add_argument(
        "--github",
        action="store_true",
        help="also emit GitHub Actions ::error annotations "
        "(auto-enabled when GITHUB_ACTIONS is set)",
    )
    args = ap.parse_args(argv)

    project = load_project(args.root or find_repo_root())
    violations = analyze_project(project)
    github = args.github or bool(os.environ.get("GITHUB_ACTIONS"))
    for v in violations:
        print(v.render())
        if github:
            print(_emit_github(v))
    n_files = len(project.files)
    if violations:
        print(f"zipnn-lint: {len(violations)} violation(s)")
        return 1
    print(f"zipnn-lint: clean ({n_files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
