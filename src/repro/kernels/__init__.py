"""Pallas TPU kernels for the ZipNN compression hot path.

Kernels (each: <name>.py kernel + ref.py oracle + ops.py wrapper):
  * bytegroup   — exponent-extraction / byte-group transform (Fig. 3/5)
  * histogram   — 256-bin byte histogram, whole-array and per-chunk
                  (table building, compressibility probes)
  * bitpack     — parallel Huffman bit-packing (encode hot loop)
  * xor_delta   — checkpoint XOR delta + changed-byte count (§4.2)
  * fused_plane — one-dispatch composition of xor_delta + bytegroup +
                  per-chunk histogram: the engine's device plane-producer
                  backend (see ``core.device_plane``)
  * fused_unplane — the decode mirror: un-byte-group + inverse rotate +
                  inverse XOR-delta in one kernel per dispatch: the
                  engine's device plane-consumer backend (see
                  ``core.device_unplane``)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode against the pure-jnp oracles.
"""

from . import fused_plane, fused_unplane, ops, ref
from .ops import (
    bytegroup_bf16,
    ungroup_bf16,
    bytegroup_fp32,
    ungroup_fp32,
    byte_histogram,
    xor_delta_u32,
    huffman_encode_chunks,
)

__all__ = [
    "ops", "ref", "fused_plane", "fused_unplane", "bytegroup_bf16", "ungroup_bf16",
    "bytegroup_fp32", "ungroup_fp32", "byte_histogram", "xor_delta_u32",
    "huffman_encode_chunks",
]
