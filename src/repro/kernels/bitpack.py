"""Pallas TPU kernel: parallel Huffman bit-packing (the encode hot loop).

CPU Huffman encoders emit bits serially into an accumulator — there is no
TPU analogue of that loop.  The TPU-native formulation (DESIGN.md §3) is
*gather-based stream compaction*:

  1. gather per-symbol (code, length) from the 256-entry canonical table;
  2. inclusive prefix-sum of lengths → every symbol's output bit interval
     (the VPU scan is the only cross-lane dependency);
  3. for every *output* bit ``j``, binary-search the producing symbol in the
     cumulative-lengths vector and gather bit ``j - start[s]`` of its
     left-aligned code field — a pure parallel gather;
  4. reduce groups of 32 bits into uint32 words with a power-of-two
     weighted sum (VPU multiply-add).

One grid step packs one 256 KiB-format chunk, so the kernel's parallelism
matches the container's parallel-decode metadata map.  Output capacity per
chunk equals the raw size: chunks that would expand are stored raw by the
host (the codec's expansion guard), so no dynamic shapes are needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAXL = 15


def _pack_block(syms, lens_tab, codes_tab, words_ref, nbits_ref):
    """Shared kernel body: pack one chunk's symbols under one table."""
    n = syms.shape[0]
    lens = lens_tab[syms]
    codes = codes_tab[syms]
    ends = jnp.cumsum(lens)
    nbits = ends[n - 1]
    starts = ends - lens

    cap_bits = 8 * n
    j = jax.lax.iota(jnp.int32, cap_bits)
    s = jnp.searchsorted(ends, j, side="right").astype(jnp.int32)
    s = jnp.minimum(s, n - 1)
    b = j - starts[s]
    field = codes[s] << (MAXL - lens[s])
    bit = (field >> (MAXL - 1 - b)) & 1
    bit = jnp.where(j < nbits, bit, 0)

    # Weighted reduce in two exact int32 halves (≤ 2^16 each), then splice.
    pow16 = 1 << (15 - jax.lax.iota(jnp.int32, 16))
    groups = bit.reshape(-1, 32)
    hi = jnp.sum(groups[:, :16] * pow16[None, :], axis=1)
    lo = jnp.sum(groups[:, 16:] * pow16[None, :], axis=1)
    words_ref[...] = ((hi.astype(jnp.uint32) << 16) | lo.astype(jnp.uint32))
    nbits_ref[0] = nbits


def _bitpack_kernel(syms_ref, len_ref, code_ref, words_ref, nbits_ref):
    syms = syms_ref[...].reshape(-1).astype(jnp.int32)
    _pack_block(syms, len_ref[...], code_ref[...], words_ref, nbits_ref)


def _bitpack_multi_kernel(pid_ref, len_ref, code_ref, syms_ref, words_ref, nbits_ref):
    """Per-chunk table selection: chunk ``i`` packs under table row
    ``pid_ref[0]`` of the stacked ``(P, 256)`` canonical tables — the
    multi-plane form (every plane of a tensor has its own table, but all
    planes' chunks ride ONE dispatch)."""
    pid = pid_ref[0]
    lens_tab = jax.lax.dynamic_index_in_dim(len_ref[...], pid, axis=0, keepdims=False)
    codes_tab = jax.lax.dynamic_index_in_dim(code_ref[...], pid, axis=0, keepdims=False)
    syms = syms_ref[...].reshape(-1).astype(jnp.int32)
    _pack_block(syms, lens_tab, codes_tab, words_ref, nbits_ref)


@functools.partial(jax.jit, static_argnames=("chunk_syms", "interpret"))
def bitpack_encode_chunks(
    syms: jax.Array,
    len_table: jax.Array,
    code_table: jax.Array,
    *,
    chunk_syms: int = 1 << 13,
    interpret: bool = True,
):
    """uint8[C*chunk_syms] → (uint32[C, chunk_syms/4], int32[C]).

    ``chunk_syms`` symbols per grid step (per container chunk).  Returns
    packed words (raw-size capacity) and true bit counts per chunk.
    """
    n = syms.shape[0]
    assert n % chunk_syms == 0, "pad to whole chunks on the host"
    c = n // chunk_syms
    words, nbits = pl.pallas_call(
        _bitpack_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((chunk_syms,), lambda i: (i,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk_syms // 4,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c * (chunk_syms // 4),), jnp.uint32),
            jax.ShapeDtypeStruct((c,), jnp.int32),
        ],
        interpret=interpret,
    )(syms, len_table.astype(jnp.int32), code_table.astype(jnp.int32))
    return words.reshape(c, chunk_syms // 4), nbits


@functools.partial(jax.jit, static_argnames=("chunk_syms", "interpret"))
def bitpack_encode_chunks_multi(
    syms: jax.Array,
    plane_ids: jax.Array,
    len_tables: jax.Array,
    code_tables: jax.Array,
    *,
    chunk_syms: int = 1 << 13,
    interpret: bool = True,
):
    """Multi-table variant: chunk ``i`` packs under table ``plane_ids[i]``.

    ``syms`` is uint8[C*chunk_syms] (chunks from *different planes*
    concatenated), ``plane_ids`` int32[C] selects a row of the stacked
    ``(P, 256)`` length/code tables per chunk.  One dispatch covers every
    (plane, chunk) Huffman work item of a tensor.  Returns
    ``(uint32[C, chunk_syms/4], int32[C])`` like
    :func:`bitpack_encode_chunks`.
    """
    n = syms.shape[0]
    assert n % chunk_syms == 0, "pad to whole chunks on the host"
    c = n // chunk_syms
    p = len_tables.shape[0]
    words, nbits = pl.pallas_call(
        _bitpack_multi_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((p, 256), lambda i: (0, 0)),
            pl.BlockSpec((p, 256), lambda i: (0, 0)),
            pl.BlockSpec((chunk_syms,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk_syms // 4,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c * (chunk_syms // 4),), jnp.uint32),
            jax.ShapeDtypeStruct((c,), jnp.int32),
        ],
        interpret=interpret,
    )(
        plane_ids.astype(jnp.int32),
        len_tables.astype(jnp.int32),
        code_tables.astype(jnp.int32),
        syms,
    )
    return words.reshape(c, chunk_syms // 4), nbits
