"""Pure-jnp oracles for the ZipNN Pallas kernels.

Every kernel in this package has its reference semantics defined here, in
plain ``jnp`` ops on whole arrays.  Kernel tests sweep shapes/dtypes and
``assert_allclose`` (exact equality — these are bit-manipulation ops)
against these functions, with the Pallas kernels running in interpret mode.

Semantics notes
---------------
* ``bytegroup_*``: rotate-left-1 on the scalar's uint image, then split into
  byte planes MSB-first — plane 0 is the pure biased exponent for
  BF16/FP32 (paper Fig. 3/5).  Mirrors ``core.bitlayout``.
* ``histogram``: 256-bin byte histogram (int32 counts).
* ``bitpack_encode``: two-pass parallel Huffman packing.  For each output
  bit ``j``, the producing symbol is found with a monotone searchsorted over
  the cumulative code lengths, then the bit is gathered from the symbol's
  left-aligned code field.  MSB-first within each 32-bit word, words
  concatenated big-endian — byte-identical to ``np.packbits`` of the bit
  string (and to ``core.huffman.encode``).
* ``xor_delta``: elementwise XOR (+ count of changed bytes per call, the
  Fig. 8(a) statistic).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

MAXL = 15  # max Huffman code length (core.huffman.MAX_CODE_LEN)


# ---------------------------------------------------------------------------
# byte grouping / exponent extraction
# ---------------------------------------------------------------------------

def bytegroup_bf16(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """uint16[N] -> (exponent uint8[N], frac|sign uint8[N])."""
    x = x.astype(jnp.uint16)
    rot = ((x << 1) | (x >> 15)).astype(jnp.uint16)
    return (rot >> 8).astype(jnp.uint8), (rot & 0xFF).astype(jnp.uint8)


def ungroup_bf16(exp: jnp.ndarray, frac: jnp.ndarray) -> jnp.ndarray:
    rot = (exp.astype(jnp.uint16) << 8) | frac.astype(jnp.uint16)
    return ((rot >> 1) | (rot << 15)).astype(jnp.uint16)


def bytegroup_fp32(x: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """uint32[N] -> 4 uint8[N] planes, plane 0 = exponent."""
    x = x.astype(jnp.uint32)
    rot = ((x << 1) | (x >> 31)).astype(jnp.uint32)
    return tuple(
        ((rot >> (8 * (3 - i))) & 0xFF).astype(jnp.uint8) for i in range(4)
    )


def ungroup_fp32(*planes: jnp.ndarray) -> jnp.ndarray:
    rot = jnp.zeros_like(planes[0], dtype=jnp.uint32)
    for i, p in enumerate(planes):
        rot = rot | (p.astype(jnp.uint32) << (8 * (3 - i)))
    return ((rot >> 1) | (rot << 31)).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------

def histogram(x: jnp.ndarray) -> jnp.ndarray:
    """uint8[...] -> int32[256] counts."""
    x = x.reshape(-1).astype(jnp.int32)
    bins = jnp.arange(256, dtype=jnp.int32)
    return jnp.sum(
        (x[None, :] == bins[:, None]).astype(jnp.int32), axis=1
    )


def chunk_histogram(x: jnp.ndarray, chunk_elems: int) -> jnp.ndarray:
    """uint8[N] (N % chunk_elems == 0) -> int32[N // chunk_elems, 256]."""
    x = x.reshape(-1, chunk_elems).astype(jnp.int32)
    bins = jnp.arange(256, dtype=jnp.int32)
    return jnp.sum(
        (x[:, None, :] == bins[None, :, None]).astype(jnp.int32), axis=2
    )


# ---------------------------------------------------------------------------
# Huffman bit-pack
# ---------------------------------------------------------------------------

def bitpack_encode(
    syms: jnp.ndarray, len_table: jnp.ndarray, code_table: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pack Huffman codes of ``syms`` into 32-bit words.

    Args:
      syms: uint8[N] symbols.
      len_table: int32[256] code lengths (1..15; 0 = absent symbol).
      code_table: int32[256] canonical code values.

    Returns:
      words: uint32[ceil(8*N/32)] — capacity equals the raw size; if the
        encoding would exceed it (incompressible chunk — the host stores raw
        in that case, mirroring the codec's expansion guard), the tail is
        truncated.
      nbits: int32[] — true number of encoded bits.
    """
    n = syms.shape[0]
    syms_i = syms.astype(jnp.int32)
    lens = len_table[syms_i]
    codes = code_table[syms_i]
    ends = jnp.cumsum(lens)                     # inclusive prefix sum
    nbits = ends[-1] if n else jnp.int32(0)
    starts = ends - lens

    cap_bits = 8 * n                            # == raw size capacity
    j = jnp.arange(cap_bits, dtype=jnp.int32)
    s = jnp.searchsorted(ends, j, side="right").astype(jnp.int32)
    s = jnp.minimum(s, n - 1)
    b = j - starts[s]                           # bit index within the code
    field = (codes[s] << (MAXL - lens[s])).astype(jnp.int32)
    bit = (field >> (MAXL - 1 - b)) & 1
    bit = jnp.where(j < nbits, bit, 0)

    # Exact int32 reduce in two 16-bit halves, spliced into a uint32 word.
    pow16 = 1 << (15 - jnp.arange(16, dtype=jnp.int32))
    groups = bit.reshape(-1, 32)
    hi = jnp.sum(groups[:, :16] * pow16[None, :], axis=1)
    lo = jnp.sum(groups[:, 16:] * pow16[None, :], axis=1)
    words = (hi.astype(jnp.uint32) << 16) | lo.astype(jnp.uint32)
    return words, jnp.asarray(nbits, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# XOR delta
# ---------------------------------------------------------------------------

def xor_delta(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(uint32[N], uint32[N]) -> (delta uint32[N], changed-byte count int32)."""
    d = jnp.bitwise_xor(a.astype(jnp.uint32), b.astype(jnp.uint32))
    changed = jnp.zeros((), jnp.int32)
    for k in range(4):
        changed = changed + jnp.sum(((d >> (8 * k)) & 0xFF) != 0, dtype=jnp.int32)
    return d, changed
