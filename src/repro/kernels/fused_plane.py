"""Fused device plane producer: the compression front half in ONE dispatch.

The host compression pipeline spends its pre-entropy time in three passes
over the tensor bytes — rotate+byte-group (``bytegroup``), optional XOR
delta (``xor_delta``), and the per-chunk compressibility probe histogram
(``histogram``).  Run separately they each round-trip HBM; run host-side
they serialize on the GIL (``np.bincount``).  This module composes the
three Pallas kernels under a single ``jax.jit`` so XLA schedules them as
one device dispatch: uint lanes in, uint8 byte-group planes + per-chunk
256-bin probe histograms out.  The caller then does a single device→host
transfer and hands the planes straight to the entropy work items
(``core.codec``), with ``hist256``/``np.bincount`` never touching the
probe path.

Alignment contract (enforced by ``core.device_plane``):

* input is a flat uint16/uint32 element array padded with zeros and
  reshaped to ``(M, 128)``;
* the per-plane chunk size ``chunk_elems`` divides ``M * 128`` and is a
  multiple of the histogram block (``HIST_ROWS * 128`` bytes);
* ``M`` is a multiple of every constituent kernel's row block, so no
  kernel sees a partial block.

Zero padding is invariant under all three stages (``rotl1(0) == 0``,
``0 ^ 0 == 0``), so pad bytes only ever inflate bin 0 of the final chunk's
histogram — the host corrects that with one subtraction.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import bytegroup, histogram, xor_delta

LANES = 128

# Row-block alignment (in elements) the padded input must satisfy: the
# byte-group rows and the XOR rows both divide it.
ALIGN_ELEMS_U16 = max(bytegroup.BF16_ROWS, xor_delta.XOR_ROWS) * LANES
ALIGN_ELEMS_U32 = max(bytegroup.FP32_ROWS, xor_delta.XOR_ROWS) * LANES
# Per-plane chunk sizes must be whole histogram blocks.
CHUNK_ALIGN_BYTES = histogram.HIST_ROWS * LANES


@functools.partial(
    jax.jit, static_argnames=("itemsize", "chunk_elems", "interpret")
)
def plane_producer(
    x: jax.Array,
    base: Optional[jax.Array] = None,
    *,
    itemsize: int,
    chunk_elems: int,
    interpret: bool = True,
) -> Tuple[Tuple[jax.Array, ...], jax.Array]:
    """(optional XOR with ``base``) → rotate+byte-group → per-chunk hists.

    Args:
      x: uint16/uint32 ``(M, 128)`` element grid (zero-padded).
      base: same-shape base for the §4.2 XOR-delta path, or None.
      itemsize: 2 or 4 — selects the byte-group kernel.
      chunk_elems: per-plane codec chunk size in elements (== bytes, since
        every element contributes one byte per plane).

    Returns:
      (planes, chunk_hists): ``itemsize`` uint8 ``(M, 128)`` planes, plane 0
      the exponent byte, and int32 ``(n_chunks, itemsize, 256)`` histograms
      where ``n_chunks = M * 128 // chunk_elems``.
    """
    if base is not None:
        x = xor_delta.xor_elems_2d(x, base, interpret=interpret)
    if itemsize == 2:
        planes = bytegroup.bytegroup_bf16_2d(x, interpret=interpret)
    elif itemsize == 4:
        planes = bytegroup.bytegroup_fp32_2d(x, interpret=interpret)
    else:
        raise ValueError(f"fused plane producer: unsupported itemsize {itemsize}")
    chunk_rows = chunk_elems // LANES
    hists = [
        histogram.chunk_histogram_2d(p, chunk_rows=chunk_rows, interpret=interpret)
        for p in planes
    ]
    return tuple(planes), jnp.stack(hists, axis=1)
