"""Pallas TPU kernels: byte-group / exponent-extraction transform.

The compression hot path starts with a pure data-movement transform
(paper Fig. 3/5): rotate each parameter's uint image left by one bit and
split it into byte planes.  On TPU this is an elementwise VPU op — the
design decisions are the uint lane width (16/32-bit ops on native lanes,
8-bit only at the final downcast) and the VMEM block shape (rows × 128
lanes, rows sized so in+out blocks stay ≲ 256 KiB for double buffering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 2-D layout: (rows, 128) — the TPU-native lane count.
LANES = 128
BF16_ROWS = 512            # u16 in: 128 KiB; u8 outs: 2×64 KiB
FP32_ROWS = 256            # u32 in: 128 KiB; u8 outs: 4×32 KiB


def _bf16_fwd_kernel(x_ref, exp_ref, frac_ref):
    # Work in int32 lanes (TPU-native); keep values in the low 16 bits.
    x = x_ref[...].astype(jnp.int32) & 0xFFFF
    rot = ((x << 1) | (x >> 15)) & 0xFFFF
    exp_ref[...] = (rot >> 8).astype(jnp.uint8)
    frac_ref[...] = (rot & 0xFF).astype(jnp.uint8)


def _bf16_inv_kernel(exp_ref, frac_ref, x_ref):
    rot = (exp_ref[...].astype(jnp.int32) << 8) | frac_ref[...].astype(jnp.int32)
    x = ((rot >> 1) | ((rot & 1) << 15)) & 0xFFFF
    x_ref[...] = x.astype(jnp.uint16)


def _fp32_fwd_kernel(x_ref, p0_ref, p1_ref, p2_ref, p3_ref):
    x = x_ref[...].astype(jnp.uint32)
    rot = (x << 1) | (x >> 31)
    p0_ref[...] = (rot >> 24).astype(jnp.uint8)
    p1_ref[...] = ((rot >> 16) & 0xFF).astype(jnp.uint8)
    p2_ref[...] = ((rot >> 8) & 0xFF).astype(jnp.uint8)
    p3_ref[...] = (rot & 0xFF).astype(jnp.uint8)


def _fp32_inv_kernel(p0_ref, p1_ref, p2_ref, p3_ref, x_ref):
    rot = (
        (p0_ref[...].astype(jnp.uint32) << 24)
        | (p1_ref[...].astype(jnp.uint32) << 16)
        | (p2_ref[...].astype(jnp.uint32) << 8)
        | p3_ref[...].astype(jnp.uint32)
    )
    x_ref[...] = (rot >> 1) | (rot << 31)


def _spec(rows):
    return pl.BlockSpec((rows, LANES), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def bytegroup_bf16_2d(x: jax.Array, *, interpret: bool = True):
    """uint16[M, 128] (M % BF16_ROWS == 0) → (exp, frac) uint8[M, 128]."""
    m = x.shape[0]
    return pl.pallas_call(
        _bf16_fwd_kernel,
        grid=(m // BF16_ROWS,),
        in_specs=[_spec(BF16_ROWS)],
        out_specs=[_spec(BF16_ROWS)] * 2,
        out_shape=[jax.ShapeDtypeStruct((m, LANES), jnp.uint8)] * 2,
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ungroup_bf16_2d(exp: jax.Array, frac: jax.Array, *, interpret: bool = True):
    m = exp.shape[0]
    return pl.pallas_call(
        _bf16_inv_kernel,
        grid=(m // BF16_ROWS,),
        in_specs=[_spec(BF16_ROWS)] * 2,
        out_specs=_spec(BF16_ROWS),
        out_shape=jax.ShapeDtypeStruct((m, LANES), jnp.uint16),
        interpret=interpret,
    )(exp, frac)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bytegroup_fp32_2d(x: jax.Array, *, interpret: bool = True):
    """uint32[M, 128] (M % FP32_ROWS == 0) → 4 × uint8[M, 128] planes."""
    m = x.shape[0]
    return pl.pallas_call(
        _fp32_fwd_kernel,
        grid=(m // FP32_ROWS,),
        in_specs=[_spec(FP32_ROWS)],
        out_specs=[_spec(FP32_ROWS)] * 4,
        out_shape=[jax.ShapeDtypeStruct((m, LANES), jnp.uint8)] * 4,
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ungroup_fp32_2d(p0, p1, p2, p3, *, interpret: bool = True):
    m = p0.shape[0]
    return pl.pallas_call(
        _fp32_inv_kernel,
        grid=(m // FP32_ROWS,),
        in_specs=[_spec(FP32_ROWS)] * 4,
        out_specs=_spec(FP32_ROWS),
        out_shape=jax.ShapeDtypeStruct((m, LANES), jnp.uint32),
        interpret=interpret,
    )(p0, p1, p2, p3)
