"""Fused device plane consumer: the decompression back half in ONE dispatch.

Mirror of :mod:`.fused_plane`.  After the entropy stage rebuilds the uint8
byte-group planes, the host decompression path still runs two more numpy
passes — the per-plane byte scatter + inverse rotate (``from_planes``) and,
for §4.2 delta streams, the XOR with the base tensor.  Both serialize on
the GIL and round-trip the planed bytes through host memory.

This module instead runs un-byte-group, inverse rotate-left-1 and the
optional inverse XOR-delta as **one Pallas kernel per dispatch**: uint8
planes in, reconstructed uint16/uint32 elements out, with the base tensor
(when delta-decoding) read directly at its device residence.  The caller
uploads the entropy-decoded planes once, launches once, and does a single
device→host transfer of the reconstructed elements (or leaves them on
device for a shard restore).

Alignment contract (enforced by ``core.device_unplane``): every plane is a
flat uint8 array zero-padded and reshaped to ``(M, 128)`` with ``M`` a
multiple of the kernel's row block.  Zero plane bytes reconstruct to zero
elements (``rotr1(0) == 0``) and XOR against a zero-padded base leaves the
pad region irrelevant — pad elements are sliced off host-side.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
# Row blocks sized like the forward kernels (in+out VMEM blocks ≲ 384 KiB
# with the delta base resident): u8 plane blocks are small, the element
# output block dominates.
BF16_ROWS = 512            # 2 × u8 64 KiB + u16 base/out 128 KiB each
FP32_ROWS = 256            # 4 × u8 32 KiB + u32 base/out 128 KiB each

# Row alignment (in elements) the padded planes must satisfy.
ALIGN_ELEMS_U16 = BF16_ROWS * LANES
ALIGN_ELEMS_U32 = FP32_ROWS * LANES


def _bf16_unplane_kernel(exp_ref, frac_ref, x_ref):
    rot = (exp_ref[...].astype(jnp.int32) << 8) | frac_ref[...].astype(jnp.int32)
    x = ((rot >> 1) | ((rot & 1) << 15)) & 0xFFFF
    x_ref[...] = x.astype(jnp.uint16)


def _bf16_unplane_delta_kernel(exp_ref, frac_ref, base_ref, x_ref):
    rot = (exp_ref[...].astype(jnp.int32) << 8) | frac_ref[...].astype(jnp.int32)
    x = ((rot >> 1) | ((rot & 1) << 15)) & 0xFFFF
    b = base_ref[...].astype(jnp.int32) & 0xFFFF
    x_ref[...] = (x ^ b).astype(jnp.uint16)


def _fp32_rot_inv(p0_ref, p1_ref, p2_ref, p3_ref):
    rot = (
        (p0_ref[...].astype(jnp.uint32) << 24)
        | (p1_ref[...].astype(jnp.uint32) << 16)
        | (p2_ref[...].astype(jnp.uint32) << 8)
        | p3_ref[...].astype(jnp.uint32)
    )
    return (rot >> 1) | (rot << 31)


def _fp32_unplane_kernel(p0_ref, p1_ref, p2_ref, p3_ref, x_ref):
    x_ref[...] = _fp32_rot_inv(p0_ref, p1_ref, p2_ref, p3_ref)


def _fp32_unplane_delta_kernel(p0_ref, p1_ref, p2_ref, p3_ref, base_ref, x_ref):
    x_ref[...] = _fp32_rot_inv(p0_ref, p1_ref, p2_ref, p3_ref) ^ base_ref[
        ...
    ].astype(jnp.uint32)


def _spec(rows):
    return pl.BlockSpec((rows, LANES), lambda i: (i, 0))


@functools.partial(jax.jit, static_argnames=("itemsize", "interpret"))
def plane_consumer(
    planes: Sequence[jax.Array],
    base: Optional[jax.Array] = None,
    *,
    itemsize: int,
    interpret: bool = True,
) -> jax.Array:
    """un-byte-group → inverse rotate → (optional XOR with ``base``).

    Args:
      planes: ``itemsize`` uint8 ``(M, 128)`` byte-group planes, plane 0 the
        exponent byte (most significant after the forward rotation).
      base: uint16/uint32 ``(M, 128)`` base elements for the §4.2
        delta-decode path, or None.
      itemsize: 2 or 4 — selects the kernel.

    Returns:
      uint16/uint32 ``(M, 128)`` reconstructed elements.
    """
    planes = tuple(planes)
    m = planes[0].shape[0]
    if itemsize == 2:
        rows, out_dtype = BF16_ROWS, jnp.uint16
        kern = _bf16_unplane_kernel if base is None else _bf16_unplane_delta_kernel
    elif itemsize == 4:
        rows, out_dtype = FP32_ROWS, jnp.uint32
        kern = _fp32_unplane_kernel if base is None else _fp32_unplane_delta_kernel
    else:
        raise ValueError(f"fused plane consumer: unsupported itemsize {itemsize}")
    operands: Tuple[jax.Array, ...] = planes if base is None else planes + (base,)
    return pl.pallas_call(
        kern,
        grid=(m // rows,),
        in_specs=[_spec(rows)] * len(operands),
        out_specs=_spec(rows),
        out_shape=jax.ShapeDtypeStruct((m, LANES), out_dtype),
        interpret=interpret,
    )(*operands)
