"""Public ops over the ZipNN Pallas kernels.

Handles 1-D↔2-D reshaping, padding to block multiples, interpret-mode
selection (CPU validation vs TPU execution), and byte-exact equivalence
with the host codec (``core.huffman`` / ``core.bitlayout``).
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitpack, bytegroup, histogram, xor_delta

LANES = 128


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_2d(x: jnp.ndarray, rows: int) -> Tuple[jnp.ndarray, int]:
    """Pad flat array to a (M, 128) grid with M % rows == 0."""
    n = x.shape[0]
    block = rows * LANES
    m = -(-max(n, 1) // block) * block
    if m != n:
        x = jnp.pad(x, (0, m - n))
    return x.reshape(-1, LANES), n


def bytegroup_bf16(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """uint16[N] → (exponent uint8[N], frac|sign uint8[N])."""
    x2, n = _pad_2d(x, bytegroup.BF16_ROWS)
    exp, frac = bytegroup.bytegroup_bf16_2d(x2, interpret=_interpret())
    return exp.reshape(-1)[:n], frac.reshape(-1)[:n]


def ungroup_bf16(exp: jax.Array, frac: jax.Array) -> jax.Array:
    e2, n = _pad_2d(exp, bytegroup.BF16_ROWS)
    f2, _ = _pad_2d(frac, bytegroup.BF16_ROWS)
    x = bytegroup.ungroup_bf16_2d(e2, f2, interpret=_interpret())
    return x.reshape(-1)[:n]


def bytegroup_fp32(x: jax.Array) -> Tuple[jax.Array, ...]:
    """uint32[N] → 4 × uint8[N] planes (plane 0 = exponent)."""
    x2, n = _pad_2d(x, bytegroup.FP32_ROWS)
    planes = bytegroup.bytegroup_fp32_2d(x2, interpret=_interpret())
    return tuple(p.reshape(-1)[:n] for p in planes)


def ungroup_fp32(*planes: jax.Array) -> jax.Array:
    padded = [_pad_2d(p, bytegroup.FP32_ROWS)[0] for p in planes]
    n = planes[0].shape[0]
    x = bytegroup.ungroup_fp32_2d(*padded, interpret=_interpret())
    return x.reshape(-1)[:n]


def byte_histogram(x: jax.Array) -> jax.Array:
    """uint8[N] → int32[256].  Padding bytes (zeros) are subtracted out."""
    x2, n = _pad_2d(x, histogram.HIST_ROWS)
    hist = histogram.histogram_2d(x2, interpret=_interpret())
    pad = x2.size - n
    return hist.at[0].add(-pad)


def xor_delta_u32(a: jax.Array, b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(uint32[N],)² → (delta uint32[N], changed-byte count int32[])."""
    a2, n = _pad_2d(a, xor_delta.XOR_ROWS)
    b2, _ = _pad_2d(b, xor_delta.XOR_ROWS)
    d, cnt = xor_delta.xor_delta_2d(a2, b2, interpret=_interpret())
    return d.reshape(-1)[:n], cnt[0]


def huffman_encode_chunks(
    syms: np.ndarray,
    lens: np.ndarray,
    codes: np.ndarray,
    chunk_syms: int = 1 << 13,
) -> List[bytes]:
    """Byte-exact TPU-kernel counterpart of ``core.huffman.encode_chunks``.

    Splits ``syms`` into fixed ``chunk_syms`` chunks (last chunk padded; its
    true bit count is recomputed from the table so the padding never leaks
    into the output), runs the bit-pack kernel, and serializes each chunk's
    words big-endian — byte-identical to ``np.packbits`` order.
    """
    n = int(syms.shape[0])
    if n == 0:
        return []
    n_chunks = -(-n // chunk_syms)
    padded = np.zeros(n_chunks * chunk_syms, dtype=np.uint8)
    padded[:n] = syms
    if n % chunk_syms:
        # Pad with the symbol whose canonical code is all-zero bits (code 0
        # always exists): its bits land *after* the true payload and leave
        # the trailing partial byte zero-filled, matching np.packbits.
        lens_arr = np.asarray(lens)
        codes_arr = np.asarray(codes)
        pad_sym = int(np.flatnonzero((lens_arr > 0) & (codes_arr == 0))[0])
        padded[n:] = pad_sym

    words, nbits = bitpack.bitpack_encode_chunks(
        jnp.asarray(padded),
        jnp.asarray(lens, dtype=jnp.int32),
        jnp.asarray(codes, dtype=jnp.int32),
        chunk_syms=chunk_syms,
        interpret=_interpret(),
    )
    words = np.asarray(words)
    nbits = np.asarray(nbits)

    out: List[bytes] = []
    lens_np = np.asarray(lens, dtype=np.int64)
    for c in range(n_chunks):
        lo, hi = c * chunk_syms, min((c + 1) * chunk_syms, n)
        true_bits = int(lens_np[syms[lo:hi]].sum())
        raw = words[c].astype(">u4").tobytes()
        out.append(raw[: -(-true_bits // 8)])
    return out
