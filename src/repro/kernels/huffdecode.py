"""Multi-table canonical Huffman *decode* kernel (device entropy stage).

Inverse of :mod:`repro.kernels.bitpack`: the encode kernel packs MSB-first
canonical codes into uint32 words (bit ``j`` of the chunk at word bit
``31 - j``); this kernel walks that bitstream back to symbols.  The
schedule is the paper's §5.1 chunk-level parallelism exactly as
``huffman.decode_many`` expresses it on the host — chunks are mutually
independent, so the grid runs one program per HUFF chunk in lockstep,
while *within* a chunk the decode is inherently serial (symbol ``i+1``'s
bit position depends on symbol ``i``'s code length) and runs as a
``fori_loop`` over the chunk's symbol count:

* one fused ``(symbol << 8) | length`` LUT gather per symbol (the same
  16-bit trick as the host decoder's ``lut16``), against a per-chunk row
  of the stacked per-plane tables — multi-table selection mirroring
  ``bitpack_encode_chunks_multi``, so all planes of a tensor decode in
  one launch;
* a per-chunk bit cursor advanced by the gathered code length; the final
  cursor is emitted so the host can apply the same integrity check as
  ``decode_many`` (a valid chunk's cursor lands inside its final byte,
  0-7 zero pad bits of slack);
* word gathers are index-clamped to the chunk's word block, so corrupt or
  truncated payloads decode garbage that the host-side cursor check then
  rejects — never an out-of-bounds gather.

Symbols land device-resident: the driver
(:func:`repro.core.device_entropy.decode_planes`) can feed them straight
into the fused un-byte-group dispatch without a host bounce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["MAXL", "huffdecode_chunks_multi"]

MAXL = 15                      # same cap as the encoder / length-limited tables


def _decode_block(words, lut_row, count, syms_ref, cursor_ref):
    """Serial bit-cursor decode of one chunk's packed words.

    ``words``: ``(chunk_bytes // 4,)`` uint32 block (encode-kernel bit
    convention: bit ``j`` of the chunk at word bit ``31 - j``).
    ``lut_row``: ``(1 << lut_bits,)`` fused ``(sym << 8) | len`` LUT.
    Writes ``count`` symbols and the final bit cursor.
    """
    nwords = words.shape[0]
    lut_bits = lut_row.shape[0].bit_length() - 1    # LUT size is 1 << lut_bits
    out_shift = jnp.uint32(32 - lut_bits)

    def body(i, bitpos):
        # Bits [bitpos, bitpos + lut_bits) straddle at most two words.  The
        # indices are clamped so a runaway cursor (corrupt payload) reads
        # in-range garbage; the host rejects it via the cursor check.
        w0 = jnp.minimum(bitpos >> 5, nwords - 1)
        w1 = jnp.minimum(w0 + 1, nwords - 1)
        o = (bitpos & 31).astype(jnp.uint32)
        a = jax.lax.dynamic_index_in_dim(words, w0, 0, keepdims=False)
        b = jax.lax.dynamic_index_in_dim(words, w1, 0, keepdims=False)
        # (a << o) keeps the window's first bit at the MSB; the second word
        # contributes its top o bits.  The double shift (>> 1 >> (31 - o))
        # stays defined at o == 0, where a single >> 32 would not be.
        win = ((a << o) | ((b >> jnp.uint32(1)) >> (jnp.uint32(31) - o)))
        v = jax.lax.dynamic_index_in_dim(
            lut_row, (win >> out_shift).astype(jnp.int32), 0, keepdims=False
        )
        syms_ref[pl.ds(i, 1)] = ((v >> 8).astype(jnp.uint8)).reshape(1)
        return bitpos + (v & 0xFF)

    final = jax.lax.fori_loop(0, count, body, jnp.int32(0))
    # Clamp for reporting only: a live cursor never exceeds the block (the
    # expansion guard keeps valid payloads under chunk_bytes), so the clamp
    # only tames corrupt streams — which the host then rejects.
    cursor_ref[0] = jnp.minimum(final, nwords * 32)


def _huffdecode_multi_kernel(pid_ref, count_ref, lut_ref, words_ref,
                             syms_ref, cursor_ref):
    pid = pid_ref[0]
    lut_row = jax.lax.dynamic_index_in_dim(
        lut_ref[...], pid, axis=0, keepdims=False
    )
    _decode_block(words_ref[...], lut_row, count_ref[0], syms_ref, cursor_ref)


@functools.partial(jax.jit, static_argnames=("chunk_bytes", "interpret"))
def huffdecode_chunks_multi(
    words: jax.Array,
    plane_ids: jax.Array,
    counts: jax.Array,
    lut16_tables: jax.Array,
    *,
    chunk_bytes: int,
    interpret: bool = True,
):
    """Decode many packed HUFF chunks against stacked per-plane LUTs.

    ``words``        — ``(c * (chunk_bytes // 4),)`` uint32: each chunk's
                       payload bytes as big-endian words, zero-padded to the
                       ``chunk_bytes`` capacity (valid HUFF payloads are
                       always shorter — the expansion guard stores larger
                       chunks raw).
    ``plane_ids``    — ``(c,)`` row of ``lut16_tables`` per chunk.
    ``counts``       — ``(c,)`` symbols to decode per chunk (its raw length).
    ``lut16_tables`` — ``(p, 1 << lut_bits)`` fused ``(sym << 8) | len``
                       canonical LUTs, one row per plane, built at a shared
                       ``lut_bits`` ≥ every table's max code length.

    Returns ``(syms, cursors)``: ``(c, chunk_bytes)`` uint8 decoded symbols
    (entries past ``counts[k]`` are unspecified) and ``(c,)`` int32 final
    bit cursors for the host-side integrity check.
    """
    cw = chunk_bytes // 4
    c = words.shape[0] // cw
    p = lut16_tables.shape[0]
    lut_n = lut16_tables.shape[1]
    syms, cursors = pl.pallas_call(
        _huffdecode_multi_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((p, lut_n), lambda i: (0, 0)),
            pl.BlockSpec((cw,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk_bytes,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c * chunk_bytes,), jnp.uint8),
            jax.ShapeDtypeStruct((c,), jnp.int32),
        ],
        interpret=interpret,
    )(
        plane_ids.astype(jnp.int32),
        counts.astype(jnp.int32),
        lut16_tables.astype(jnp.int32),
        words,
    )
    return syms.reshape(c, chunk_bytes), cursors
