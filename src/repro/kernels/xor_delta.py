"""Pallas TPU kernel: XOR delta of two checkpoints + changed-byte count.

Paper §4.2: checkpoint deltas are XORs (exactly reversible, no carry bits).
The kernel fuses the delta with the changed-byte statistic that drives both
the Fig. 8(a) analysis and the Huffman-vs-LZ auto-selection's zero counting,
saving one full pass over HBM relative to delta-then-count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
XOR_ROWS = 256             # 2 × u32 in + u32 out = 384 KiB per step


def _xor_kernel(a_ref, b_ref, d_ref, cnt_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    d = jnp.bitwise_xor(a_ref[...].astype(jnp.uint32), b_ref[...].astype(jnp.uint32))
    d_ref[...] = d
    changed = jnp.zeros((), jnp.int32)
    for k in range(4):
        changed = changed + jnp.sum(((d >> (8 * k)) & 0xFF) != 0, dtype=jnp.int32)
    cnt_ref[0] += changed


def _xor_elems_kernel(a_ref, b_ref, d_ref):
    d_ref[...] = jnp.bitwise_xor(a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def xor_elems_2d(a: jax.Array, b: jax.Array, *, interpret: bool = True):
    """Elementwise XOR at the operand dtype width (uint16/uint32).

    The counting variant below serves the Fig. 8(a) statistic; this plain
    variant feeds the fused plane producer (``kernels.fused_plane``), where
    the per-chunk zero counts come from the chunk histograms instead — no
    second reduction needed.  ``a.shape[0] % XOR_ROWS == 0`` required.
    """
    m = a.shape[0]
    return pl.pallas_call(
        _xor_elems_kernel,
        grid=(m // XOR_ROWS,),
        in_specs=[pl.BlockSpec((XOR_ROWS, LANES), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((XOR_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("interpret",))
def xor_delta_2d(a: jax.Array, b: jax.Array, *, interpret: bool = True):
    """(uint32[M,128], uint32[M,128]) → (delta uint32[M,128], int32[1])."""
    m = a.shape[0]
    return pl.pallas_call(
        _xor_kernel,
        grid=(m // XOR_ROWS,),
        in_specs=[pl.BlockSpec((XOR_ROWS, LANES), lambda i: (i, 0))] * 2,
        out_specs=[
            pl.BlockSpec((XOR_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
