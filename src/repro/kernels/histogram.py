"""Pallas TPU kernel: 256-bin byte histogram.

Histograms drive ZipNN's table building and compressibility probes.  CUDA
would use atomic scatter-adds; TPU has no atomics, so the TPU-native
formulation is *compare-and-reduce*: each grid step compares its block
against bin indices and accumulates per-bin counts into a revisited output
block.  Bins are processed in groups of 32 to bound the comparison
matrix's VMEM footprint (32 × block ≈ 2 MiB int32 at the default block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
HIST_ROWS = 128            # u8 block: 16 KiB; compare matrix: 32×16384 i32 = 2 MiB
BIN_GROUPS = 8             # 8 × 32 bins


def _hist_kernel(x_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32).reshape(1, -1)

    def body(g, carry):
        bins = g * 32 + jax.lax.iota(jnp.int32, 32).reshape(32, 1)
        part = jnp.sum((x == bins).astype(jnp.int32), axis=1)
        out_ref[pl.ds(g * 32, 32)] += part
        return carry

    jax.lax.fori_loop(0, BIN_GROUPS, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def histogram_2d(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """uint8[M, 128] (M % HIST_ROWS == 0) → int32[256] counts."""
    m = x.shape[0]
    return pl.pallas_call(
        _hist_kernel,
        grid=(m // HIST_ROWS,),
        in_specs=[pl.BlockSpec((HIST_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.int32),
        interpret=interpret,
    )(x)
