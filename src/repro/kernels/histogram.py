"""Pallas TPU kernel: 256-bin byte histogram.

Histograms drive ZipNN's table building and compressibility probes.  CUDA
would use atomic scatter-adds; TPU has no atomics, so the TPU-native
formulation is *compare-and-reduce*: each grid step compares its block
against bin indices and accumulates per-bin counts into a revisited output
block.  Bins are processed in groups of 32 to bound the comparison
matrix's VMEM footprint (32 × block ≈ 2 MiB int32 at the default block).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
HIST_ROWS = 128            # u8 block: 16 KiB; compare matrix: 32×16384 i32 = 2 MiB
BIN_GROUPS = 8             # 8 × 32 bins


def _hist_kernel(x_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32).reshape(1, -1)

    def body(g, carry):
        bins = g * 32 + jax.lax.iota(jnp.int32, 32).reshape(32, 1)
        part = jnp.sum((x == bins).astype(jnp.int32), axis=1)
        out_ref[pl.ds(g * 32, 32)] += part
        return carry

    jax.lax.fori_loop(0, BIN_GROUPS, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def histogram_2d(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """uint8[M, 128] (M % HIST_ROWS == 0) → int32[256] counts."""
    m = x.shape[0]
    return pl.pallas_call(
        _hist_kernel,
        grid=(m // HIST_ROWS,),
        in_specs=[pl.BlockSpec((HIST_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.int32),
        interpret=interpret,
    )(x)


def _chunk_hist_kernel(x_ref, out_ref):
    # Grid (chunk, block-within-chunk): the output block for chunk ``i`` is
    # revisited across the inner grid dimension, initialized on its first
    # visit — same revisit-and-accumulate pattern as ``_hist_kernel``.
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32).reshape(1, -1)

    def body(g, carry):
        bins = g * 32 + jax.lax.iota(jnp.int32, 32).reshape(32, 1)
        part = jnp.sum((x == bins).astype(jnp.int32), axis=1)
        out_ref[0, pl.ds(g * 32, 32)] += part
        return carry

    jax.lax.fori_loop(0, BIN_GROUPS, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk_rows", "interpret"))
def chunk_histogram_2d(
    x: jax.Array, *, chunk_rows: int, interpret: bool = True
) -> jax.Array:
    """uint8[M, 128] → int32[M // chunk_rows, 256] per-chunk counts.

    Requires ``M % chunk_rows == 0`` and ``chunk_rows % HIST_ROWS == 0`` —
    codec chunks (128 KiB per plane by default) are whole multiples of the
    16 KiB histogram block, so one grid row of blocks reduces into one
    chunk's 256-bin row.  This is the device-side replacement for the
    codec's per-chunk ``np.bincount`` probe (the GIL-bound ~15 % of host
    compress time): every chunk's probe histogram comes back in a single
    fused dispatch alongside the byte-group planes.
    """
    m = x.shape[0]
    n_chunks = m // chunk_rows
    blocks = chunk_rows // HIST_ROWS
    return pl.pallas_call(
        _chunk_hist_kernel,
        grid=(n_chunks, blocks),
        in_specs=[
            pl.BlockSpec((HIST_ROWS, LANES), lambda i, j: (i * blocks + j, 0))
        ],
        out_specs=pl.BlockSpec((1, 256), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, 256), jnp.int32),
        interpret=interpret,
    )(x)
