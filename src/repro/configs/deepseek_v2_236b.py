"""DeepSeek-V2-236B — MLA (kv_lora=512) + 2 shared / 160 routed top-6 MoE.
236 B total, ~21 B active. [arXiv:2405.04434; hf]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="deepseek_v2_236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # nominal; MLA replaces the KV path
    d_ff=1536,               # per routed expert
    vocab_size=102400,
    head_dim=128,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    moe=True,
    n_experts=160,
    n_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1536,
    first_k_dense=1,         # layer 0 is a dense FFN
    dense_d_ff=12288,
    zero3=True,              # mandatory at 236 B
    source="arXiv:2405.04434",
))
