"""repro-gpt-100m — in-repo ~100 M-param LM for the end-to-end training
driver and checkpoint/delta experiments (the paper's own evaluation uses
off-the-shelf checkpoints; this is our trainable stand-in)."""

from .base import ModelConfig, register

register(ModelConfig(
    name="repro_gpt_100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=32000,
    head_dim=64,
    remat="none",
    source="in-repo",
))
