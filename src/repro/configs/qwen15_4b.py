"""Qwen1.5-4B — llama-like with QKV bias, MHA (kv == heads).
[hf:Qwen/Qwen1.5-4B; hf]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="qwen15_4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,           # full MHA
    d_ff=6912,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=5e6,
    zero3=True,
    source="hf:Qwen/Qwen1.5-4B",
))
