"""Qwen2-VL-2B backbone — M-RoPE, dynamic-resolution vision (frontend is a
stub providing precomputed patch embeddings). [arXiv:2409.12191; hf]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="qwen2_vl_2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,            # GQA kv=2
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),   # freq pairs for (t, h, w); sums to hd/2
    rope_theta=1e6,
    frontend="vision",
    frontend_dim=1176,       # 14×14 patch × 2×2 merge × 1.5 ch (stub dim)
    zero3=True,
    source="arXiv:2409.12191",
))
