"""Mamba2-130M — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="mamba2_130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,               # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    use_rope=False,
    pos_embedding="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    sp=False,                # 130M: residuals are small; skip the SP gathers
    source="arXiv:2405.21060",
))
