"""Architecture configs: one module per assigned architecture + registry."""

from .base import ModelConfig, get_config, list_archs, SHAPES, shape_cells

__all__ = ["ModelConfig", "get_config", "list_archs", "SHAPES", "shape_cells"]
