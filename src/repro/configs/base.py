"""ModelConfig dataclass, architecture registry, and shape-cell definitions.

Every assigned architecture registers the *exact* published config in its own
module; ``reduced()`` derives the family-preserving small config for CPU
smoke tests.  The FULL configs are only ever lowered via ShapeDtypeStructs
(launch/dryrun.py) — never allocated on this host.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 ⇒ d_model // n_heads

    # attention
    window: int = 0                # sliding-window size (0 = full attention)
    qkv_bias: bool = False
    rope_theta: float = 1e4
    use_rope: bool = True
    pos_embedding: str = "rope"    # rope | learned | none
    max_position: int = 32768      # learned-pos table length
    encoder_only: bool = False
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    q_block: int = 512
    kv_block: int = 1024

    # MLA (DeepSeek-V2)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    dense_d_ff: int = 0            # width of the leading dense layers
    aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25  # MoE per-expert capacity headroom
    dispatch_shards: int = 1       # shard-local MoE dispatch rows (= number
                                   # of batch shards; set by the launcher)

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssd_chunk: int = 128
    shared_attn_every: int = 0     # hybrid: shared attn block interval

    # modality frontend (STUB: precomputed embeddings, see DESIGN.md)
    frontend: str = "none"         # none | vision | audio
    frontend_dim: int = 0

    # numerics / structure
    ce_chunks: int = 8             # fused-CE sequence chunking (memory knob)
    param_dtype: str = "bfloat16"
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # distribution
    zero3: bool = False            # FSDP-style secondary param sharding
    sp: bool = True                # Megatron-style sequence-parallel residuals:
                                   # the per-layer saved carry shards its seq
                                   # dim over 'model' (all-gather at use)
    remat: str = "full"            # none | full | dots
    scan_layers: bool = True       # lax.scan over the stack (False: unroll)
    attn_impl: str = "flash"       # flash | dense (dense: accounting variant)

    # provenance
    source: str = ""

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype) if self.param_dtype != "bfloat16" else jnp.bfloat16

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """May run the long_500k cell (sub-quadratic context handling)."""
        return self.family in ("ssm", "hybrid") or self.window > 0

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6·N·D."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        r = dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.family == "hybrid" else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32,
            d_ff=256 if not self.moe else self.d_ff,
            dense_d_ff=256,
            vocab_size=512,
            max_position=512,
            window=min(self.window, 64) if self.window else 0,
            q_block=64,
            kv_block=64,
            n_experts=8 if self.moe else 0,
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=64 if self.moe else 0,
            q_lora_rank=32 if self.mla else 0,
            kv_lora_rank=16 if self.mla else 0,
            qk_nope_dim=32 if self.mla else 128,
            qk_rope_dim=16 if self.mla else 64,
            v_head_dim=32 if self.mla else 128,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32,
            ssd_chunk=32,
            shared_attn_every=2 if self.shared_attn_every else 0,
            frontend_dim=64 if self.frontend != "none" else 0,
            zero3=False,
            remat="none",
        )
        return r


# ---------------------------------------------------------------------------
# Shape cells (assigned): seq_len × global_batch per kind
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


ARCHS = [
    "h2o_danube3_4b",
    "granite_20b",
    "yi_6b",
    "qwen15_4b",
    "qwen2_vl_2b",
    "olmoe_1b_7b",
    "deepseek_v2_236b",
    "mamba2_130m",
    "hubert_xlarge",
    "zamba2_7b",
]

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_")
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{name}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    return list(ARCHS)


def shape_cells(cfg: ModelConfig) -> List[ShapeCell]:
    """Applicable cells for an arch (skips recorded in DESIGN.md §4)."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.has_decode:
        cells.append(SHAPES["decode_32k"])
        if cfg.subquadratic:
            cells.append(SHAPES["long_500k"])
    return cells
