"""Zamba2-7B — Mamba2 backbone + shared attention block every 6 layers.
The shared block uses a 4096-token sliding window so the 500 k decode cell
keeps a bounded cache (deviation recorded in DESIGN.md §4).
[arXiv:2411.15242; unverified]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="zamba2_7b",
    family="hybrid",
    n_layers=81,             # mamba2 layers; shared attn applied every 6
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,              # shared block FFN
    vocab_size=32000,
    head_dim=112,
    window=4096,             # shared attn sliding window (bounded 500k cache)
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    zero3=True,
    source="arXiv:2411.15242",
))
