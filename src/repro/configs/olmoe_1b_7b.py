"""OLMoE-1B-7B — 64-expert top-8 MoE, 1 B active / 7 B total.
[arXiv:2409.02060; hf]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="olmoe_1b_7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,               # per-expert FFN width
    vocab_size=50304,
    head_dim=128,
    moe=True,
    n_experts=64,
    experts_per_token=8,
    moe_d_ff=1024,
    zero3=True,
    source="arXiv:2409.02060",
))
