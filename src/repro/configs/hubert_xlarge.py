"""HuBERT-XLarge — encoder-only audio transformer (w2v2 arch), masked
cluster prediction over 504 codes; conv frontend stubbed to precomputed
frame embeddings. [arXiv:2106.07447; unverified]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="hubert_xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,          # k-means cluster codes
    head_dim=80,
    encoder_only=True,       # no decode shapes (DESIGN.md §4)
    use_rope=False,
    pos_embedding="learned",
    max_position=32768,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    param_dtype="float32",   # published weights are FP32 → ZipNN FP32 path
    frontend="audio",
    frontend_dim=512,        # conv feature extractor output (stub)
    zero3=True,
    source="arXiv:2106.07447",
))
