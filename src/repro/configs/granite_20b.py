"""Granite-20B-Code — gpt_bigcode arch: MQA (kv=1), layernorm+gelu, learned
positions. [arXiv:2405.04324; hf]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="granite_20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,            # MQA
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    use_rope=False,
    pos_embedding="learned",
    max_position=32768,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    zero3=True,              # 20B params: shard optimizer+params over data
    source="arXiv:2405.04324",
))
