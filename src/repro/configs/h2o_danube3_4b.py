"""H2O-Danube-3-4B — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="h2o_danube3_4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,            # GQA kv=8
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    window=8192,             # mistral-style SWA ⇒ sub-quadratic, runs long_500k
    rope_theta=1e4,
    zero3=True,
    source="arXiv:2401.16818",
))
