"""Yi-6B — llama-arch GQA. [arXiv:2403.04652; hf]"""

from .base import ModelConfig, register

register(ModelConfig(
    name="yi_6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,            # GQA kv=4
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5e6,
    zero3=True,
    source="arXiv:2403.04652",
))
