"""Model-hub transfer simulation (paper §5.3, Fig. 10).

Models the paper's measured channel classes (first download / cached
download / upload, cloud vs home) and reports end-to-end time with and
without ZipNN: transfer(compressed) + decompress vs transfer(raw).
Compression/decompression times are *measured* on this host; only the wire
time is modeled — same methodology as the paper, which also separates the
two terms."""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

import numpy as np

from repro.core import zipnn

# Channel bandwidths (MB/s) — paper §5.3 measurements.
CHANNELS: Dict[str, float] = {
    "upload_cloud": 20.0,
    "first_download_cloud": 30.0,
    "cached_download_cloud": 125.0,
    "first_download_home": 10.0,
    "cached_download_home": 40.0,
}


@dataclasses.dataclass
class TransferReport:
    channel: str
    raw_bytes: int
    comp_bytes: int
    wire_raw_s: float
    wire_comp_s: float
    codec_s: float

    @property
    def total_raw_s(self) -> float:
        return self.wire_raw_s

    @property
    def total_comp_s(self) -> float:
        return self.wire_comp_s + self.codec_s

    @property
    def speedup(self) -> float:
        return self.total_raw_s / max(self.total_comp_s, 1e-9)


def simulate_transfer(
    data: bytes,
    dtype_name: str,
    channel: str,
    *,
    direction: str = "download",
    config: zipnn.ZipNNConfig = zipnn.DEFAULT,
) -> TransferReport:
    bw = CHANNELS[channel] * 1e6
    t0 = time.perf_counter()
    blob = zipnn.compress_bytes(data, dtype_name, config)
    t_comp = time.perf_counter() - t0
    t0 = time.perf_counter()
    back = zipnn.decompress_bytes(blob, config)
    t_dec = time.perf_counter() - t0
    assert back == bytes(data), "hub transfer must be lossless"
    codec = t_comp if direction == "upload" else t_dec
    return TransferReport(
        channel=channel,
        raw_bytes=len(data),
        comp_bytes=len(blob),
        wire_raw_s=len(data) / bw,
        wire_comp_s=len(blob) / bw,
        codec_s=codec,
    )
