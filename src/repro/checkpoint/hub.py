"""Model-hub transfer simulation (paper §5.3, Fig. 10).

Models the paper's measured channel classes (first download / cached
download / upload, cloud vs home) and reports end-to-end time with and
without ZipNN: transfer(compressed) + decompress vs transfer(raw).
Compression/decompression times are *measured* on this host; only the wire
time is modeled — same methodology as the paper, which also separates the
two terms."""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import zipnn
from repro.core.options import resolve_options

# Channel bandwidths (MB/s) — paper §5.3 measurements.
CHANNELS: Dict[str, float] = {
    "upload_cloud": 20.0,
    "first_download_cloud": 30.0,
    "cached_download_cloud": 125.0,
    "first_download_home": 10.0,
    "cached_download_home": 40.0,
}


@dataclasses.dataclass
class TransferReport:
    channel: str
    raw_bytes: int
    comp_bytes: int
    wire_raw_s: float
    wire_comp_s: float
    codec_s: float
    # Prefetch-overlapped download (streamed transfers only): frame i
    # decompresses in the engine pool while frame i+1 crosses the modeled
    # wire, so only codec time that outruns the wire is exposed.  0.0 when
    # the transfer was not frame-overlapped (single blob, or upload).
    codec_overlap_s: float = 0.0        # codec time NOT hidden by the wire
    total_comp_overlap_s: float = 0.0   # pipelined end-to-end time

    @property
    def total_raw_s(self) -> float:
        return self.wire_raw_s

    @property
    def total_comp_s(self) -> float:
        return self.wire_comp_s + self.codec_s

    @property
    def speedup(self) -> float:
        return self.total_raw_s / max(self.total_comp_s, 1e-9)

    @property
    def overlapped_speedup(self) -> float:
        """Speedup with wire/codec overlap; equals :attr:`speedup` when the
        transfer was not overlapped."""
        base = self.total_comp_overlap_s or self.total_comp_s
        return self.total_raw_s / max(base, 1e-9)


def simulate_transfer(
    data: bytes,
    dtype_name: str,
    channel: str,
    *,
    direction: str = "download",
    config: zipnn.ZipNNConfig = zipnn.DEFAULT,
    options: Optional[zipnn.CodecOptions] = None,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
) -> TransferReport:
    """Measure one hub transfer.  Codec knobs arrive as one
    ``CodecOptions`` bag (``options=``; the loose kwargs still work with a
    DeprecationWarning and win over the bag when set).  ``threads`` fans
    the codec's (plane, chunk) work items across the engine pool — the
    hub-scale serving knob (codec time scales down with cores, wire time
    is fixed); ``backend`` selects both the plane-producer path on upload
    and the plane-consumer path on download (host numpy vs fused device
    dispatch, bytes identical); ``entropy_backend`` overrides just the
    Huffman entropy stage on both directions — the bit-pack kernel on
    upload, the decoder kernel on download (see core/device_entropy.py —
    mixed mode)."""
    opts = resolve_options(
        options, threads=threads, backend=backend,
        entropy_backend=entropy_backend, _stacklevel=3,
    )
    bw = CHANNELS[channel] * 1e6
    t0 = time.perf_counter()
    blob = zipnn.compress_bytes(data, dtype_name, config, options=opts)
    t_comp = time.perf_counter() - t0
    t0 = time.perf_counter()
    back = zipnn.decompress_bytes(blob, config, options=opts)
    t_dec = time.perf_counter() - t0
    if back != bytes(data):
        # A real exception, not `assert`: the losslessness guard must
        # survive `python -O` — it is an integrity check, not a debug aid.
        raise IOError("hub transfer must be lossless: round-trip mismatch")
    codec = t_comp if direction == "upload" else t_dec
    return TransferReport(
        channel=channel,
        raw_bytes=len(data),
        comp_bytes=len(blob),
        wire_raw_s=len(data) / bw,
        wire_comp_s=len(blob) / bw,
        codec_s=codec,
    )


def _overlapped_download(
    comp_path: str,
    config: zipnn.ZipNNConfig,
    opts: "zipnn.CodecOptions",
    bw: float,
) -> Tuple[float, float]:
    """Pipelined download time over a ``ZNS1`` container.

    ZNS1 frames are independent, so a downloader can decompress frame i (on
    the engine pool) while frame i+1 is still on the wire.  Each frame's
    decode is *measured* here (submitted to the pool — the same execution
    path a real prefetching client uses) and each frame's wire time is
    modeled from its size; the pipeline then exposes only codec time that
    outruns the wire:

        total = wire(header) + wire(f0) + Σ max(wire(f_{i+1}), dec(f_i))
                + dec(f_last)

    Frames are parsed and decoded one at a time — O(frame) memory, like the
    transfer it models.  Each decode fans its (plane, chunk) work items
    across the engine pool via ``threads``, exactly like a real prefetching
    client.  Returns ``(total_overlap_s, exposed_codec_s)``.
    """
    from repro.core import engine

    fixed = (engine._SHDR.size + engine._FRAME.size) / bw   # header + end frame
    total = wire_total = fixed
    prev_dec = None
    for _raw_len, comp_len, blob in engine.frame_records(comp_path):
        wire = (engine._FRAME.size + comp_len) / bw
        wire_total += wire
        total += wire if prev_dec is None else max(wire, prev_dec)
        t0 = time.perf_counter()
        zipnn.decompress_bytes(blob, config, options=opts)
        prev_dec = time.perf_counter() - t0
    if prev_dec is not None:
        total += prev_dec
    return total, max(total - wire_total, 0.0)


def simulate_file_transfer(
    path: str,
    dtype_name: str,
    channel: str,
    *,
    direction: str = "download",
    config: zipnn.ZipNNConfig = zipnn.DEFAULT,
    window_bytes: Optional[int] = None,
    options: Optional[zipnn.CodecOptions] = None,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
) -> TransferReport:
    """Bounded-memory variant of :func:`simulate_transfer` for checkpoints
    larger than RAM: streams the file through the engine's windowed
    ``ZNS1`` container (O(window) peak memory) instead of materializing the
    raw + compressed blobs.

    Downloads additionally report the **prefetch-overlapped** time
    (``total_comp_overlap_s`` / :attr:`TransferReport.overlapped_speedup`):
    frame i decompresses in the engine pool while frame i+1 crosses the
    modeled wire."""
    import os
    import tempfile

    from repro.core import engine

    opts = resolve_options(
        options, threads=threads, backend=backend,
        entropy_backend=entropy_backend, _stacklevel=3,
    )
    window = engine.DEFAULT_WINDOW if window_bytes is None else window_bytes
    bw = CHANNELS[channel] * 1e6
    with tempfile.TemporaryDirectory() as td:
        comp_path = os.path.join(td, "model.znns")
        t0 = time.perf_counter()
        raw_bytes, comp_bytes = engine.compress_file(
            path, comp_path, dtype_name, config,
            window_bytes=window, options=opts,
        )
        t_comp = time.perf_counter() - t0
        t0 = time.perf_counter()
        with open(os.devnull, "wb") as sink:
            n = engine.decompress_file(comp_path, sink, config, options=opts)
        t_dec = time.perf_counter() - t0
        overlap_total = overlap_codec = 0.0
        if direction == "download":
            overlap_total, overlap_codec = _overlapped_download(
                comp_path, config, opts, bw,
            )
    if n != raw_bytes:
        raise IOError("streamed hub transfer must be lossless")
    codec = t_comp if direction == "upload" else t_dec
    return TransferReport(
        channel=channel,
        raw_bytes=raw_bytes,
        comp_bytes=comp_bytes,
        wire_raw_s=raw_bytes / bw,
        wire_comp_s=comp_bytes / bw,
        codec_s=codec,
        codec_overlap_s=overlap_codec,
        total_comp_overlap_s=overlap_total,
    )
