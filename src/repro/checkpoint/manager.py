"""ZipNN-compressed checkpointing with delta chains and periodic bases.

This is the paper's §2.1.3/§4.2 use case as a production subsystem:

* every checkpoint is ZipNN-compressed per tensor (exponent extraction +
  byte grouping + Huffman-only entropy coding);
* between periodic **bases** (every ``base_every`` saves), checkpoints are
  stored as XOR **deltas against the last base** — recovery cost is bounded
  at base+one-delta, never a chain (§4.2 "Periodic Base");
* **optimizer moments** (AdamW ``m``/``v`` trees — the fp32 bulk of a
  mixed-precision checkpoint) are instead stored as deltas **against the
  previous save**: moments are EMAs, so step-over-step deltas are far
  sparser than vs-base deltas.  Restore replays the chain (bounded at
  ``base_every`` links — bases always store moments in full) bit-exactly,
  memoizing each intermediate save so a chain of k loads each checkpoint
  once, not O(k²) times;
* §4.2 auto-detection picks Huffman vs LZ per chunk of each delta;
* saves are **async** (compression+IO off the training critical path),
  **atomic** (tmp dir + os.replace — a crash mid-save can never corrupt the
  latest valid checkpoint), and **CRC-verified** on load: restore() scans
  back to the newest *valid* checkpoint, skipping torn ones;
* restore returns host numpy trees; ``shard_restore`` device_puts them to
  any mesh/PartitionSpecs — the elastic-rescale path (the saved layout does
  not constrain the restored one).

Layout:  <dir>/step_<N>/{manifest.json, data.bin}
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import zipnn
from repro.optim.adamw import MOMENT_KEYS, is_moment_path

PyTree = Any


@dataclasses.dataclass
class CheckpointConfig:
    directory: str
    base_every: int = 5              # every k-th save is a full base (§4.2)
    keep_bases: int = 2              # retention: bases (+ their deltas)
    async_save: bool = True
    # Engine workers for per-tensor (plane, chunk) compression — stacks with
    # async_save: the save thread fans chunk work items across the pool.
    # 0/1 serial, N > 1 pool workers, -1 all cores (see core/engine.py).
    threads: int = 0
    # Plane-producer backend for the compression front half: 'host' |
    # 'device' | 'auto' (see core/device_plane.py).  'device' fuses
    # rotate+byte-group+probe into one Pallas dispatch per save batch AND
    # routes the entropy stage through the fused Huffman bit-pack dispatch
    # (core/device_entropy.py, canonical 'huffman' coder only);
    # checkpoint bytes are identical for every setting.
    backend: str = "host"
    # Entropy-stage override for mixed mode (None follows `backend`):
    # 'host' | 'device' | 'auto' — see core/device_entropy.py.
    entropy_backend: Optional[str] = None
    # The unified knob bag (core/options.py): non-None fields fold into the
    # three legacy fields above (which still win when set explicitly), then
    # everything merges into the carried ZipNNConfig as before.
    options: Optional[zipnn.CodecOptions] = None
    # Flat-key prefixes treated as optimizer moments (delta-vs-previous-save
    # chains).  () disables moment chaining entirely.
    moment_keys: Tuple[str, ...] = MOMENT_KEYS
    zipnn: zipnn.ZipNNConfig = dataclasses.field(default_factory=zipnn.ZipNNConfig)

    def __post_init__(self) -> None:
        if self.options is not None:
            if self.options.threads is not None and not self.threads:
                self.threads = self.options.threads
            if self.options.backend is not None and self.backend == "host":
                self.backend = self.options.backend
            if self.options.entropy_backend is not None and self.entropy_backend is None:
                self.entropy_backend = self.options.entropy_backend
        if self.threads and not self.zipnn.threads:
            self.zipnn = dataclasses.replace(self.zipnn, threads=self.threads)
        if self.backend != "host" and self.zipnn.plane_backend == "host":
            self.zipnn = dataclasses.replace(self.zipnn, plane_backend=self.backend)
        if self.entropy_backend is not None and self.zipnn.entropy_backend is None:
            self.zipnn = dataclasses.replace(
                self.zipnn, entropy_backend=self.entropy_backend
            )


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> PyTree:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class CheckpointManager:
    def __init__(self, config: CheckpointConfig):
        self.cfg = config
        os.makedirs(config.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._save_count = 0
        self._last_base_step: Optional[int] = None
        self._last_base_flat: Optional[Dict[str, np.ndarray]] = None
        # Moment-chain bookkeeping: the previous save's moment arrays (kept
        # in host RAM — fp32 moments of the model, one save's worth) and its
        # step.  Lost on restart, in which case the next save simply stores
        # moments vs-base/full again — chains never span a process restart.
        self._last_save_step: Optional[int] = None
        self._last_moment_flat: Optional[Dict[str, np.ndarray]] = None
        self._errors: List[BaseException] = []
        # resume bookkeeping from disk
        for step, kind, base in self._scan():
            self._save_count += 1
            if kind == "base":
                self._last_base_step = step

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: PyTree, *, blocking: bool = False) -> None:
        """Snapshot is taken synchronously; compression+IO go async."""
        self.wait()
        flat = _flatten(state)
        is_base = (
            self._save_count % self.cfg.base_every == 0
            or self._last_base_flat is None
            and self._last_base_step is None
        )
        self._save_count += 1
        base_flat = None if is_base else self._last_base_flat
        base_step = None if is_base else self._last_base_step
        if base_flat is None and not is_base:
            is_base = True                      # lost base in memory ⇒ full save
        prev_flat = None if is_base else self._last_moment_flat
        prev_step = None if is_base else self._last_save_step

        def work():
            try:
                self._write(
                    step, flat, is_base, base_flat, base_step,
                    prev_flat, prev_step,
                )
                if is_base:
                    self._last_base_step = step
                    self._last_base_flat = flat
                if self.cfg.moment_keys:
                    self._last_moment_flat = {
                        k: v for k, v in flat.items()
                        if is_moment_path(k, self.cfg.moment_keys)
                    }
                    self._last_save_step = step
                self._gc()
            except BaseException as e:          # surfaced on next wait()
                self._errors.append(e)

        if blocking or not self.cfg.async_save:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._errors:
            err = self._errors[:]
            self._errors.clear()
            raise RuntimeError(f"async checkpoint save failed: {err[0]}") from err[0]

    def _write(
        self,
        step: int,
        flat: Dict[str, np.ndarray],
        is_base: bool,
        base_flat: Optional[Dict[str, np.ndarray]],
        base_step: Optional[int],
        prev_flat: Optional[Dict[str, np.ndarray]] = None,
        prev_step: Optional[int] = None,
    ) -> None:
        tmp = os.path.join(self.cfg.directory, f".tmp_step_{step}")
        final = os.path.join(self.cfg.directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        keys = sorted(flat)
        # Optimizer moments delta against the PREVIOUS save (EMA state moves
        # a little every step, so vs-prev deltas are much sparser than
        # vs-base) — bases still store moments in full, which bounds the
        # restore chain at base_every links.
        prev_keys = [
            k for k in keys
            if prev_flat is not None
            and prev_step is not None
            and is_moment_path(k, self.cfg.moment_keys)
            and k in prev_flat
            and prev_flat[k].shape == flat[k].shape
            and prev_flat[k].dtype == flat[k].dtype
        ]
        prev_set = frozenset(prev_keys)
        # Delta leaves go through ONE batched call: with the device backend,
        # same-dtype (new, base) pairs pack into a single fused
        # XOR→byte-group→probe dispatch (produce_planes_batched(bases=...))
        # instead of a kernel launch + transfer per leaf.  Blobs are
        # identical to the leaf-at-a-time path on every backend.
        delta_keys = [
            k for k in keys
            if not is_base
            and k not in prev_set
            and k in base_flat
            and base_flat[k].shape == flat[k].shape
        ]
        delta_cts = dict(
            zip(
                delta_keys,
                zipnn.delta_compress_batched(
                    [flat[k] for k in delta_keys],
                    [base_flat[k] for k in delta_keys],
                    self.cfg.zipnn,
                ),
            )
        )
        moment_cts = dict(
            zip(
                prev_keys,
                zipnn.delta_compress_batched(
                    [flat[k] for k in prev_keys],
                    [prev_flat[k] for k in prev_keys],
                    self.cfg.zipnn,
                ),
            )
        )
        entries = []
        offset = 0
        with open(os.path.join(tmp, "data.bin"), "wb") as f:
            for key in keys:
                arr = flat[key]
                if key in moment_cts:
                    ct = moment_cts[key]
                    kind = "delta_prev"
                elif key in delta_cts:
                    ct = delta_cts[key]
                    kind = "delta"
                else:
                    ct = zipnn.compress_array(arr, self.cfg.zipnn)
                    kind = "full"
                f.write(ct.blob)
                entries.append(
                    {
                        "key": key,
                        "kind": kind,
                        "dtype": ct.dtype,
                        "shape": list(ct.shape),
                        "offset": offset,
                        "size": len(ct.blob),
                        "crc": zlib.crc32(ct.blob),
                        "raw": int(arr.nbytes),
                    }
                )
                offset += len(ct.blob)
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "kind": "base" if is_base else "delta",
            "base_step": base_step,
            "prev_step": prev_step if prev_keys else None,
            "comp_bytes": offset,
            "raw_bytes": sum(e["raw"] for e in entries),
            "entries": entries,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)                  # atomic publish

    # --------------------------------------------------------------- restore

    def _scan(self) -> List[Tuple[int, str, Optional[int]]]:
        out = []
        for name in sorted(os.listdir(self.cfg.directory)):
            if not name.startswith("step_"):
                continue
            mpath = os.path.join(self.cfg.directory, name, "manifest.json")
            try:
                with open(mpath) as f:
                    m = json.load(f)
                out.append((m["step"], m["kind"], m.get("base_step")))
            except (OSError, json.JSONDecodeError):
                continue                        # torn checkpoint: skip
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._scan()
        return steps[-1][0] if steps else None

    def _load_flat(
        self,
        step: int,
        device_resident: bool = False,
        _cache: Optional[Dict[int, Dict[str, np.ndarray]]] = None,
    ) -> Dict[str, np.ndarray]:
        # Memoize per restore call: a delta save references both its base
        # (weights) and the previous save (moments, "delta_prev"), and the
        # previous save references the base again — without the cache the
        # moment chain would re-decode every ancestor O(k^2) times.
        if _cache is None:
            _cache = {}
        if step in _cache:
            return _cache[step]
        d = os.path.join(self.cfg.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(d, "data.bin"), "rb") as f:
            data = f.read()
        base_flat = None
        if manifest["kind"] == "delta":
            # The base rides the same residence as the restore target: a
            # device-resident restore XORs against a device-resident base
            # (fused on device), never bouncing either through host memory.
            base_flat = self._load_flat(
                manifest["base_step"], device_resident=device_resident,
                _cache=_cache,
            )
        prev_flat = None
        if manifest.get("prev_step") is not None:
            prev_flat = self._load_flat(
                manifest["prev_step"], device_resident=device_resident,
                _cache=_cache,
            )
        out = {}
        full_entries = []
        full_cts = []
        for e in manifest["entries"]:
            blob = data[e["offset"] : e["offset"] + e["size"]]
            if zlib.crc32(blob) != e["crc"]:
                raise IOError(f"CRC mismatch in step_{step}:{e['key']}")
            ct = zipnn.CompressedTensor(blob, e["dtype"], tuple(e["shape"]))
            if e["kind"] == "delta":
                out[e["key"]] = zipnn.delta_decompress(
                    ct, base_flat[e["key"]], self.cfg.zipnn,
                    device_resident=device_resident,
                )
            elif e["kind"] == "delta_prev":
                out[e["key"]] = zipnn.delta_decompress(
                    ct, prev_flat[e["key"]], self.cfg.zipnn,
                    device_resident=device_resident,
                )
            else:
                full_entries.append(e)
                full_cts.append(ct)
        if full_cts:
            # Whole-tree batched restore: one decompress_pytree call groups
            # same-layout leaves into batched device dispatches instead of
            # a dispatch per leaf, and with device_resident=True the
            # device-resolved leaves never bounce through host memory.
            import jax.tree_util as jtu

            arrays = zipnn.decompress_pytree(
                {
                    "treedef": jtu.tree_structure([0] * len(full_cts)),
                    "leaves": full_cts,
                },
                self.cfg.zipnn,
                device_resident=device_resident,
            )
            for e, arr in zip(full_entries, arrays):
                out[e["key"]] = arr
        _cache[step] = out
        return out

    def restore(
        self, step: Optional[int] = None, *, device_resident: bool = False
    ) -> Tuple[int, PyTree]:
        """Newest valid checkpoint ≤ step (or overall). Torn/corrupt saves
        are skipped — the crash-recovery contract.

        ``device_resident=True`` keeps restored leaves on device as
        ``jax.Array``\\ s when the configured decode backend resolves to
        device (see ``zipnn.decompress_array``) — bits identical, zero
        device→host bounce; host-resolved leaves still restore as numpy.
        """
        candidates = [s for s, _, _ in self._scan() if step is None or s <= step]
        for s in reversed(candidates):
            try:
                return s, _unflatten(
                    self._load_flat(s, device_resident=device_resident)
                )
            except (IOError, OSError, KeyError):
                continue
        raise FileNotFoundError(f"no valid checkpoint in {self.cfg.directory}")

    def shard_restore(self, step: Optional[int], mesh, specs: PyTree) -> Tuple[int, PyTree]:
        """Restore + device_put onto an arbitrary mesh (elastic rescale).

        With ``CheckpointConfig.backend='device'|'auto'`` the restore's
        full decode — the device Huffman entropy stage plus the fused
        un-byte-group + inverse rotate + delta XOR back half
        (``core/device_entropy.py`` / ``core/device_unplane.py``) — runs on
        device and leaves stay device-resident straight into the
        ``device_put`` re-shard: only compressed bytes cross host→device
        and nothing bounces back.  Host-resolved configs restore through
        numpy exactly as before.
        """
        from repro.distributed import sharding

        s, tree = self.restore(step, device_resident=True)
        return s, sharding.device_put_tree(tree, mesh, specs)

    # ------------------------------------------------------------- retention

    def _gc(self) -> None:
        saves = self._scan()
        bases = [s for s, k, _ in saves if k == "base"]
        if len(bases) <= self.cfg.keep_bases:
            return
        cutoff = bases[-self.cfg.keep_bases]
        for s, kind, base in saves:
            if s < cutoff:
                path = os.path.join(self.cfg.directory, f"step_{s}")
                for root, _, files in os.walk(path, topdown=False):
                    for fn in files:
                        os.unlink(os.path.join(root, fn))
                    os.rmdir(root)

    # --------------------------------------------------------------- metrics

    def stats(self) -> List[Dict[str, Any]]:
        out = []
        for s, kind, base in self._scan():
            with open(
                os.path.join(self.cfg.directory, f"step_{s}", "manifest.json")
            ) as f:
                m = json.load(f)
            out.append(
                {
                    "step": s,
                    "kind": kind,
                    "base_step": base,
                    "ratio_pct": 100.0 * m["comp_bytes"] / max(m["raw_bytes"], 1),
                }
            )
        return out
