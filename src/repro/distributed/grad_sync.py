"""Cross-pod / decentralized gradient synchronization with ZipNN (paper
§2.1.2: FSDP-style weight/gradient traffic and federated contribution).

On-accelerator collectives (psum inside train_step) stay uncompressed —
variable-length payloads don't map onto XLA's fixed-shape collectives
(DESIGN.md §3).  What IS compressed is the *host-boundary* traffic that the
paper targets: cross-pod gradient/update exchange in decentralized training,
parameter-server style contribution uploads, and inter-run weight shipping.

`GradSync` compresses a gradient/update pytree, records the wire size, and
reconstructs bit-exactly on the receiving side.  `exchange()` simulates an
N-peer ring with a bandwidth model so examples/benchmarks can report
end-to-end sync time with vs without compression (Fig. 10 methodology
applied to gradients)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.core import zipnn
from repro.core.options import resolve_options

PyTree = Any


@dataclasses.dataclass
class WireStats:
    raw_bytes: int
    comp_bytes: int
    seconds_compress: float

    @property
    def ratio_pct(self) -> float:
        return 100.0 * self.comp_bytes / max(self.raw_bytes, 1)


class GradSync:
    """Engine-aware gradient packer.

    Codec knobs arrive as one ``CodecOptions`` bag (``options=``, see
    ``core/options.py``): ``threads`` fans the codec's (plane, chunk) work
    items across the engine's shared pool; ``backend`` selects the
    plane-producer path ('host' | 'device' | 'auto' — see
    ``core/device_plane.py``) and, with the canonical 'huffman' coder, the
    fused device Huffman bit-pack stage (``core/device_entropy.py``);
    ``entropy_backend`` overrides just that stage (mixed mode).  The loose
    legacy kwargs still work (DeprecationWarning; explicit kwarg wins over
    the bag).  Gradient payloads reuse the exact same codec work items as
    checkpoints, so the knobs apply unchanged and wire bytes are identical
    for every setting.
    """

    def __init__(
        self,
        config: zipnn.ZipNNConfig = zipnn.DEFAULT,
        *,
        options: zipnn.CodecOptions | None = None,
        threads: int | None = None,
        backend: str | None = None,
        entropy_backend: str | None = None,
    ):
        opts = resolve_options(
            options, threads=threads, backend=backend,
            entropy_backend=entropy_backend, _stacklevel=3,
        )
        self.config = config
        self.options = opts
        self.threads = opts.threads
        self.backend = opts.backend
        self.entropy_backend = opts.entropy_backend

    def pack(self, grads: PyTree) -> Tuple[Dict[str, Any], WireStats]:
        import time

        t0 = time.perf_counter()
        # Host backend: one batched tree fetch up front (cheaper than a
        # per-leaf synchronous D2H copy inside compress_array).  Device /
        # auto: leaves stay put — accelerator-resident tensors are planed on
        # device (batched multi-leaf dispatch) and only planed bytes cross.
        be = self.backend if self.backend is not None else self.config.plane_backend
        tree = jax.device_get(grads) if be == "host" else grads
        manifest = zipnn.compress_pytree(tree, self.config, options=self.options)
        dt = time.perf_counter() - t0
        return manifest, WireStats(manifest["raw_bytes"], manifest["comp_bytes"], dt)

    def unpack(self, manifest: Dict[str, Any]) -> PyTree:
        # The receive side uses the same knobs: with 'device'/'auto' the
        # entropy stage can decode through the device Huffman kernel
        # (core/device_entropy.py — only compressed bytes cross host→device)
        # and un-group + inverse rotate run as fused dispatches
        # (core/device_unplane.py), batched across same-layout leaves —
        # bytes identical to the host path.
        return zipnn.decompress_pytree(manifest, self.config, options=self.options)

    def exchange(
        self, grads: PyTree, n_peers: int, link_gbps: float = 1.0
    ) -> Dict[str, float]:
        """Ring all-reduce wire-time model: 2·(N−1)/N of the payload crosses
        each link; returns seconds with/without ZipNN on the payload."""
        manifest, st = self.pack(grads)
        rt = self.unpack(manifest)
        for a, b in zip(
            jax.tree_util.tree_leaves(jax.device_get(grads)),
            jax.tree_util.tree_leaves(rt),
        ):
            if np.asarray(a).tobytes() != np.asarray(b).tobytes():
                # Integrity check, not a debug aid — must survive python -O.
                raise IOError("lossy sync: decoded gradient != original bytes")
        factor = 2 * (n_peers - 1) / n_peers
        wire = link_gbps * 1e9 / 8
        return {
            "raw_s": st.raw_bytes * factor / wire,
            "zipnn_s": st.comp_bytes * factor / wire + st.seconds_compress,
            "ratio_pct": st.ratio_pct,
        }


def straggler_reissue_plan(
    shard_times: List[float], deadline_factor: float = 2.0
) -> List[int]:
    """Shards slower than deadline_factor × median get re-issued — valid
    because the data pipeline is deterministic in (step, shard) (any host can
    recompute any shard).  Returns the shard indices to re-issue."""
    med = float(np.median(shard_times))
    return [i for i, t in enumerate(shard_times) if t > deadline_factor * med]
