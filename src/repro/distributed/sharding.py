"""Logical-axis sharding: one rule table maps logical tensor axes to mesh
axes; activations use :func:`lshard` constraints, parameters get their
PartitionSpec from name-pattern rules over the pytree paths.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model') two-pod.
Batch shards over ('pod', 'data'); heads/ff/experts/vocab over 'model';
with ZeRO-3 (``zero3=True`` archs) the non-model parameter axis additionally
shards over 'data' (FSDP-style — GSPMD all-gathers at use sites).
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),   # filtered to existing mesh axes at use
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "experts_serve": "data",    # inference EP: experts live on the data axis
    "zero3": "data",            # secondary param axis under ZeRO-3
    "seq_sp": "model",          # sequence-parallel residual carry (cfg.sp)
}


def _abstract_mesh():
    """Current abstract mesh, or None on jax versions without the API."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    return get() if get is not None else None


def _current_mesh_axes() -> Optional[Tuple[str, ...]]:
    mesh = _abstract_mesh()
    if mesh is not None and mesh.axis_names:
        return tuple(mesh.axis_names)
    try:  # legacy `with mesh:` context (what launch/dryrun.py uses)
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if not pm.empty:
            return tuple(pm.axis_names)
    except Exception:
        pass
    return None


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Any]):
    """Activate logical→mesh rules (launcher/dryrun scope)."""
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def active_rules() -> Optional[Dict[str, Any]]:
    return getattr(_state, "rules", None)


def resolve(logical: Optional[str], mesh_axes: Tuple[str, ...]) -> Any:
    rules = active_rules() or DEFAULT_RULES
    target = rules.get(logical) if logical else None
    if target is None:
        return None
    if isinstance(target, tuple):
        hit = tuple(a for a in target if a in mesh_axes)
        return hit if hit else None
    return target if target in mesh_axes else None


def axis_size(name: str) -> int:
    """Size of a mesh axis in the active mesh context (1 if absent)."""
    try:
        from jax._src.mesh import thread_resources

        pm = thread_resources.env.physical_mesh
        if not pm.empty:
            return dict(pm.shape).get(name, 1)
    except Exception:
        pass
    am = _abstract_mesh()
    if am is not None and am.axis_names:
        return dict(am.shape).get(name, 1)
    return 1


def lshard(x, *logical_axes: Optional[str]):
    """with_sharding_constraint by logical axis names; no-op without mesh."""
    mesh_axes = _current_mesh_axes()
    if mesh_axes is None:
        return x
    spec = P(*[resolve(a, mesh_axes) for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter PartitionSpec rules (name-pattern over pytree paths)
# ---------------------------------------------------------------------------

# (regex over '/'-joined path, logical axes per trailing dimension).
# Leading scan (layer-stack) axes are padded with None automatically.
# ORDER MATTERS: first match wins — expert rules must precede the generic
# MLP rules (expert paths end in the same leaf names).
_PARAM_RULES = [
    # experts dominate MoE parameter/optimizer bytes → ZeRO-3 shards their
    # d_model dim over 'data' on top of expert parallelism over 'model'
    (r"experts/(w_gate|w_up)$", (("experts",), ("zero3",), None)),
    (r"experts/w_down$", (("experts",), None, ("zero3",))),
    (r"(wq|wk|wv|w_uq|w_uk|w_uv)/w$", (("zero3",), ("heads",))),
    (r"(wq|wk|wv)/b$", (("heads",),)),
    (r"wo/w$", (("heads",), ("zero3",))),
    # SwiGLU/GELU MLP leaves are raw arrays (no trailing '/w')
    (r"(w_gate|w_up|w_in)$", (("zero3",), ("ff",))),
    (r"(w_down|w_out)$", (("ff",), ("zero3",))),
    (r"b_in$", (("ff",),)),
    (r"(embed|lm_head|cls_head)/table$", (("vocab",), ("zero3",))),
    (r"pos/table$", (None, ("ff",))),
    (r"frontend_proj/w$", (None, ("zero3",))),
    (r"router/w$", (None, None)),
    (r"(w_dq|w_dkv|w_kr)/w$", (("zero3",), None)),
    # SSM params
    (r"(in_proj|out_proj)/w$", (("zero3",), ("heads",))),
    (r"ssm/(A_log|D|dt_bias)$", (("heads",),)),
    (r"conv/w$", (None, ("heads",))),
]


def _axis_size(axis: Any, mesh_sizes: Dict[str, int]) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh_sizes.get(a, 1)
        return n
    return mesh_sizes.get(axis, 1)


def _spec_for(
    path: str,
    shape: Tuple[int, ...],
    zero3: bool,
    mesh_axes: Tuple[str, ...],
    mesh_sizes: Dict[str, int],
) -> P:
    ndim = len(shape)
    for pat, dims in _PARAM_RULES:
        if re.search(pat, path):
            axes = []
            for d in dims:
                if d is None:
                    axes.append(None)
                    continue
                logical = d[0] if isinstance(d, tuple) else d
                if logical == "zero3":
                    axes.append(resolve("zero3", mesh_axes) if zero3 else None)
                elif logical == "ff_inner":
                    # expert-parallel models shard E over 'model'; the inner
                    # ff dim stays unsharded to avoid double-cutting
                    axes.append(None)
                else:
                    axes.append(resolve(logical, mesh_axes))
            pad = ndim - len(axes)               # leading scan axes
            axes = [None] * pad + axes
            # divisibility guard: unshardable dims (odd vocab, few kv heads)
            # fall back to replicated on that dim
            axes = [
                a if shape[i] % _axis_size(a, mesh_sizes) == 0 else None
                for i, a in enumerate(axes)
            ]
            return P(*axes)
    return P(*([None] * ndim))   # norms, scalars, biases: replicated


def param_pspecs(params: Any, *, zero3: bool = False, mesh=None) -> Any:
    """PartitionSpec pytree matching ``params`` via the name rules."""
    if mesh is not None:
        mesh_axes = tuple(mesh.axis_names)
        mesh_sizes = dict(mesh.shape)
    else:
        mesh_axes = _current_mesh_axes() or ()
        mesh_sizes = {}

    def one(path_tuple, leaf):
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path_tuple
        )
        return _spec_for(path, tuple(leaf.shape), zero3, mesh_axes, mesh_sizes)

    return jax.tree_util.tree_map_with_path(one, params)


def device_put_tree(tree: Any, mesh, specs: Any) -> Any:
    """device_put every leaf of ``tree`` onto ``mesh`` per its PartitionSpec.

    ``specs`` is a prefix-pytree of PartitionSpecs (None = leave the leaf
    where it is).  This is the shard-restore back half shared by
    ``checkpoint.manager.CheckpointManager.shard_restore`` and any elastic
    rescale path: the saved layout never constrains the restored one.

    Leaves may be host numpy arrays *or* already device-resident
    ``jax.Array``\\ s (the zero-bounce restore path: device-decoded leaves
    arrive here without ever touching host memory) — ``jax.device_put``
    re-shards a device-resident leaf device-to-device, so the compressed
    payload remains the only host→device transfer of the whole restore.
    """
    from jax.sharding import NamedSharding

    leaves_t, treedef_t = jax.tree_util.tree_flatten(tree)
    leaves_s = (
        treedef_t.flatten_up_to(specs)
        if specs is not None
        else [None] * len(leaves_t)
    )
    out = [
        jax.device_put(l, NamedSharding(mesh, sp)) if sp is not None else l
        for l, sp in zip(leaves_t, leaves_s)
    ]
    return jax.tree_util.tree_unflatten(treedef_t, out)


def batch_pspec(mesh=None) -> P:
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else (
        _current_mesh_axes() or ()
    )
    return P(resolve("batch", mesh_axes))
