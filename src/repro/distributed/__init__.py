"""Distribution substrate: logical-axis sharding rules, cross-pod gradient
sync with ZipNN compression, elastic re-sharding helpers."""

from . import sharding

__all__ = ["sharding"]
