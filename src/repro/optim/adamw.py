"""AdamW with fp32 moments, global-norm clipping, warmup+cosine schedule.

Moments live in fp32 pytrees mirroring the parameters, so under ZeRO-3 they
shard with the same PartitionSpecs (×"zero3" axis) — 8 bytes of optimizer
state per bf16 parameter, fully sharded.  The Adam epsilon (1e-8 ≈ 2⁻²⁷)
is the very noise floor the paper identifies (§3.1) as bounding the
exponent range from below — our Fig. 7 benchmark reproduces optimizer-state
compressibility from exactly these moments.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

# Top-level keys of :func:`init_opt_state`'s tree.  The checkpoint manager
# keys off these to store AdamW moments as XOR deltas against the *previous
# save* (not the periodic base): moments are EMAs, so step-over-step deltas
# are far sparser than weight deltas — the paper's Fig. 7 optimizer-state
# story applied to the save path.  Restoring replays the (bounded,
# ≤ base_every) chain bit-exactly.
MOMENT_KEYS: Tuple[str, ...] = ("m", "v")


def is_moment_path(key: str, moment_keys: Tuple[str, ...] = MOMENT_KEYS) -> bool:
    """True when a flat checkpoint key addresses an optimizer moment.

    Matches ``m/...`` / ``v/...`` (an opt state saved alone) and
    ``<anything>/m/...`` one level down (the train-state layout
    ``opt/m/...``) — a *parameter* named ``m`` deeper in the tree never
    matches.
    """
    parts = key.split("/")
    return bool(parts) and (
        parts[0] in moment_keys or (len(parts) > 1 and parts[1] in moment_keys)
    )


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> PyTree:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.copy, zeros)}


def _global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    cfg: AdamWConfig,
    params: PyTree,
    grads: PyTree,
    opt_state: PyTree,
    step: jnp.ndarray,
) -> Tuple[PyTree, PyTree, dict]:
    """One AdamW step. Returns (params, opt_state, metrics)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
