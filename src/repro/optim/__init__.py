from .adamw import AdamWConfig, init_opt_state, apply_updates, lr_schedule

__all__ = ["AdamWConfig", "init_opt_state", "apply_updates", "lr_schedule"]
