from .adamw import (
    AdamWConfig,
    MOMENT_KEYS,
    init_opt_state,
    is_moment_path,
    apply_updates,
    lr_schedule,
)

__all__ = [
    "AdamWConfig",
    "MOMENT_KEYS",
    "init_opt_state",
    "is_moment_path",
    "apply_updates",
    "lr_schedule",
]
