"""Training step: loss → grads → AdamW, with optional microbatch
accumulation, under pjit-style sharding.

The step is a pure function of (state, batch); all distribution is carried
by PartitionSpecs (params via name rules, batch over ('pod','data'), ZeRO-3
optionally sharding params+moments over 'data').  Remat policy comes from
the model config and is applied inside the layer scan.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import batch_pspec, param_pspecs
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state

PyTree = Any
TrainState = Dict[str, Any]      # {"params": …, "opt": {"m","v"}, "step": i32}


def init_train_state(model: Model, rng: jax.Array) -> TrainState:
    params = model.init(rng)
    return {
        "params": params,
        "opt": init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_train_state(model: Model) -> TrainState:
    key = jax.random.key(0)
    return jax.eval_shape(lambda: init_train_state(model, key))


def train_state_specs(model: Model, mesh=None) -> TrainState:
    """PartitionSpecs for the whole train state (moments mirror params)."""
    pspecs = param_pspecs(model.abstract_params(), zero3=model.cfg.zero3, mesh=mesh)
    from jax.sharding import PartitionSpec as P

    return {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs},
        "step": P(),
    }


def batch_pspecs(batch_tree: PyTree, mesh=None) -> PyTree:
    bp = batch_pspec(mesh)
    from jax.sharding import PartitionSpec as P

    def one(leaf):
        return P(*(list(bp) + [None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(one, batch_tree)


def make_train_step(
    model: Model,
    ocfg: AdamWConfig,
    *,
    microbatches: int = 1,
) -> Callable[[TrainState, Dict[str, Any]], Tuple[TrainState, Dict[str, Any]]]:
    """Build the jit-able train step.

    ``microbatches > 1`` splits the batch on axis 0 and accumulates grads
    with a lax.scan — activation memory drops ×M at the cost of M serial
    passes (a knob the §Perf hillclimb uses on memory-bound cells).
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def single(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def accumulated(params, batch):
        def split(x):
            return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

        mb = jax.tree_util.tree_map(split, batch)

        def body(carry, b):
            loss_a, grads_a = carry
            loss, _, grads = single(params, b)
            return (
                loss_a + loss / microbatches,
                jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches, grads_a, grads
                ),
            ), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), mb)
        return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}, grads

    def step_fn(state: TrainState, batch: Dict[str, Any]):
        params = state["params"]
        if microbatches > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        new_params, new_opt, om = apply_updates(
            ocfg, params, grads, state["opt"], state["step"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_state, metrics

    return step_fn
