import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and extract the roofline terms from the compiled artifact.

The two lines above run before ANY other import — jax locks the device
count at first init, and the dry-run needs 512 placeholder host devices to
build the production meshes.  Smoke tests and benchmarks never import this
module, so they keep seeing 1 device.

Per cell we record to experiments/dryrun/<cell>.json:
  * per-device argument/output/temp bytes (memory_analysis → proves it fits)
  * per-device HLO FLOPs and bytes accessed (cost_analysis)
  * collective bytes by opcode, parsed from the post-SPMD optimized HLO
  * MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) for the useful-compute ratio

Usage:
  python -m repro.launch.dryrun --arch yi_6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs, shape_cells  # noqa: E402
from repro.data import DataConfig, batch_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh, n_chips  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.serve.step import decode_state_specs, make_serve_step  # noqa: E402
from repro.train.step import (  # noqa: E402
    abstract_train_state,
    batch_pspecs,
    make_train_step,
    train_state_specs,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]"
)
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%[\w.\-]+\s*=\s*(\(?[^=]*?)\s*"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(x) for x in m.group(2).split(",") if x] or [1]
        sz = _DTYPE_BYTES[m.group(1)]
        for d in dims:
            sz *= d
        total += sz
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective wire bytes, parsed from post-SPMD optimized HLO.

    This dialect prints no operand types inline, so we size each op from its
    RESULT type and convert to approximate per-device wire bytes with
    opcode-specific factors (ring schedules):
      all-gather        → result            (each device receives ≈ full)
      all-reduce        → 2 × result        (reduce-scatter + all-gather)
      reduce-scatter    → result × (gs − 1) (receives the other shards)
      all-to-all        → result            (sends/receives ≈ result)
      collective-permute→ result
    Async pairs count once (the -done line; -start skipped — its tuple type
    aliases both buffers)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = _OP_RE.match(ls)
        if not m:
            continue
        result_types, op, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-start":
            continue
        rbytes = _shape_bytes(result_types)
        gm = _GROUPS_RE.search(ls)
        if gm:
            gs = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(ls)
            gs = len(gl.group(1).split(",")) if gl else 2
        if op == "all-reduce":
            wire = 2 * rbytes
        elif op == "reduce-scatter":
            wire = rbytes * max(gs - 1, 1)
        else:
            wire = rbytes
        out[op] += wire
        out["count"] += 1
    return out


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step —
    weak-type-correct, shardable, zero allocation."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    model = build_model(cfg)
    dc = DataConfig(seq_len=cell.seq_len, global_batch=cell.global_batch)
    if cell.kind == "train":
        return {
            "state": abstract_train_state(model),
            "batch": batch_specs(cfg, dc),
        }
    if cell.kind == "prefill":
        return {
            "params": model.abstract_params(),
            "batch": batch_specs(cfg, dc),
        }
    # decode: one new token against a full cache
    state = jax.eval_shape(
        lambda: model.init_decode_state(cell.global_batch, cell.seq_len)
    )
    return {
        "params": model.abstract_params(),
        "dstate": state,
        "tokens": jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32),
    }


def _shardings(tree, specs, mesh):
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _with_dispatch_shards(cfg, cell, mesh):
    """MoE dispatch locality: one dispatch row per batch shard."""
    if not cfg.moe:
        return cfg
    bs = 1
    for a in ("pod", "data"):
        bs *= mesh.shape.get(a, 1)
    t = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    if t % bs:
        bs = 1
    return dataclasses.replace(cfg, dispatch_shards=bs)


def _lower_and_compile(cfg, cell, mesh):
    """Lower + compile one step for a (possibly replaced) config."""
    cfg = _with_dispatch_shards(cfg, cell, mesh)
    model = build_model(cfg)
    dc = DataConfig(seq_len=cell.seq_len, global_batch=cell.global_batch)
    with mesh:
        if cell.kind == "train":
            state = abstract_train_state(model)
            batch = batch_specs(cfg, dc)
            sspecs = train_state_specs(model, mesh)
            bspecs = batch_pspecs(batch, mesh)
            step = make_train_step(model, AdamWConfig())
            jitted = jax.jit(
                step,
                in_shardings=(
                    _shardings(state, sspecs, mesh),
                    _shardings(batch, bspecs, mesh),
                ),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, batch)
        elif cell.kind == "prefill":
            params = model.abstract_params()
            batch = batch_specs(cfg, dc)
            pspecs = model.param_specs(mesh)
            bspecs = batch_pspecs(batch, mesh)

            def prefill(p, b):
                return model.forward(p, b)[0]

            jitted = jax.jit(
                prefill,
                in_shardings=(
                    _shardings(params, pspecs, mesh),
                    _shardings(batch, bspecs, mesh),
                ),
            )
            lowered = jitted.lower(params, batch)
        else:  # decode
            from repro.serve.step import inference_param_specs

            params = model.abstract_params()
            dstate = jax.eval_shape(
                lambda: model.init_decode_state(cell.global_batch, cell.seq_len)
            )
            tokens = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
            pspecs = inference_param_specs(model, mesh)
            dspecs = decode_state_specs(model, dstate, mesh)
            serve = make_serve_step(model)
            jitted = jax.jit(
                serve,
                in_shardings=(
                    _shardings(params, pspecs, mesh),
                    _shardings(dstate, dspecs, mesh),
                    NamedSharding(
                        mesh,
                        P("data" if cell.global_batch % mesh.shape["data"] == 0 else None),
                    ),
                ),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params, dstate, tokens)
        compiled = lowered.compile()
    return compiled


def _cost_analysis(compiled) -> Dict[str, float]:
    """Version-portable compiled.cost_analysis(): older jax returns a
    one-element list of dicts, newer jax the dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _costs_of(compiled) -> Dict[str, float]:
    cost = _cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    for k, v in coll.items():
        out[f"coll_{k}"] = float(v)
    return out


import dataclasses  # noqa: E402


def accounting_costs(cfg, cell, mesh) -> Dict[str, float]:
    """Trip-count-correct per-device cost terms.

    XLA's cost analysis counts a lax.scan body ONCE (verified empirically),
    so the production lowering (scanned layers + flash attention) massively
    undercounts FLOPs/collectives.  We lower an *accounting variant* —
    unrolled layer stack + dense masked attention (flop-identical to masked
    flash) — at 2–3 small depths and extrapolate linearly in depth, which is
    exact because layers are homogeneous.  Memory/compile-proof still come
    from the production variant.
    """
    def series(over) -> Dict[str, float]:
        fam = cfg.family
        if fam == "hybrid":
            f6 = _costs_of(_lower_and_compile(dataclasses.replace(cfg, n_layers=6, **over), cell, mesh))
            f7 = _costs_of(_lower_and_compile(dataclasses.replace(cfg, n_layers=7, **over), cell, mesh))
            f12 = _costs_of(_lower_and_compile(dataclasses.replace(cfg, n_layers=12, **over), cell, mesh))
            out = {}
            ng = cfg.n_layers // cfg.shared_attn_every      # 13 shared applications
            for k in f6:
                m = f7[k] - f6[k]                            # one mamba layer
                s = (f12[k] - f6[k]) - 6 * m                 # one shared block
                base = f6[k] - 6 * m - s
                out[k] = base + cfg.n_layers * m + ng * s
            return out
        if cfg.moe and cfg.first_k_dense:
            f2 = _costs_of(_lower_and_compile(dataclasses.replace(cfg, n_layers=2, **over), cell, mesh))
            f3 = _costs_of(_lower_and_compile(dataclasses.replace(cfg, n_layers=3, **over), cell, mesh))
            return {k: f2[k] + (cfg.n_layers - 2) * (f3[k] - f2[k]) for k in f2}
        f1 = _costs_of(_lower_and_compile(dataclasses.replace(cfg, n_layers=1, **over), cell, mesh))
        f2 = _costs_of(_lower_and_compile(dataclasses.replace(cfg, n_layers=2, **over), cell, mesh))
        return {k: f1[k] + (cfg.n_layers - 1) * (f2[k] - f1[k]) for k in f1}

    acct = series(dict(scan_layers=False, attn_impl="dense"))
    # The dense-attention series is flop/collective-exact but its
    # bytes_accessed materializes S×S scores the flash path never writes to
    # HBM.  For train/prefill of attention archs, a second flash series
    # provides the memory term (ideal-reuse lower bound; dense = upper).
    if cell.kind != "decode" and cfg.family != "ssm":
        flash = series(dict(scan_layers=False, attn_impl="flash"))
        acct["bytes_accessed_dense_ub"] = acct["bytes_accessed"]
        acct["bytes_accessed"] = flash["bytes_accessed"]
    return acct


def run_cell(
    arch: str, shape: str, *, multi_pod: bool, out_dir: Optional[str] = None,
    cfg_override=None, tag: str = "",
) -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(mesh)
    t0 = time.time()
    compiled = _lower_and_compile(cfg, cell, mesh)
    t_compile = time.time() - t0
    t_lower = 0.0

    mem = compiled.memory_analysis()
    cost = _cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())

    # Accounting terms feed the single-pod roofline table only; the
    # multi-pod pass is the pod-axis shard proof (lower+compile+memory).
    if not multi_pod:
        t0 = time.time()
        acct = accounting_costs(cfg, cell, mesh)
        t_acct = time.time() - t0
    else:
        acct, t_acct = {}, 0.0

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        model_flops = 6 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        model_flops = 2 * n_active * tokens
    else:
        tokens = cell.global_batch          # one token per sequence
        model_flops = 2 * n_active * tokens

    result = {
        "arch": arch,
        "shape": shape,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "params": int(n_params),
        "active_params": int(n_active),
        "model_flops": float(model_flops),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "accounting_s": round(t_acct, 1),
        # production lowering (scan+flash): true memory picture; its
        # flops/collectives are scan-undercounted and kept for reference only
        "per_device_production_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives_bytes": coll,
        },
        "per_device_memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_hint_bytes": int(
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        # trip-count-correct accounting (unrolled + dense attn, extrapolated)
        "per_device_accounting": acct,
        "status": "ok",
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}.{shape}.{result['mesh']}{tag}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="every applicable cell")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_archs():
            for cell in shape_cells(get_config(arch)):
                cells.append((arch, cell.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}.{shape}.{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, f"{tag}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            try:
                r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out)
                acct = r["per_device_accounting"]
                mem = r["per_device_memory"]
                coll_sum = sum(
                    v for k, v in acct.items()
                    if k.startswith("coll_") and k != "coll_count"
                )
                print(
                    f"[ok]   {tag}: compile={r['compile_s']}s acct={r['accounting_s']}s "
                    f"flops/dev={acct.get('flops', 0):.3g} "
                    f"mem/dev={mem['peak_hint_bytes']/2**30:.2f}GiB "
                    f"coll/dev={coll_sum/2**20:.1f}MiB", flush=True,
                )
            except Exception as e:
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                traceback.print_exc(limit=4)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape, "status": "fail",
                                   "error": f"{type(e).__name__}: {e}"}, f)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
