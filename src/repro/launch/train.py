"""Training driver: config-driven launcher with ZipNN checkpointing.

Runs on anything from this CPU host (reduced configs) to a multi-pod TPU
fleet (full configs under the production mesh).  Fault-tolerance posture:

  * auto-resume from the newest valid checkpoint (torn saves skipped);
  * async ZipNN-compressed saves with XOR-delta chains + periodic bases;
  * deterministic data pipeline keyed by step — after elastic re-shard or
    node replacement the stream continues bit-identically;
  * elastic restore: the checkpoint layout is mesh-independent
    (host-numpy trees re-device_put against whatever mesh exists today).

Usage (CPU demo):
  python -m repro.launch.train --arch repro_gpt_100m --reduced \
      --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, make_batch
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="repro_gpt_100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--base-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch, seed=args.seed)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                       total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, ocfg, microbatches=args.microbatches))

    mgr = None
    state = None
    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(
            CheckpointConfig(args.ckpt_dir, base_every=args.base_every)
        )
        latest = mgr.latest_step()
        if latest is not None:
            print(f"[resume] restoring step {latest} from {args.ckpt_dir}")
            _, tree = mgr.restore(latest)
            state = jax.tree_util.tree_map(jax.numpy.asarray, tree)
            start = int(np.asarray(state["step"]))
    if state is None:
        state = init_train_state(model, jax.random.key(args.seed))

    t0 = time.time()
    tokens_done = 0
    for step in range(start, args.steps):
        batch = make_batch(cfg, dc, step)
        state, metrics = step_fn(state, batch)
        tokens_done += args.batch * args.seq
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            dt = time.time() - t0
            print(
                f"step {step+1:5d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"lr={float(metrics['lr']):.2e} tok/s={tokens_done/dt:,.0f}"
            )
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state)          # async, off critical path
    if mgr:
        mgr.save(args.steps, state, blocking=True)
        for s in mgr.stats():
            print(f"[ckpt] step={s['step']:5d} kind={s['kind']:5s} "
                  f"compressed_to={s['ratio_pct']:.1f}%")


if __name__ == "__main__":
    main()
