"""Serving driver: load a ZipNN-compressed checkpoint, batch requests,
greedy-decode.

CPU demo:
  python -m repro.launch.serve --arch repro_gpt_100m --reduced \
      --ckpt-dir /tmp/ckpt --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_config
from repro.models import build_model
from repro.serve.step import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="repro_gpt_100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only — nothing to decode")
    model = build_model(cfg)

    if args.ckpt_dir:
        mgr = CheckpointManager(CheckpointConfig(args.ckpt_dir))
        step, tree = mgr.restore()
        params = jax.tree_util.tree_map(jnp.asarray, tree["params"])
        print(f"[serve] restored step {step} from ZipNN checkpoint")
    else:
        params = model.init(jax.random.key(args.seed))
        print("[serve] random init (no --ckpt-dir)")

    rng = np.random.default_rng(args.seed)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    out, _ = greedy_generate(model, params, prompt, args.gen)
    dt = time.time() - t0
    print(f"[serve] generated {args.batch}×{args.gen} tokens in {dt:.1f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("first sequence:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
