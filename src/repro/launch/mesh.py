"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — device count is locked on first jax init, and
only launch/dryrun.py (which sets XLA_FLAGS first) may build the 512-way
placeholder topology.
"""

from __future__ import annotations

import jax

SINGLE_POD = (16, 16)                 # 256 chips (v5e pod slice)
MULTI_POD = (2, 16, 16)               # 2 pods × 256 = 512 chips


def _mk(shape, axes):
    # Pin Auto axis types where the API exists: the jax 0.9 default flips to
    # Explicit.  Older jax (< 0.4.38) has neither jax.sharding.AxisType nor
    # the axis_types= kwarg — there Auto is the only behavior, so plain
    # make_mesh is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes)
            )
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke paths."""
    return _mk((1, 1), ("data", "model"))


def n_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
