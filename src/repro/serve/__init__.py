from .compressed import CompressedParamStore
from .kvcache import KVCacheStore
from .step import (
    decode_state_specs,
    make_compressed_serve_step,
    make_kv_tiered_serve_step,
    make_prefill,
    make_serve_step,
)

__all__ = [
    "CompressedParamStore",
    "KVCacheStore",
    "decode_state_specs",
    "make_compressed_serve_step",
    "make_kv_tiered_serve_step",
    "make_prefill",
    "make_serve_step",
]
