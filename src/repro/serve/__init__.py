from .step import decode_state_specs, make_serve_step, make_prefill

__all__ = ["decode_state_specs", "make_serve_step", "make_prefill"]
