from .compressed import CompressedParamStore
from .step import (
    decode_state_specs,
    make_compressed_serve_step,
    make_prefill,
    make_serve_step,
)

__all__ = [
    "CompressedParamStore",
    "decode_state_specs",
    "make_compressed_serve_step",
    "make_prefill",
    "make_serve_step",
]
