"""Compressed-resident serving store: weights at rest stay ZNN1 payloads.

Serving holds the full uncompressed model in HBM today — decompression
happens once, up front, and the paper's 33%+ savings evaporate the moment
the forward pass starts.  Huff-LLM / ZipServ (PAPERS.md) show the
alternative: keep the weights *compressed at rest* and decode each layer
just ahead of its matmuls, so decoded weights only ever exist for the
layers currently in flight.

``CompressedParamStore`` is the at-rest half of that design.  It splits a
model's parameter tree along the stacked-layer leading axis into per-layer
subtrees and compresses each one into ZNN1 payloads (one
:func:`repro.core.zipnn.compress_pytree` manifest per layer, so a layer
decode is one batched multi-leaf dispatch).  Non-stacked params — embed,
final norm, lm head, learned positions — are the ``static`` residue: they
are touched every token and stay uncompressed.

``decode_layer`` restores one layer through
``zipnn.decompress_pytree(..., device_resident=True)``: under the device
backends only the compressed payload crosses host→device (the device
Huffman decoder feeds the fused un-plane consumer in place) and the leaves
land as device-resident ``jax.Array``\\ s; under the host backends the same
call returns bit-identical numpy — the knob contract.  The ring scheduler
(:func:`repro.serve.step.make_compressed_serve_step`) drives
decode/release; the store only does bookkeeping: ``resident_count`` /
``peak_resident`` count decoded-layer slots alive right now / ever, which
is what the "at most ``ring`` decoded layers" claim asserts against.

Codec knobs arrive as one ``CodecOptions`` bag (``options=``, see
``core/options.py``) and are instance-carried — the store forwards the
bag on every compress/decompress edge, and ``analysis/knobs.py`` pins the
constructor surface.  The loose legacy kwargs (``threads`` / ``backend``
/ ``entropy_backend``) still work with a DeprecationWarning; an explicit
kwarg wins over the bag.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core import codec, zipnn
from repro.core.options import resolve_options

PyTree = Any

# Stacked-layer top-level keys across the model zoo (leading axis = layer).
# hybrid's nested mamba_groups/shared_attn layout is not ring-schedulable
# (shared params repeat across groups) and is rejected by the scheduler.
DEFAULT_STACK_KEYS: Tuple[str, ...] = ("layers", "dense_layers", "moe_layers")


def _leaf_nbytes(leaf: Any) -> int:
    return int(np.size(leaf)) * np.dtype(leaf.dtype).itemsize


class CompressedParamStore:
    """Per-layer ZNN1 payloads at rest + decoded-slot residency accounting."""

    def __init__(
        self,
        config: Optional[zipnn.ZipNNConfig] = None,
        *,
        options: Optional[zipnn.CodecOptions] = None,
        threads: Optional[int] = None,
        backend: Optional[str] = None,
        entropy_backend: Optional[str] = None,
        payload_feed: bool = False,
    ) -> None:
        opts = resolve_options(
            options, threads=threads, backend=backend,
            entropy_backend=entropy_backend, _stacklevel=3,
        )
        self._config = zipnn.DEFAULT if config is None else config
        self._options = opts
        self._threads = opts.threads
        self._backend = opts.backend
        self._entropy_backend = opts.entropy_backend
        self.payload_feed = payload_feed
        self.static: Dict[str, PyTree] = {}
        self._stacks: Dict[str, List[Dict[str, Any]]] = {}
        # payload_feed=True: per-layer, per-leaf ArrayFeeds (None where a
        # leaf is feed-ineligible and rides the per-call decode instead).
        self._feeds: Dict[str, List[List[Optional[zipnn.ArrayFeed]]]] = {}
        self._lock = threading.Lock()
        self._resident: set = set()
        self.peak_resident = 0

    # -- construction -----------------------------------------------------

    @classmethod
    def from_params(
        cls,
        params: Mapping[str, PyTree],
        config: Optional[zipnn.ZipNNConfig] = None,
        *,
        stack_keys: Optional[Tuple[str, ...]] = None,
        options: Optional[zipnn.CodecOptions] = None,
        threads: Optional[int] = None,
        backend: Optional[str] = None,
        entropy_backend: Optional[str] = None,
        payload_feed: bool = False,
    ) -> "CompressedParamStore":
        """Compress ``params``' stacked-layer subtrees into a store.

        Every top-level key in ``stack_keys`` (default: the zoo's stacked
        keys present in ``params``) is split along its leading layer axis
        and compressed per layer; everything else stays uncompressed in
        ``store.static``.  Compression is deterministic, so two stores
        built from the same params hold byte-identical payloads on any
        backend/threads combination.

        ``payload_feed=True`` additionally parses every layer's payloads
        into device-resident :class:`~repro.core.zipnn.ArrayFeed` plans
        (:func:`~repro.core.zipnn.build_array_feed`) — the compressed words
        upload to HBM **here, once**, and every later ring decode runs with
        zero host→device payload traffic.  Leaves a feed cannot cover ride
        the per-call decode path; decoded bits are identical either way.
        """
        import jax

        if not isinstance(params, Mapping):
            raise ValueError(
                "from_params expects the model's top-level param dict"
            )
        store = cls(
            config,
            options=resolve_options(
                options, threads=threads, backend=backend,
                entropy_backend=entropy_backend, _stacklevel=3,
            ),
            payload_feed=payload_feed,
        )
        keys = DEFAULT_STACK_KEYS if stack_keys is None else stack_keys
        for key, sub in params.items():
            if key not in keys:
                store.static[key] = sub
                continue
            leaves = jax.tree_util.tree_leaves(sub)
            if not leaves:
                continue
            n = leaves[0].shape[0]
            store._stacks[key] = [
                zipnn.compress_pytree(
                    jax.tree_util.tree_map(lambda a: a[i], sub),
                    store._config,
                    options=store._options,
                )
                for i in range(n)
            ]
            if payload_feed:
                store._feeds[key] = [
                    [
                        zipnn.build_array_feed(
                            ct, store._config, options=store._options
                        )
                        for ct in manifest["leaves"]
                    ]
                    for manifest in store._stacks[key]
                ]
        return store

    # -- decode / residency ------------------------------------------------

    def _decode_leaf(self, key: str, i: int, j: int) -> Any:
        """Decode leaf ``j`` of layer ``i`` — feed path when a feed covers
        it, per-call decode otherwise; bit-identical either way."""
        feeds = self._feeds.get(key)
        if feeds is not None:
            feed = feeds[i][j]
            if feed is not None:
                return feed.decode()
        return zipnn.decompress_array(
            self._stacks[key][i]["leaves"][j],
            self._config,
            options=self._options.replace(device_resident=True),
        )

    def decode_layer(self, key: str, i: int) -> PyTree:
        """Decode layer ``i`` of stack ``key`` into a ring slot.

        One batched ``decompress_pytree(..., device_resident=True)`` call
        (or, with ``payload_feed=True``, per-leaf fused decodes straight
        from the resident payload buffers — zero host→device payload
        traffic): bit-identical leaves on every backend combo;
        device-resolved leaves stay on device with zero host bounce.
        Marks the slot resident — the caller owns it until :meth:`release`.
        """
        import jax

        manifest = self._stacks[key][i]
        if key in self._feeds:
            arrays = [
                self._decode_leaf(key, i, j)
                for j in range(len(manifest["leaves"]))
            ]
            tree = jax.tree_util.tree_unflatten(manifest["treedef"], arrays)
        else:
            tree = zipnn.decompress_pytree(
                manifest,
                self._config,
                options=self._options.replace(device_resident=True),
            )
        with self._lock:
            self._resident.add((key, i))
            self.peak_resident = max(self.peak_resident, len(self._resident))
        return tree

    def release(self, key: str, i: int) -> None:
        """Return a decoded slot to the ring (drops the store's claim; the
        buffers themselves die when the layer's compute finishes)."""
        with self._lock:
            self._resident.discard((key, i))

    # -- per-tile decode ---------------------------------------------------

    def n_leaves(self, key: str) -> int:
        """Leaves per layer of stack ``key`` (constant across its layers)."""
        return len(self._stacks[key][0]["leaves"])

    def tile_leaf_ids(self, key: str, t: int, tiles: int) -> range:
        """Leaf indices of tile ``t`` when a layer splits into ``tiles``
        contiguous tensor-groups (``codec.split_ids`` geometry — trailing
        tiles may be empty when a layer has fewer leaves than tiles)."""
        ranges = codec.split_ids(self.n_leaves(key), tiles)
        return ranges[t] if t < len(ranges) else range(0)

    def decode_layer_tile(
        self, key: str, i: int, t: int, tiles: int
    ) -> Dict[int, Any]:
        """Decode tile ``t`` of layer ``i`` — one contiguous tensor-group.

        Returns ``{leaf_index: array}`` for the tile's leaves (empty for
        trailing empty tiles) and marks one *tile slot* resident, so
        ``peak_resident`` counts tile-granular residency: a ring of
        ``ring`` layers split ``tiles`` ways holds at most ``ring × tiles``
        tile slots.  Tiling changes scheduling and residency only — the
        reassembled layer (:meth:`layer_unflatten`) is leaf-for-leaf
        identical to :meth:`decode_layer`.
        """
        arrays = {
            j: self._decode_leaf(key, i, j)
            for j in self.tile_leaf_ids(key, t, tiles)
        }
        with self._lock:
            self._resident.add((key, i, t, tiles))
            self.peak_resident = max(self.peak_resident, len(self._resident))
        return arrays

    def release_tile(self, key: str, i: int, t: int, tiles: int) -> None:
        """Tile twin of :meth:`release`."""
        with self._lock:
            self._resident.discard((key, i, t, tiles))

    def layer_unflatten(self, key: str, i: int, arrays: List[Any]) -> PyTree:
        """Reassemble a layer tree from its decoded leaves (in leaf order)."""
        import jax

        return jax.tree_util.tree_unflatten(
            self._stacks[key][i]["treedef"], arrays
        )

    @property
    def resident_count(self) -> int:
        with self._lock:
            return len(self._resident)

    def reset_peak(self) -> None:
        with self._lock:
            self._resident.clear()
            self.peak_resident = 0

    # -- introspection -----------------------------------------------------

    @property
    def stack_keys(self) -> Tuple[str, ...]:
        return tuple(self._stacks)

    def n_layers(self, key: str) -> int:
        return len(self._stacks.get(key, ()))

    @property
    def raw_bytes(self) -> int:
        """Uncompressed size of the compressed-at-rest stacks."""
        return sum(m["raw_bytes"] for ms in self._stacks.values() for m in ms)

    @property
    def comp_bytes(self) -> int:
        """ZNN1 payload size actually held at rest."""
        return sum(m["comp_bytes"] for ms in self._stacks.values() for m in ms)

    @property
    def ratio_pct(self) -> float:
        return 100.0 * self.comp_bytes / max(1, self.raw_bytes)

    @property
    def device_payload_bytes(self) -> int:
        """HBM resident bytes held by the payload feeds (0 when
        ``payload_feed=False`` — payloads then live host-side at rest)."""
        return sum(
            feed.device_bytes
            for layers in self._feeds.values()
            for per_leaf in layers
            for feed in per_leaf
            if feed is not None
        )

    @property
    def static_bytes(self) -> int:
        import jax

        return sum(
            _leaf_nbytes(l)
            for sub in self.static.values()
            for l in jax.tree_util.tree_leaves(sub)
        )

    @property
    def max_layer_raw_bytes(self) -> int:
        """Decoded size of the largest single layer — one ring slot."""
        return max(
            (m["raw_bytes"] for ms in self._stacks.values() for m in ms),
            default=0,
        )

    def footprint_bytes(self, ring: int = 2) -> int:
        """Serving-time weight footprint: payloads at rest + static residue
        + ``ring`` decoded-layer slots (vs ``raw_bytes + static_bytes``
        for the uncompressed model)."""
        return (
            self.comp_bytes
            + self.static_bytes
            + ring * self.max_layer_raw_bytes
        )
