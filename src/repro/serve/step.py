"""Serving: prefill + single-token decode with sharded caches.

Cache sharding policy (decode cells):
  * batch axis → 'data' when divisible (decode_32k: 128/16 ✓; long_500k has
    batch 1 → replicated over data, noted in EXPERIMENTS.md);
  * kv-head axis → 'model' when divisible (MQA granite kv=1 → replicated;
    its head_dim shards instead);
  * MLA latent dim → 'model' (contraction-sharded attention, partial-sum
    all-reduce inserted by GSPMD);
  * SSM state heads → 'model' when divisible.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models.model import Model

PyTree = Any


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0


def inference_param_specs(model: Model, mesh) -> PyTree:
    """Serving-time parameter layout (§Perf: decode is not ZeRO-3 country).

    Dense/attention weights: TP over 'model', replicated over 'data' —
    per-layer ZeRO-3 all-gathers amortize over training batches but cost
    GiBs per decoded token.  Experts: E over 'data' × ff over 'model' so
    expert weights never move; the tiny decode token buffers all-to-all
    instead."""
    import jax.tree_util as jtu

    base = model.param_specs(mesh)          # includes zero3 if cfg.zero3
    cfg = model.cfg

    def one(path_tuple, leaf, spec):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        nd = leaf.ndim
        if "experts/" in path and cfg.n_experts:
            e_ax = "data" if _div(cfg.n_experts, mesh, "data") else None
            f_ax = "model" if _div(cfg.moe_d_ff, mesh, "model") else None
            pad = [None] * (nd - 3)
            if path.endswith("w_down"):
                return P(*(pad + [e_ax, f_ax, None]))
            return P(*(pad + [e_ax, None, f_ax]))
        # strip the zero3 ('data') axis everywhere else
        return P(*[None if ax == "data" else ax for ax in (list(spec) + [None] * nd)[:nd]])

    abstract = model.abstract_params()
    return jtu.tree_map_with_path(
        lambda p, l, s: one(p, l, s), abstract, base
    )


def decode_state_specs(model: Model, state_tree: PyTree, mesh) -> PyTree:
    cfg = model.cfg

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        nd = leaf.ndim
        if path.endswith("pos"):
            return P()
        shape = leaf.shape
        if "kv_" in path:
            # (L, B, Lc, G, hd).  Preference order for the 'model' axis:
            # kv heads when they divide, else the CACHE LENGTH dim —
            # length-sharded decode keeps the score einsum local and
            # combines softmax via tiny stat all-reduces.  Sharding head_dim
            # forces XLA into involuntary full-cache all-gathers
            # (§Perf cell 2: 2.5 GiB × n_layers per step before this).
            b = "data" if _div(shape[1], mesh, "data") else None
            if _div(shape[3], mesh, "model"):
                return P(None, b, None, "model", None)
            if _div(shape[2], mesh, "model"):
                return P(None, b, "model", None, None)
            hd = "model" if _div(shape[4], mesh, "model") else None
            return P(None, b, None, None, hd)
        if "mla_" in path:
            # (L, B, Lc, r) — shard the cache length; sharding the latent r
            # makes every layer's score einsum a (B,H,Lc)-sized partial-sum
            # all-reduce (§Perf cell 1/2 finding).
            b = "data" if _div(shape[1], mesh, "data") else None
            if _div(shape[2], mesh, "model"):
                return P(None, b, "model", None)
            r = "model" if _div(shape[3], mesh, "model") else None
            return P(None, b, None, r)
        if "ssm_state" in path:
            # (L[, G], B, H, P, N)
            b = "data" if _div(shape[-4], mesh, "data") else None
            h = "model" if _div(shape[-3], mesh, "model") else None
            return P(*([None] * (nd - 4) + [b, h, None, None]))
        if "ssm_conv" in path:
            b = "data" if _div(shape[-3], mesh, "data") else None
            c = "model" if _div(shape[-1], mesh, "model") else None
            return P(*([None] * (nd - 3) + [b, None, c]))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, state_tree)


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, state, tokens) → (logits, state)."""

    def serve_step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return serve_step


def make_prefill(model: Model) -> Callable:
    """prefill(params, batch) → logits for the full prompt (chunked attn)."""

    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill


def greedy_generate(
    model: Model, params, prompt, steps: int
) -> Tuple[Any, Any]:
    """Small-scale generation loop for examples/tests (feeds tokens one by
    one through the decode step; caches sized for prompt+steps)."""
    import jax.numpy as jnp

    B, S = prompt.shape
    state = model.init_decode_state(B, S + steps, start_pos=0)
    step = jax.jit(model.decode_step)
    logits = None
    for t in range(S):
        logits, state = step(params, state, prompt[:, t : t + 1])
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1), state
