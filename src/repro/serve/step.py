"""Serving: prefill + single-token decode with sharded caches.

Cache sharding policy (decode cells):
  * batch axis → 'data' when divisible (decode_32k: 128/16 ✓; long_500k has
    batch 1 → replicated over data, noted in EXPERIMENTS.md);
  * kv-head axis → 'model' when divisible (MQA granite kv=1 → replicated;
    its head_dim shards instead);
  * MLA latent dim → 'model' (contraction-sharded attention, partial-sum
    all-reduce inserted by GSPMD);
  * SSM state heads → 'model' when divisible.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models.model import Model

PyTree = Any


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0


def inference_param_specs(model: Model, mesh) -> PyTree:
    """Serving-time parameter layout (§Perf: decode is not ZeRO-3 country).

    Dense/attention weights: TP over 'model', replicated over 'data' —
    per-layer ZeRO-3 all-gathers amortize over training batches but cost
    GiBs per decoded token.  Experts: E over 'data' × ff over 'model' so
    expert weights never move; the tiny decode token buffers all-to-all
    instead."""
    import jax.tree_util as jtu

    base = model.param_specs(mesh)          # includes zero3 if cfg.zero3
    cfg = model.cfg

    def one(path_tuple, leaf, spec):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        nd = leaf.ndim
        if "experts/" in path and cfg.n_experts:
            e_ax = "data" if _div(cfg.n_experts, mesh, "data") else None
            f_ax = "model" if _div(cfg.moe_d_ff, mesh, "model") else None
            pad = [None] * (nd - 3)
            if path.endswith("w_down"):
                return P(*(pad + [e_ax, f_ax, None]))
            return P(*(pad + [e_ax, None, f_ax]))
        # strip the zero3 ('data') axis everywhere else
        return P(*[None if ax == "data" else ax for ax in (list(spec) + [None] * nd)[:nd]])

    abstract = model.abstract_params()
    return jtu.tree_map_with_path(
        lambda p, l, s: one(p, l, s), abstract, base
    )


def decode_state_specs(model: Model, state_tree: PyTree, mesh) -> PyTree:
    cfg = model.cfg

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_tuple)
        nd = leaf.ndim
        if path.endswith("pos"):
            return P()
        shape = leaf.shape
        if "kv_" in path:
            # (L, B, Lc, G, hd).  Preference order for the 'model' axis:
            # kv heads when they divide, else the CACHE LENGTH dim —
            # length-sharded decode keeps the score einsum local and
            # combines softmax via tiny stat all-reduces.  Sharding head_dim
            # forces XLA into involuntary full-cache all-gathers
            # (§Perf cell 2: 2.5 GiB × n_layers per step before this).
            b = "data" if _div(shape[1], mesh, "data") else None
            if _div(shape[3], mesh, "model"):
                return P(None, b, None, "model", None)
            if _div(shape[2], mesh, "model"):
                return P(None, b, "model", None, None)
            hd = "model" if _div(shape[4], mesh, "model") else None
            return P(None, b, None, None, hd)
        if "mla_" in path:
            # (L, B, Lc, r) — shard the cache length; sharding the latent r
            # makes every layer's score einsum a (B,H,Lc)-sized partial-sum
            # all-reduce (§Perf cell 1/2 finding).
            b = "data" if _div(shape[1], mesh, "data") else None
            if _div(shape[2], mesh, "model"):
                return P(None, b, "model", None)
            r = "model" if _div(shape[3], mesh, "model") else None
            return P(None, b, None, r)
        if "ssm_state" in path:
            # (L[, G], B, H, P, N)
            b = "data" if _div(shape[-4], mesh, "data") else None
            h = "model" if _div(shape[-3], mesh, "model") else None
            return P(*([None] * (nd - 4) + [b, h, None, None]))
        if "ssm_conv" in path:
            b = "data" if _div(shape[-3], mesh, "data") else None
            c = "model" if _div(shape[-1], mesh, "model") else None
            return P(*([None] * (nd - 3) + [b, None, c]))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, state_tree)


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, state, tokens) → (logits, state)."""

    def serve_step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return serve_step


# ---------------------------------------------------------------------------
# shared decode-step scaffolding (compressed ring + KV-tiered steps)
#
# The per-layer loop steps below reproduce decode_step outside the scan: the
# same block functions, the same eager front (embed + learned positions) and
# tail (final norm + unembed), the same single post-loop cache write.  These
# helpers are that shared skeleton — one source of truth for the layer plan
# and the bit-identity claim.
# ---------------------------------------------------------------------------


def _layer_plan(cfg) -> list:
    """[(stack_key, layer_index, block_kind)] in decode order."""
    if cfg.family == "moe":
        fk = cfg.first_k_dense
        return [("dense_layers", i, "dense") for i in range(fk)] + [
            ("moe_layers", i, "moe") for i in range(cfg.n_layers - fk)
        ]
    return [
        ("layers", i, "ssm" if cfg.family == "ssm" else "dense")
        for i in range(cfg.n_layers)
    ]


def _block_kinds(cfg) -> Dict[str, Callable]:
    """One compile per block *kind*, shared by every layer (all layers of a
    stack have identical shapes) — the same block functions decode_step's
    scan body runs, so the math is bit-identical to the fused step."""
    from repro.models import blocks

    return {
        "dense": jax.jit(
            lambda lp, h, c0, c1, pos: blocks.dense_block_decode(
                lp, h, (c0, c1), pos, cfg
            )
        ),
        "moe": jax.jit(
            lambda lp, h, c0, c1, pos: blocks.moe_block_decode(
                lp, h, (c0, c1), pos, cfg
            )
        ),
        "ssm": jax.jit(
            lambda lp, h, st, cv, pos: blocks.mamba_block_decode(
                lp, h, (st, cv), pos, cfg
            )
        ),
    }


def _decode_front(cfg, sp, tokens, pos):
    """Embed + learned positions, mirroring decode_step line for line (kept
    eager: a token-sized gather — bitwise the same ops)."""
    import jax.numpy as jnp

    from repro.models import layers
    from repro.distributed.sharding import lshard

    x = layers.embed(sp["embed"], tokens)
    if cfg.pos_embedding == "learned":
        pe = jax.lax.dynamic_slice_in_dim(
            sp["pos"]["table"], jnp.minimum(pos, cfg.max_position - 1), 1
        )
        x = x + pe[None].astype(x.dtype)
    return lshard(x, "batch", None, None)


def _decode_tail(cfg, sp, x):
    from repro.models import blocks, layers

    x = blocks.norm_apply(cfg, sp["final_norm"], x)
    head = sp["embed"] if cfg.tie_embeddings else sp["lm_head"]
    return layers.unembed(head, x)


def make_kv_tiered_serve_step(model: Model, params, kv_store) -> Callable:
    """Decode step over a :class:`repro.serve.kvcache.KVCacheStore`.

    ``serve_step(tokens) -> logits`` — the cache lives in ``kv_store``
    (hot suffix + compressed cold blocks) instead of the state dict, and
    advances as a side effect of the call.  Logits are **bit-identical**
    to ``model.decode_step`` over the untiered cache: each layer's block
    function receives the store's reassembled full-length caches
    (byte-identical arrays — see ``serve/kvcache.py``), and the new-token
    entries flow through the same masked one-hot write.  Peak cache
    residency drops to hot buffers + compressed payloads + one layer's
    reassembly in flight.

    ssm / hybrid models have no cache-length axis and are rejected.
    """
    import jax.numpy as jnp

    cfg = model.cfg
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            f"{cfg.name}: family {cfg.family!r} has no attention-cache "
            "length axis to tier"
        )
    if not cfg.has_decode:
        raise ValueError(f"{cfg.name}: family {cfg.family!r} has no decode path")
    if kv_store.n_layers != cfg.n_layers:
        raise ValueError(
            f"kv_store holds {kv_store.n_layers} layers, "
            f"model {cfg.name} has {cfg.n_layers}"
        )
    plan = _layer_plan(cfg)
    kinds = _block_kinds(cfg)

    def serve_step(tokens):
        pos = jnp.asarray(kv_store.pos, jnp.int32)
        x = _decode_front(cfg, params, tokens, pos)
        outs0, outs1 = [], []
        for j, (key, i, kind) in enumerate(plan):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params[key])
            c0j, c1j = kv_store.layer_caches(j)
            x, (u0, u1) = kinds[kind](lp, x, c0j, c1j, pos)
            outs0.append(u0)
            outs1.append(u1)
        kv_store.append(jnp.stack(outs0), jnp.stack(outs1))
        return _decode_tail(cfg, params, x)

    serve_step.kv_store = kv_store
    return serve_step


def make_compressed_serve_step(
    model: Model,
    store,
    *,
    ring: int = 2,
    prefetch: bool = True,
    tiles: int = 1,
    kv_store=None,
) -> Callable:
    """Compressed-resident decode step over a ``CompressedParamStore``.

    ``serve_step(state, tokens) -> (logits, new_state)`` — same contract as
    :func:`make_serve_step`'s step, but the weights live in ``store`` as
    ZNN1 payloads and decode **just ahead of compute**: a double-buffered
    prefetch/decode ring (default ``ring=2``) runs layer *i*'s matmuls
    while a single background worker decodes layer *i+1* into the next
    slot, so at most ``ring`` layers of decoded weights are claimed at any
    moment (``store.peak_resident`` asserts this).  Each slot is released
    as soon as its layer's compute is dispatched; XLA frees the decoded
    buffers when the matmuls retire.

    Logits and new state are **bit-identical** to the uncompressed
    ``model.decode_step``: the per-layer block functions are the same code
    decode_step runs (jit-compiled once per block *kind*, reused by every
    layer — identical math to the scan body), the cache slot-write happens
    once after the loop exactly as in decode_step, and the payload decode
    itself is byte-identical across ``backend`` × ``entropy_backend`` ×
    ``threads`` (the knob contract; ``prefetch=False`` gives the
    host-sequential fallback with residency 1).

    hybrid (mamba-group) models are rejected: their shared attention
    params repeat across groups, which does not fit a per-layer ring.

    ``kv_store`` (a :class:`repro.serve.kvcache.KVCacheStore`) composes
    the KV-cache tier with the weight ring: the state dict then carries
    only ``pos`` — caches live in the store as a hot suffix + compressed
    cold blocks, each layer attends over its reassembled full-length
    caches (bit-identical arrays), and the post-loop slot write becomes
    ``kv_store.append``.  Everything compressible at serve time — weights
    at rest AND cold cache — is then ZNN1 payloads.

    ``tiles`` sets the decode *granularity*: with ``tiles > 1`` each layer
    splits into ``tiles`` contiguous tensor-groups
    (``store.decode_layer_tile``) that decode as independent ring jobs —
    a layer's first tensor-group is decoded and resident while its last
    group is still in the decoder, and the next layer's first tiles start
    decoding before the current layer's tail tiles are consumed.  Peak
    decoded residency is accounted per tile slot: at most ``ring × tiles``
    tile slots (each roughly ``1/tiles`` of a layer) instead of ``ring``
    whole layers.  Tiling changes scheduling and residency only — the
    reassembled layer is leaf-for-leaf identical, so logits stay
    bit-identical to ``model.decode_step``.
    """
    import jax.numpy as jnp
    from concurrent.futures import ThreadPoolExecutor

    from repro.models.model import _slot_write

    cfg = model.cfg
    if cfg.family == "hybrid":
        raise NotImplementedError(
            "hybrid (mamba-group) models are not supported by the "
            "compressed serving ring: shared_attn params repeat per group"
        )
    if not cfg.has_decode:
        raise ValueError(f"{cfg.name}: family {cfg.family!r} has no decode path")
    if ring < 1:
        raise ValueError(f"ring must be >= 1, got {ring}")
    if tiles < 1:
        raise ValueError(f"tiles must be >= 1, got {tiles}")
    if kv_store is not None and cfg.family == "ssm":
        raise NotImplementedError(
            f"{cfg.name}: ssm state has no cache-length axis to tier"
        )

    plan = _layer_plan(cfg)
    for key in {k for k, _, _ in plan}:
        want = sum(1 for k, _, _ in plan if k == key)
        if store.n_layers(key) != want:
            raise ValueError(
                f"store stack {key!r} holds {store.n_layers(key)} layers, "
                f"model {cfg.name} needs {want}"
            )

    kinds = _block_kinds(cfg)

    executor = (
        ThreadPoolExecutor(max_workers=1, thread_name_prefix="znn-ring")
        if (prefetch and ring > 1)
        else None
    )
    # Ring depth in decode-job units: jobs are whole layers (tiles == 1) or
    # tile slots (tiles > 1) — either way the ring keeps ring-1 layers'
    # worth of decode ahead of compute.
    n_jobs = len(plan) * tiles
    depth = (ring - 1) * tiles if executor is not None else 0

    def _decode(n: int):
        j, t = divmod(n, tiles)
        key, i, _ = plan[j]
        if tiles == 1:
            return store.decode_layer(key, i)
        return store.decode_layer_tile(key, i, t, tiles)

    def _release(key: str, i: int) -> None:
        if tiles == 1:
            store.release(key, i)
        else:
            for t in range(tiles):
                store.release_tile(key, i, t, tiles)

    def serve_step(state, tokens):
        pos = state["pos"]
        x = _decode_front(cfg, store.static, tokens, pos)
        new_state = dict(state)

        inflight: list = []
        nxt = 0

        def pump() -> None:
            # Keep up to ring-1 layers' worth of decode jobs ahead of
            # compute; the worker fills the next slot while the current
            # layer's matmuls run.
            nonlocal nxt
            while (
                executor is not None
                and nxt < n_jobs
                and len(inflight) < depth
            ):
                inflight.append(executor.submit(_decode, nxt))
                nxt += 1

        def next_job(n: int):
            nonlocal nxt
            if inflight:
                out = inflight.pop(0).result()
            else:
                out = _decode(n)
                nxt = n + 1
            pump()
            return out

        def layer_params(j: int):
            if tiles == 1:
                return next_job(j)
            # Collect the layer's tiles in order; pump() between tiles so
            # later layers' tiles enter the decoder as slots free up — the
            # tile-granular overlap.
            arrays: Dict[int, Any] = {}
            for t in range(tiles):
                arrays.update(next_job(j * tiles + t))
            key, i, _ = plan[j]
            return store.layer_unflatten(
                key, i, [arrays[k] for k in sorted(arrays)]
            )

        pump()
        if cfg.family == "ssm":
            outs_s, outs_c = [], []
            for j, (key, i, kind) in enumerate(plan):
                lp = layer_params(j)
                x, (st, cv) = kinds[kind](
                    lp, x, state["ssm_state"][j], state["ssm_conv"][j], pos
                )
                _release(key, i)
                outs_s.append(st)
                outs_c.append(cv)
            new_state["ssm_state"] = jnp.stack(outs_s)
            new_state["ssm_conv"] = jnp.stack(outs_c)
        elif kv_store is not None:
            outs0, outs1 = [], []
            for j, (key, i, kind) in enumerate(plan):
                lp = layer_params(j)
                c0j, c1j = kv_store.layer_caches(j)
                x, (u0, u1) = kinds[kind](lp, x, c0j, c1j, pos)
                _release(key, i)
                outs0.append(u0)
                outs1.append(u1)
            # single post-loop cache write, exactly as decode_step — into
            # the tiered store's hot buffer instead of the state dict
            kv_store.append(jnp.stack(outs0), jnp.stack(outs1))
        else:
            c0, c1 = (
                (state["mla_ckv"], state["mla_kr"])
                if cfg.mla
                else (state["kv_k"], state["kv_v"])
            )
            Lc = c0.shape[2]
            slot = (pos % Lc).astype(jnp.int32)
            outs0, outs1 = [], []
            for j, (key, i, kind) in enumerate(plan):
                lp = layer_params(j)
                x, (u0, u1) = kinds[kind](lp, x, c0[j], c1[j], pos)
                _release(key, i)
                outs0.append(u0)
                outs1.append(u1)
            # single slot write for all layers, exactly as decode_step
            n0, n1 = jnp.stack(outs0), jnp.stack(outs1)
            if cfg.mla:
                new_state["mla_ckv"] = _slot_write(c0, n0, slot)
                new_state["mla_kr"] = _slot_write(c1, n1, slot)
            else:
                new_state["kv_k"] = _slot_write(c0, n0, slot)
                new_state["kv_v"] = _slot_write(c1, n1, slot)

        logits = _decode_tail(cfg, store.static, x)
        new_state["pos"] = pos + 1
        return logits, new_state

    serve_step.store = store
    serve_step.ring = ring
    serve_step.tiles = tiles
    serve_step.kv_store = kv_store
    return serve_step


def make_prefill(model: Model) -> Callable:
    """prefill(params, batch) → logits for the full prompt (chunked attn)."""

    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill


def greedy_generate(
    model: Model, params, prompt, steps: int
) -> Tuple[Any, Any]:
    """Small-scale generation loop for examples/tests (feeds tokens one by
    one through the decode step; caches sized for prompt+steps).

    ``steps == 0`` is valid (prompt is fed through the cache, no tokens are
    sampled; returns an empty ``(B, 0)`` int32 array).  An empty prompt or
    negative ``steps`` raises ``ValueError`` — there is no logits history
    to sample the first token from.
    """
    import jax.numpy as jnp

    if getattr(prompt, "ndim", None) != 2:
        raise ValueError(
            f"prompt must be a (B, S) token array, got shape "
            f"{getattr(prompt, 'shape', None)}"
        )
    B, S = prompt.shape
    if S == 0:
        raise ValueError(
            "prompt must contain at least one token (S == 0): the first "
            "sampled token is argmax over the prompt's last logits"
        )
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    state = model.init_decode_state(B, S + steps, start_pos=0)
    step = jax.jit(model.decode_step)
    logits = None
    for t in range(S):
        logits, state = step(params, state, prompt[:, t : t + 1])
    if steps == 0:
        return jnp.zeros((B, 0), dtype=jnp.int32), state
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1), state
