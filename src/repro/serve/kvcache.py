"""KV-cache tiering: cold cache blocks live as ZNN1 payloads in HBM.

Long-context decode is cache-bound: a (L, B, Lc, G, hd) bf16 KV cache is
GiBs per layer stack, yet each step's attention touches every position
while only the most recent ones were produced recently.  Cache entries are
activations-at-rest — exactly the exponent-skewed bf16 payloads the paper's
byte-grouping pipeline compresses well — so the cold majority of the cache
can live compressed and decode on re-attention, the serving-side analogue
of the compressed-at-rest weight store (``serve/compressed.py``).

``KVCacheStore`` tiers a model's stacked attention caches (GQA ``kv_k`` /
``kv_v`` and MLA ``mla_ckv`` / ``mla_kr``) by position:

* the newest ``hot_window`` positions stay in a small uncompressed **hot
  buffer** (a stacked suffix, one per cache key);
* once a ``block_len``-aligned block falls entirely behind the hot window
  it is **evicted**: each (key, layer) block compresses to its own ZNN1
  payload (``zipnn.compress_array``), so re-attention for layer *j*
  decodes only layer *j*'s blocks;
* :meth:`layer_caches` reassembles one layer's full-length caches —
  decoded cold blocks + live hot suffix + zero tail — bit-identical to the
  array the untiered ``decode_step`` would have passed to the block
  function (the codec is lossless and unwritten positions are zeros by
  construction, matching ``init_kv_cache``/``init_mla_cache``).

Bit-identity contract: a greedy decode through a tiered step produces
logits (and therefore tokens) byte-identical to ``model.decode_step``,
because every block function receives byte-identical inputs.  Residency
contract: live hot positions never exceed ``hot_window + block_len`` (the
partially-filled block awaiting eviction), and decoded cold blocks are in
flight only for the single layer currently attending —
``peak_hot_positions`` / ``peak_inflight_blocks`` assert both.

There is no ring wraparound: tiering assumes ``pos < cache length`` (a
wrapped slot would overwrite positions already evicted).  SSM / hybrid
states have no cache-length axis and are rejected.

Codec knobs arrive as one ``CodecOptions`` bag (``options=`` — this is a
new surface, so there are no legacy loose kwargs to shim).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import zipnn
from repro.core.options import CodecOptions, DEFAULT_OPTIONS

Array = Any

# Stacked attention-cache keys across the model zoo, in block-call order:
# (c0, c1) = (kv_k, kv_v) for GQA, (mla_ckv, mla_kr) for MLA.
GQA_KEYS: Tuple[str, str] = ("kv_k", "kv_v")
MLA_KEYS: Tuple[str, str] = ("mla_ckv", "mla_kr")


class KVCacheStore:
    """Block-granular compressed tier over stacked attention caches."""

    def __init__(
        self,
        state: Dict[str, Any],
        *,
        hot_window: int = 256,
        block_len: int = 64,
        config: Optional[zipnn.ZipNNConfig] = None,
        options: Optional[CodecOptions] = None,
    ) -> None:
        if block_len < 1:
            raise ValueError(f"block_len must be >= 1, got {block_len}")
        if hot_window < 1:
            raise ValueError(f"hot_window must be >= 1, got {hot_window}")
        if "ssm_state" in state:
            raise NotImplementedError(
                "ssm/hybrid decode state has no cache-length axis to tier"
            )
        if all(k in state for k in MLA_KEYS):
            keys = MLA_KEYS
        elif all(k in state for k in GQA_KEYS):
            keys = GQA_KEYS
        else:
            raise ValueError(
                "state holds no stacked attention caches "
                f"(need {GQA_KEYS} or {MLA_KEYS})"
            )
        if int(state["pos"]) != 0:
            raise ValueError(
                "tiering starts from an empty cache: build the state with "
                "start_pos=0 and feed the prompt through the tiered step"
            )
        self._config = zipnn.DEFAULT if config is None else config
        self._options = DEFAULT_OPTIONS if options is None else options
        self.keys = keys
        self.hot_window = hot_window
        self.block_len = block_len
        ref = state[keys[0]]
        self.n_layers = int(ref.shape[0])
        self.length = int(ref.shape[2])
        # Hot capacity: hot_window live positions plus one block still
        # filling — the moment a full block ages past the window it leaves.
        cap = min(hot_window + block_len, self.length)
        self.hot: Dict[str, Array] = {
            k: jnp.zeros(
                state[k].shape[:2] + (cap,) + state[k].shape[3:],
                state[k].dtype,
            )
            for k in keys
        }
        # cold[key][layer] = ZNN1 payloads, one per evicted block, in
        # position order: block b covers [b*block_len, (b+1)*block_len).
        self._cold: Dict[str, List[List[zipnn.CompressedTensor]]] = {
            k: [[] for _ in range(self.n_layers)] for k in keys
        }
        self.pos = 0
        self.cold_len = 0
        self.peak_hot_positions = 0
        self.peak_inflight_blocks = 0

    # -- read path ---------------------------------------------------------

    def layer_caches(self, layer: int) -> Tuple[Array, ...]:
        """Layer ``layer``'s full-length caches, ``(c0, c1)``-ordered.

        Byte-identical to the slices ``decode_step`` would read from the
        untiered stacked cache: decoded cold blocks (lossless), then the
        live hot suffix, then the zero tail.  Decoded blocks are in flight
        only for the duration of this layer's reassembly — the in-flight
        residency term.
        """
        return tuple(self._assemble(k, layer) for k in self.keys)

    def _assemble(self, key: str, layer: int) -> Array:
        hot = self.hot[key][layer]                      # (B, cap, ...)
        blocks = self._cold[key][layer]
        if blocks:
            self.peak_inflight_blocks = max(
                self.peak_inflight_blocks, len(blocks)
            )
        parts = [
            jnp.asarray(
                zipnn.decompress_array(
                    ct, self._config,
                    options=self._options.replace(device_resident=True),
                )
            )
            for ct in blocks
        ]
        take = min(hot.shape[1], self.length - self.cold_len)
        parts.append(hot[:, :take])
        pad = self.length - self.cold_len - take
        if pad:
            parts.append(
                jnp.zeros(hot.shape[:1] + (pad,) + hot.shape[2:], hot.dtype)
            )
        return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]

    # -- write path --------------------------------------------------------

    def append(self, *news: Array) -> None:
        """Write one decoded token's stacked new-cache entries.

        ``news`` aligns with :attr:`keys` — each ``(L, B, 1, ...)``, the
        stacked per-layer returns of the block functions, exactly what
        ``decode_step`` hands to its single post-loop slot write.  The
        write is the same masked one-hot select (at the hot-local slot),
        then blocks aged fully past the hot window evict.
        """
        if self.pos >= self.length:
            raise ValueError(
                f"tiered cache is full at pos={self.pos} (length "
                f"{self.length}): no ring wraparound over evicted blocks"
            )
        slot = self.pos - self.cold_len
        for k, new in zip(self.keys, news):
            hot = self.hot[k]
            idx = jax.lax.broadcasted_iota(jnp.int32, hot.shape, 2)
            self.hot[k] = jnp.where(idx == slot, new.astype(hot.dtype), hot)
        self.pos += 1
        self.peak_hot_positions = max(
            self.peak_hot_positions, self.pos - self.cold_len
        )
        while self.pos - self.cold_len >= self.hot_window + self.block_len:
            self._evict_block()

    def _evict_block(self) -> None:
        bl = self.block_len
        for k in self.keys:
            hot = self.hot[k]
            block = np.asarray(hot[:, :, :bl])          # (L, B, bl, ...)
            for j in range(self.n_layers):
                self._cold[k][j].append(
                    zipnn.compress_array(
                        np.ascontiguousarray(block[j]),
                        self._config, options=self._options,
                    )
                )
            zero = jnp.zeros(hot.shape[:2] + (bl,) + hot.shape[3:], hot.dtype)
            self.hot[k] = jnp.concatenate([hot[:, :, bl:], zero], axis=2)
        self.cold_len += bl

    # -- residency accounting ---------------------------------------------

    @property
    def n_cold_blocks(self) -> int:
        """Evicted blocks per (key, layer) — all chains have equal length."""
        return self.cold_len // self.block_len

    @property
    def hot_bytes(self) -> int:
        """Uncompressed bytes held resident in the hot buffers."""
        return sum(
            int(np.prod(h.shape)) * h.dtype.itemsize for h in self.hot.values()
        )

    @property
    def cold_comp_bytes(self) -> int:
        """ZNN1 payload bytes held at rest for evicted blocks."""
        return sum(
            len(ct.blob)
            for per_layer in self._cold.values()
            for chain in per_layer
            for ct in chain
        )

    @property
    def cold_raw_bytes(self) -> int:
        """What the evicted blocks would occupy uncompressed."""
        from repro.core import bitlayout

        return sum(
            int(np.prod(ct.shape)) * bitlayout.layout_for(ct.dtype).itemsize
            for per_layer in self._cold.values()
            for chain in per_layer
            for ct in chain
        )

    @property
    def full_cache_bytes(self) -> int:
        """The untiered stacked caches' footprint (the baseline)."""
        per_pos = sum(
            int(np.prod(h.shape[:2]) * np.prod(h.shape[3:])) * h.dtype.itemsize
            for h in self.hot.values()
        )
        return per_pos * self.length

    def resident_bytes(self, inflight_layers: int = 1) -> int:
        """Tiered steady-state footprint: hot buffers + compressed cold
        payloads + ``inflight_layers`` reassembled full-length layers."""
        per_layer = self.full_cache_bytes // max(self.n_layers, 1)
        return (
            self.hot_bytes
            + self.cold_comp_bytes
            + inflight_layers * per_layer
        )
