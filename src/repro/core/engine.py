"""Parallel, streaming compression engine (paper §5.2 / Table 3).

The paper's headline speed numbers come from compressing and decompressing
independent 256 KiB chunks **in parallel across threads**; the reference
ZipNN implementation exposes ``max_threads`` / ``is_streaming`` /
``streaming_chunk_kb`` for exactly this.  This module is our equivalent:

**Chunk scheduler** — a process-wide cache of ``ThreadPoolExecutor`` pools
(:func:`get_pool`) that the codec fans (plane, chunk) encode/decode work
items across.  The entropy backends (zlib / ``hufflib``) release the GIL,
so this is real parallelism on multi-core hosts.  Work items are contiguous
chunk-id ranges concatenated in submission order, so the pool path's output
is **byte-identical** to the serial path's for any thread count — the
``threads=`` knob changes wall-clock only, never bytes.

**Streaming file API** — :func:`compress_file` / :func:`decompress_file`
and the underlying :class:`CompressWriter` / :class:`DecompressReader`
process a configurable window (default 64 MiB) at a time and append framed
``ZNN1`` segments to a ``ZNS1`` container, so a multi-GiB checkpoint
round-trips with peak extra memory **O(window)**, never O(file):

    magic    4s   b'ZNS1'
    version  u16
    flags    u16  (reserved)
    dtype    16s  dtype name (padded)
    window   u64  window bytes used at write time
    -- frames, repeated --
    kind     u8   1 = data frame, 0 = end-of-stream
    raw_len  u64  uncompressed bytes in this frame (total stream len on end)
    comp_len u64  compressed bytes following (0 on end)
    crc      u32  crc32 of the compressed frame body
    body     comp_len bytes — one self-contained ZNN1 stream

Every frame is an independent ``ZNN1`` container (same per-chunk work-item
implementation as the in-memory path), so frames decompress independently
and the unaligned remainder of the stream rides the last frame's ``TAIL``
mechanism.  Threads apply *within* each frame.
"""

from __future__ import annotations

import os
import struct
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import IO, Iterator, Optional, Tuple, Union

__all__ = [
    "DEFAULT_WINDOW",
    "resolve_threads",
    "get_pool",
    "CompressWriter",
    "DecompressReader",
    "compress_file",
    "decompress_file",
]

DEFAULT_WINDOW = 64 << 20          # 64 MiB streaming window

_STREAM_MAGIC = b"ZNS1"
_SHDR = struct.Struct("<4sHH16sQ")          # magic, version, flags, dtype, window
_FRAME = struct.Struct("<BQQI")             # kind, raw_len, comp_len, crc
_KIND_DATA = 1
_KIND_END = 0


# ---------------------------------------------------------------------------
# chunk scheduler: shared thread pools
# ---------------------------------------------------------------------------

def resolve_threads(threads: Optional[int]) -> int:
    """Normalize the ``threads`` knob: 0/1/None → serial, -1 → all cores.

    Requests beyond the core count are capped: the work items are CPU-bound
    (zlib/numpy), so extra workers only add context-switch and GIL churn.
    """
    if threads is None or threads == 0 or threads == 1:
        return 1
    cores = os.cpu_count() or 1
    if threads < 0:
        return cores
    return min(threads, cores)


_pools: dict = {}
_pools_lock = threading.Lock()


def get_pool(threads: Optional[int]) -> Optional[ThreadPoolExecutor]:
    """Shared executor for ``threads`` workers, or None for the serial path.

    Pools are cached per worker count for the life of the process: codec
    calls are frequent (every tensor of a pytree) and executor start-up is
    not free.  Idle pooled threads cost nothing while blocked on the queue.
    """
    n = resolve_threads(threads)
    if n <= 1:
        return None
    with _pools_lock:
        pool = _pools.get(n)
        if pool is None:
            pool = ThreadPoolExecutor(
                max_workers=n, thread_name_prefix=f"zipnn-{n}"
            )
            _pools[n] = pool
        return pool


# ---------------------------------------------------------------------------
# streaming file API
# ---------------------------------------------------------------------------

PathOrFile = Union[str, os.PathLike, IO[bytes]]


def _open(fp: PathOrFile, mode: str) -> Tuple[IO[bytes], bool]:
    if isinstance(fp, (str, os.PathLike)):
        return open(fp, mode), True
    return fp, False


class CompressWriter:
    """Bounded-memory streaming compressor (file-like ``write`` interface).

    Buffers raw bytes until a full window is available, then compresses the
    window through the shared codec implementation and appends one framed
    segment.  Peak memory is a small multiple of the window (the raw window,
    its byte-group planes, and the compressed payloads — measured ~5×window
    + interpreter baseline), independent of stream length; the raw stream is
    never materialized.  Windows are aligned down to the dtype itemsize so
    only the final frame can carry an unaligned ``TAIL`` remainder.
    """

    def __init__(
        self,
        fp: PathOrFile,
        dtype_name: str,
        config=None,
        *,
        window_bytes: int = DEFAULT_WINDOW,
        threads: Optional[int] = None,
    ):
        from . import bitlayout, zipnn   # lazy: zipnn imports this module

        self._config = zipnn.DEFAULT if config is None else config
        self._threads = self._config.threads if threads is None else threads
        self._dtype_name = dtype_name
        itemsize = bitlayout.layout_for(dtype_name).itemsize
        self._window = max(window_bytes - window_bytes % itemsize, itemsize)
        self._buf = bytearray()
        self._fp, self._own = _open(fp, "wb")
        self._closed = False
        self.raw_bytes = 0
        self.comp_bytes = 0
        hdr = _SHDR.pack(
            _STREAM_MAGIC,
            1,
            0,
            dtype_name.encode().ljust(16, b"\x00"),
            self._window,
        )
        self._fp.write(hdr)
        self.comp_bytes += len(hdr)

    def write(self, data: bytes) -> int:
        self._buf += data
        while len(self._buf) >= self._window:
            self._emit(bytes(self._buf[: self._window]))
            del self._buf[: self._window]
        return len(data)

    def _emit(self, raw: bytes) -> None:
        from . import zipnn

        blob = zipnn.compress_bytes(
            raw, self._dtype_name, self._config, threads=self._threads
        )
        self._fp.write(
            _FRAME.pack(_KIND_DATA, len(raw), len(blob), zlib.crc32(blob))
        )
        self._fp.write(blob)
        self.raw_bytes += len(raw)
        self.comp_bytes += _FRAME.size + len(blob)

    def close(self) -> None:
        if self._closed:
            return
        if self._buf:
            self._emit(bytes(self._buf))
            self._buf.clear()
        self._fp.write(_FRAME.pack(_KIND_END, self.raw_bytes, 0, 0))
        self.comp_bytes += _FRAME.size
        self._fp.flush()
        if self._own:
            self._fp.close()
        self._closed = True

    def abort(self) -> None:
        """Close WITHOUT finalizing: no buffered flush, no end frame.

        The resulting file fails DecompressReader's end-frame check, so a
        consumer can never mistake an interrupted write for a complete
        stream."""
        if self._closed:
            return
        self._buf.clear()
        if self._own:
            self._fp.close()
        self._closed = True

    def __enter__(self) -> "CompressWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()


class DecompressReader:
    """Streaming decompressor over a ``ZNS1`` container.

    Iterating :meth:`frames` (or calling :meth:`read`) holds one
    decompressed window at a time — O(window) memory for any stream size.
    Frame CRCs are verified before decode; a truncated stream (no end frame)
    raises ``IOError``.
    """

    def __init__(
        self,
        fp: PathOrFile,
        config=None,
        *,
        threads: Optional[int] = None,
    ):
        from . import zipnn

        self._config = zipnn.DEFAULT if config is None else config
        self._threads = self._config.threads if threads is None else threads
        self._fp, self._own = _open(fp, "rb")
        hdr = self._fp.read(_SHDR.size)
        if len(hdr) < _SHDR.size:
            raise ValueError("truncated ZNS1 header")
        magic, version, _flags, dtype_b, window = _SHDR.unpack(hdr)
        if magic != _STREAM_MAGIC:
            raise ValueError("not a ZNS1 stream")
        if version != 1:
            raise ValueError(f"unsupported ZNS version {version}")
        self.dtype_name = dtype_b.rstrip(b"\x00").decode()
        self.window = window
        self._pending = b""
        self._frames = self._frame_iter()
        self._exhausted = False

    def _frame_iter(self) -> Iterator[bytes]:
        """Single shared generator over the file's frames (created once —
        ``read`` and ``frames`` both draw from it, so mixing them never
        skips data)."""
        from . import zipnn

        total = 0
        while True:
            rec = self._fp.read(_FRAME.size)
            if len(rec) < _FRAME.size:
                raise IOError("truncated ZNS1 stream (missing end frame)")
            kind, raw_len, comp_len, crc = _FRAME.unpack(rec)
            if kind == _KIND_END:
                # the end frame records the total raw length: a stream with
                # whole frames missing must not parse as complete
                if total != raw_len:
                    raise IOError(
                        f"ZNS1 stream yielded {total} bytes, end frame "
                        f"declares {raw_len}"
                    )
                return
            blob = self._fp.read(comp_len)
            if len(blob) < comp_len:
                raise IOError("truncated ZNS1 frame body")
            if zlib.crc32(blob) != crc:
                raise IOError("ZNS1 frame CRC mismatch")
            raw = zipnn.decompress_bytes(blob, self._config, threads=self._threads)
            if len(raw) != raw_len:
                raise IOError(
                    f"frame decoded to {len(raw)} bytes, expected {raw_len}"
                )
            total += raw_len
            yield raw

    def frames(self) -> Iterator[bytes]:
        """Yield the remaining decompressed frame bodies in stream order.

        Bytes already buffered by a prior partial :meth:`read` come first,
        so the two access styles compose without data loss.
        """
        if self._pending:
            pending, self._pending = self._pending, b""
            yield pending
        while True:
            try:
                yield next(self._frames)
            except StopIteration:
                self._exhausted = True
                return

    def read(self, n: int = -1) -> bytes:
        """File-like read; ``n < 0`` drains the remaining stream."""
        out = bytearray(self._pending)
        self._pending = b""
        while (n < 0 or len(out) < n) and not self._exhausted:
            try:
                out += next(self._frames)
            except StopIteration:
                self._exhausted = True
        if n >= 0 and len(out) > n:
            self._pending = bytes(out[n:])
            del out[n:]
        return bytes(out)

    def close(self) -> None:
        if self._own:
            self._fp.close()

    def __enter__(self) -> "DecompressReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def compress_file(
    src: PathOrFile,
    dst: PathOrFile,
    dtype_name: str,
    config=None,
    *,
    window_bytes: int = DEFAULT_WINDOW,
    threads: Optional[int] = None,
) -> Tuple[int, int]:
    """Stream-compress ``src`` into a ``ZNS1`` container at ``dst``.

    Reads/compresses/writes one window at a time — peak extra memory is
    O(window), so checkpoints larger than RAM round-trip.  Returns
    ``(raw_bytes, comp_bytes)``.
    """
    fin, own_in = _open(src, "rb")
    try:
        with CompressWriter(
            dst, dtype_name, config, window_bytes=window_bytes, threads=threads
        ) as w:
            while True:
                data = fin.read(w._window)
                if not data:
                    break
                w.write(data)
        return w.raw_bytes, w.comp_bytes
    finally:
        if own_in:
            fin.close()


def decompress_file(
    src: PathOrFile,
    dst: PathOrFile,
    config=None,
    *,
    threads: Optional[int] = None,
) -> int:
    """Stream-decompress a ``ZNS1`` container; returns raw bytes written."""
    fout, own_out = _open(dst, "wb")
    try:
        with DecompressReader(src, config, threads=threads) as r:
            total = 0
            for raw in r.frames():
                fout.write(raw)
                total += len(raw)
        fout.flush()
        return total
    finally:
        if own_out:
            fout.close()
