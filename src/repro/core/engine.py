"""Parallel, streaming compression engine (paper §5.2 / Table 3).

The paper's headline speed numbers come from compressing and decompressing
independent 256 KiB chunks **in parallel across threads**; the reference
ZipNN implementation exposes ``max_threads`` / ``is_streaming`` /
``streaming_chunk_kb`` for exactly this.  This module is our equivalent:

**Chunk scheduler** — a process-wide cache of ``ThreadPoolExecutor`` pools
(:func:`get_pool`) that the codec fans (plane, chunk) encode/decode work
items across.  The entropy backends (zlib / ``hufflib``) release the GIL,
so this is real parallelism on multi-core hosts.  Work items are contiguous
chunk-id ranges concatenated in submission order, so the pool path's output
is **byte-identical** to the serial path's for any thread count — the
``threads=`` knob changes wall-clock only, never bytes.

**Streaming file API** — :func:`compress_file` / :func:`decompress_file`
and the underlying :class:`CompressWriter` / :class:`DecompressReader`
process a configurable window (default 64 MiB) at a time and append framed
``ZNN1`` segments to a ``ZNS1`` container, so a multi-GiB checkpoint
round-trips with peak extra memory **O(window)**, never O(file):

    magic    4s   b'ZNS1'
    version  u16
    flags    u16  (reserved)
    dtype    16s  dtype name (padded)
    window   u64  window bytes used at write time
    -- frames, repeated --
    kind     u8   1 = data frame, 0 = end-of-stream
    raw_len  u64  uncompressed bytes in this frame (total stream len on end)
    comp_len u64  compressed bytes following (0 on end)
    crc      u32  crc32 of the compressed frame body
    body     comp_len bytes — one self-contained ZNN1 stream

Every frame is an independent ``ZNN1`` container (same per-chunk work-item
implementation as the in-memory path), so frames decompress independently
and the unaligned remainder of the stream rides the last frame's ``TAIL``
mechanism.  Threads apply *within* each frame.

**Frame pipelining** — with ``threads > 1`` the writer double-buffers:
window k compresses on a dedicated pipeline thread (fanning its (plane,
chunk) work items across the engine pool) while the caller reads/buffers
window k+1, and the reader symmetrically decodes frame k while frame k+1's
bytes are read and CRC-checked.  Frames are still emitted/consumed strictly
in order, so pipelining never changes the file bytes or the decoded stream.

**Backend selection** — the codec's plane-producer front half (rotate +
byte-group + probe) has two interchangeable backends, chosen by the
``backend=`` knob threaded through :class:`repro.core.zipnn.ZipNNConfig`
(``plane_backend``) and every compression entry point:

* ``"host"`` (default) — numpy byte-split + ``np.bincount`` probe, fanned
  across this module's thread pools;
* ``"device"`` — one fused Pallas dispatch (XOR-delta → rotate+byte-group →
  per-chunk histograms, see :mod:`repro.core.device_plane` /
  :mod:`repro.kernels.fused_plane`) followed by a single device→host
  transfer of planed uint8 buffers + probe stats; the entropy work items
  then run with the probe pass already done.  Unsupported layout/chunk
  combinations silently fall back to the host path;
* ``"auto"`` — device only for accelerator-resident ``jax.Array`` leaves.

``backend="device"`` also routes the **entropy stage** through the fused
Huffman bit-pack dispatch (:mod:`repro.core.device_entropy`) when the
codec's canonical ``huffman`` coder is selected; the ``entropy_backend=``
knob on :class:`CompressWriter` / :func:`compress_file` (and every
``zipnn`` compression entry point) overrides just that stage for mixed
mode.

The same knobs cover the decode work items: :class:`DecompressReader` /
:func:`decompress_file` pass ``backend=`` and ``entropy_backend=`` through
to ``zipnn.decompress_bytes``.  The back half (un-byte-group + inverse
rotate) runs either as pooled numpy scatters or as one fused Pallas
dispatch per frame (:mod:`repro.core.device_unplane`); the entropy decode
runs either as pooled host chunk work items or through the device Huffman
decoder kernel (:mod:`repro.core.device_entropy`), in which case only the
frame's compressed payload crosses host→device.  Both compose with the
reader's frame prefetch: frame k's planes can be consuming on device while
frame k+1's bytes are read and CRC-checked.

Blobs are byte-identical for every backend × thread-count combination —
both knobs change wall-clock only, never bytes.
"""

from __future__ import annotations

import os
import struct
import threading
import zlib
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import IO, Iterator, Optional, Tuple, Union

from .options import CodecOptions, resolve_options

__all__ = [
    "DEFAULT_WINDOW",
    "resolve_threads",
    "get_pool",
    "CompressWriter",
    "DecompressReader",
    "compress_file",
    "decompress_file",
    "frame_records",
]

DEFAULT_WINDOW = 64 << 20          # 64 MiB streaming window

_STREAM_MAGIC = b"ZNS1"
_SHDR = struct.Struct("<4sHH16sQ")          # magic, version, flags, dtype, window
_FRAME = struct.Struct("<BQQI")             # kind, raw_len, comp_len, crc
_KIND_DATA = 1
_KIND_END = 0

# Frame bodies are read through _read_exact in pieces of at most this many
# bytes: a corrupt u64 comp_len field must never drive a single giant
# allocation before the truncation check can reject it.
_READ_CHUNK = 8 << 20


def _read_exact(fp: IO[bytes], n: int) -> bytes:
    """Read up to ``n`` bytes, allocating at most ``_READ_CHUNK`` at a time.

    Returns fewer than ``n`` bytes only at EOF, like a single ``read(n)``
    on a regular file — callers keep their ``len(...) < n`` truncation
    checks, but a flipped length byte now fails on the first short piece
    instead of after a 2^64-sized buffer request.
    """
    if n <= _READ_CHUNK:
        return fp.read(n)
    parts = []
    remaining = n
    while remaining > 0:
        piece = fp.read(min(remaining, _READ_CHUNK))
        if not piece:
            break
        parts.append(piece)
        remaining -= len(piece)
    return b"".join(parts)


# ---------------------------------------------------------------------------
# chunk scheduler: shared thread pools
# ---------------------------------------------------------------------------

def resolve_threads(threads: Optional[int]) -> int:
    """Normalize the ``threads`` knob: 0/1/None → serial, -1 → all cores.

    Requests beyond the core count are capped: the work items are CPU-bound
    (zlib/numpy), so extra workers only add context-switch and GIL churn.
    """
    if threads is None or threads == 0 or threads == 1:
        return 1
    cores = os.cpu_count() or 1
    if threads < 0:
        return cores
    return min(threads, cores)


_pools: dict = {}
_pools_lock = threading.Lock()


def get_pool(threads: Optional[int]) -> Optional[ThreadPoolExecutor]:
    """Shared executor for ``threads`` workers, or None for the serial path.

    Pools are cached per worker count for the life of the process: codec
    calls are frequent (every tensor of a pytree) and executor start-up is
    not free.  Idle pooled threads cost nothing while blocked on the queue.
    """
    n = resolve_threads(threads)
    if n <= 1:
        return None
    with _pools_lock:
        pool = _pools.get(n)
        if pool is None:
            pool = ThreadPoolExecutor(
                max_workers=n, thread_name_prefix=f"zipnn-{n}"
            )
            _pools[n] = pool
        return pool


# ---------------------------------------------------------------------------
# streaming file API
# ---------------------------------------------------------------------------

PathOrFile = Union[str, os.PathLike, IO[bytes]]


def _open(fp: PathOrFile, mode: str) -> Tuple[IO[bytes], bool]:
    if isinstance(fp, (str, os.PathLike)):
        return open(fp, mode), True
    return fp, False


class CompressWriter:
    """Bounded-memory streaming compressor (file-like ``write`` interface).

    Buffers raw bytes until a full window is available, then compresses the
    window through the shared codec implementation and appends one framed
    segment.  Peak memory is a small multiple of the window (the raw window,
    its byte-group planes, and the compressed payloads — measured ~5×window
    + interpreter baseline), independent of stream length; the raw stream is
    never materialized.  Windows are aligned down to the dtype itemsize so
    only the final frame can carry an unaligned ``TAIL`` remainder.

    With ``threads > 1`` the writer is **frame-pipelined**: up to
    ``pipeline_depth`` windows compress concurrently on dedicated pipeline
    threads (their (plane, chunk) work items still fan across the engine
    pool) while the caller reads and buffers the next window.  Frames are
    written strictly in submission order, and the compression itself is
    deterministic — pipelined output files are byte-identical to serial
    ones.  Peak extra memory grows by ``pipeline_depth`` in-flight windows.
    """

    def __init__(
        self,
        fp: PathOrFile,
        dtype_name: str,
        config=None,
        *,
        window_bytes: int = DEFAULT_WINDOW,
        threads: Optional[int] = None,
        backend: Optional[str] = None,
        entropy_backend: Optional[str] = None,
        options: Optional[CodecOptions] = None,
        pipeline_depth: int = 2,
    ):
        from . import bitlayout, zipnn   # lazy: zipnn imports this module

        opts = resolve_options(
            options, threads=threads, backend=backend,
            entropy_backend=entropy_backend,
        )
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}"
            )
        self._config = zipnn.DEFAULT if config is None else config
        self._threads = self._config.threads if opts.threads is None else opts.threads
        self._backend = opts.backend
        self._entropy_backend = opts.entropy_backend
        self._dtype_name = dtype_name
        # Windows align to the layout's plane-split granule (== itemsize for
        # whole-byte layouts, 2 for the sub-byte fp8 nibble planes) so only
        # the final frame can carry an unaligned TAIL remainder.
        align = bitlayout.layout_for(dtype_name).align
        self._window = max(window_bytes - window_bytes % align, align)
        self._buf = bytearray()
        self._fp, self._own = _open(fp, "wb")
        self._closed = False
        # Frame pipeline: up to pipeline_depth windows compress concurrently
        # on these dedicated threads — NOT on the engine pool, so a writer
        # can never deadlock the pool that its own chunk work items need.
        # Frames are written strictly in submission order (the deque is the
        # ordering barrier), so the file bytes cannot depend on the depth.
        self._depth = pipeline_depth
        self._pipe: Optional[ThreadPoolExecutor] = None
        self._pending: deque = deque()  # (raw_len, Future[bytes]) in flight
        self.raw_bytes = 0
        self.comp_bytes = 0
        hdr = _SHDR.pack(
            _STREAM_MAGIC,
            1,
            0,
            dtype_name.encode().ljust(16, b"\x00"),
            self._window,
        )
        self._fp.write(hdr)
        self.comp_bytes += len(hdr)

    def write(self, data: bytes) -> int:
        self._buf += data
        while len(self._buf) >= self._window:
            self._submit(bytes(self._buf[: self._window]))
            del self._buf[: self._window]
        return len(data)

    def _compress(self, raw: bytes) -> bytes:
        from . import zipnn

        return zipnn.compress_bytes(
            raw, self._dtype_name, self._config,
            options=CodecOptions(
                threads=self._threads, backend=self._backend,
                entropy_backend=self._entropy_backend,
            ),
        )

    def _submit(self, raw: bytes) -> None:
        """Compress one window — pipelined when the engine is threaded."""
        if resolve_threads(self._threads) <= 1:
            self._write_frame(len(raw), self._compress(raw))
            return
        while len(self._pending) >= self._depth:
            raw_len, fut = self._pending.popleft()
            self._write_frame(raw_len, fut.result())
        if self._pipe is None:
            self._pipe = ThreadPoolExecutor(
                max_workers=self._depth, thread_name_prefix="zipnn-frame-pipe"
            )
        self._pending.append((len(raw), self._pipe.submit(self._compress, raw)))

    def _drain(self) -> None:
        """Wait for every in-flight frame and write them in submission
        order (the ordering barrier)."""
        while self._pending:
            raw_len, fut = self._pending.popleft()
            self._write_frame(raw_len, fut.result())

    def _write_frame(self, raw_len: int, blob: bytes) -> None:
        self._fp.write(
            _FRAME.pack(_KIND_DATA, raw_len, len(blob), zlib.crc32(blob))
        )
        self._fp.write(blob)
        self.raw_bytes += raw_len
        self.comp_bytes += _FRAME.size + len(blob)

    def close(self) -> None:
        if self._closed:
            return
        try:
            self._drain()
            if self._buf:
                self._write_frame(len(self._buf), self._compress(bytes(self._buf)))
                self._buf.clear()
            self._fp.write(_FRAME.pack(_KIND_END, self.raw_bytes, 0, 0))
            self.comp_bytes += _FRAME.size
            self._fp.flush()
        except BaseException:
            # A failed in-flight frame must not leak the fd/pipe thread, and
            # must leave the stream without an end frame (abort semantics) so
            # readers reject it.
            self.abort()
            raise
        if self._pipe is not None:
            self._pipe.shutdown(wait=True)
            self._pipe = None
        if self._own:
            self._fp.close()
        self._closed = True

    def abort(self) -> None:
        """Close WITHOUT finalizing: no buffered flush, no end frame.

        The resulting file fails DecompressReader's end-frame check, so a
        consumer can never mistake an interrupted write for a complete
        stream."""
        if self._closed:
            return
        while self._pending:
            _, fut = self._pending.popleft()
            fut.cancel()
            try:
                fut.result()            # wait out an already-running frame
            except BaseException:
                pass                    # discarded either way
        if self._pipe is not None:
            self._pipe.shutdown(wait=True)
            self._pipe = None
        self._buf.clear()
        if self._own:
            self._fp.close()
        self._closed = True

    def __enter__(self) -> "CompressWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()


class DecompressReader:
    """Streaming decompressor over a ``ZNS1`` container.

    Iterating :meth:`frames` (or calling :meth:`read`) holds one
    decompressed window at a time — O(window) memory for any stream size.
    Frame CRCs are verified before decode; a truncated stream (no end frame)
    raises ``IOError``.

    With ``threads > 1`` the reader **prefetches**: up to
    ``pipeline_depth`` frames decode concurrently on dedicated pipeline
    threads (chunk work items on the engine pool) while later frames'
    bytes are read and CRC-checked from the file — IO and codec overlap,
    frames resolved strictly in stream order, decoded stream unchanged.

    ``backend`` selects the decode back half per frame ('host' | 'device'
    | 'auto' — see ``core/device_unplane.py``) and ``entropy_backend``
    the per-frame entropy decode (host chunk work items vs the device
    Huffman decoder kernel — see ``core/device_entropy.py``); decoded
    bytes are identical for every setting.
    """

    def __init__(
        self,
        fp: PathOrFile,
        config=None,
        *,
        threads: Optional[int] = None,
        backend: Optional[str] = None,
        entropy_backend: Optional[str] = None,
        options: Optional[CodecOptions] = None,
        pipeline_depth: int = 2,
    ):
        from . import zipnn

        opts = resolve_options(
            options, threads=threads, backend=backend,
            entropy_backend=entropy_backend,
        )
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}"
            )
        self._config = zipnn.DEFAULT if config is None else config
        self._threads = self._config.threads if opts.threads is None else opts.threads
        self._backend = opts.backend
        self._entropy_backend = opts.entropy_backend
        self._depth = pipeline_depth
        self._fp, self._own = _open(fp, "rb")
        hdr = self._fp.read(_SHDR.size)
        if len(hdr) < _SHDR.size:
            raise ValueError("truncated ZNS1 header")
        magic, version, _flags, dtype_b, window = _SHDR.unpack(hdr)
        if magic != _STREAM_MAGIC:
            raise ValueError("not a ZNS1 stream")
        if version != 1:
            raise ValueError(f"unsupported ZNS version {version}")
        self.dtype_name = dtype_b.rstrip(b"\x00").decode()
        self.window = window
        self._pending = b""
        self._frames = self._frame_iter()
        self._exhausted = False

    def _decode(self, blob: bytes) -> bytes:
        from . import zipnn

        return zipnn.decompress_bytes(
            blob, self._config,
            options=CodecOptions(
                threads=self._threads, backend=self._backend,
                entropy_backend=self._entropy_backend,
            ),
        )

    def _frame_iter(self) -> Iterator[bytes]:
        """Single shared generator over the file's frames (created once —
        ``read`` and ``frames`` both draw from it, so mixing them never
        skips data).

        When the engine is threaded, frame k's decode is submitted to a
        dedicated pipeline thread and resolved only after up to
        ``pipeline_depth - 1`` later frames' bytes have been read and
        CRC-checked — the prefetch ring.  Frames resolve strictly in
        stream order, and all validation (CRC before decode, per-frame
        length after decode, total length at the end frame) is unchanged.
        """
        use_pipe = resolve_threads(self._threads) > 1
        pipe: Optional[ThreadPoolExecutor] = None
        total = 0
        pending: deque = deque()        # (future-or-blob, declared raw_len)

        def resolve(p) -> bytes:
            nonlocal total
            item, raw_len = p
            raw = item.result() if hasattr(item, "result") else self._decode(item)
            if len(raw) != raw_len:
                raise IOError(
                    f"frame decoded to {len(raw)} bytes, expected {raw_len}"
                )
            total += raw_len
            return raw

        try:
            while True:
                rec = self._fp.read(_FRAME.size)
                if len(rec) < _FRAME.size:
                    raise IOError("truncated ZNS1 stream (missing end frame)")
                kind, raw_len, comp_len, crc = _FRAME.unpack(rec)
                if kind not in (_KIND_DATA, _KIND_END):
                    raise IOError(f"corrupt ZNS1 frame kind {kind}")
                if kind == _KIND_END:
                    last = [resolve(p) for p in pending]
                    pending.clear()
                    # the end frame records the total raw length: a stream
                    # with whole frames missing must not parse as complete
                    if total != raw_len:
                        raise IOError(
                            f"ZNS1 stream yielded {total} bytes, end frame "
                            f"declares {raw_len}"
                        )
                    yield from last
                    return
                blob = _read_exact(self._fp, comp_len)
                if len(blob) < comp_len:
                    raise IOError("truncated ZNS1 frame body")
                if zlib.crc32(blob) != crc:
                    raise IOError("ZNS1 frame CRC mismatch")
                if use_pipe and pipe is None:
                    pipe = ThreadPoolExecutor(
                        max_workers=self._depth,
                        thread_name_prefix="zipnn-frame-pipe",
                    )
                pending.append(
                    (pipe.submit(self._decode, blob) if pipe else blob, raw_len)
                )
                # Keep up to pipeline_depth frames in flight (1 when serial
                # — the blob then decodes lazily at resolve, as before).
                while len(pending) > (self._depth if pipe else 1):
                    yield resolve(pending.popleft())
        finally:
            if pipe is not None:
                pipe.shutdown(wait=False)

    def frames(self) -> Iterator[bytes]:
        """Yield the remaining decompressed frame bodies in stream order.

        Bytes already buffered by a prior partial :meth:`read` come first,
        so the two access styles compose without data loss.
        """
        if self._pending:
            pending, self._pending = self._pending, b""
            yield pending
        while True:
            try:
                yield next(self._frames)
            except StopIteration:
                self._exhausted = True
                return

    def read(self, n: int = -1) -> bytes:
        """File-like read; ``n < 0`` drains the remaining stream."""
        out = bytearray(self._pending)
        self._pending = b""
        while (n < 0 or len(out) < n) and not self._exhausted:
            try:
                out += next(self._frames)
            except StopIteration:
                self._exhausted = True
        if n >= 0 and len(out) > n:
            self._pending = bytes(out[n:])
            del out[n:]
        return bytes(out)

    def close(self) -> None:
        if self._own:
            self._fp.close()

    def __enter__(self) -> "DecompressReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def frame_records(src: PathOrFile) -> Iterator[Tuple[int, int, bytes]]:
    """Yield ``(raw_len, comp_len, blob)`` per data frame of a ``ZNS1``
    container, without decoding — frame-level tooling (the hub's wire/codec
    overlap model, integrity scanners) reads sizes and bodies through this.
    One frame in memory at a time."""
    fin, own = _open(src, "rb")
    try:
        hdr = fin.read(_SHDR.size)
        if len(hdr) < _SHDR.size or _SHDR.unpack(hdr)[0] != _STREAM_MAGIC:
            raise ValueError("not a ZNS1 stream")
        while True:
            rec = fin.read(_FRAME.size)
            if len(rec) < _FRAME.size:
                raise IOError("truncated ZNS1 stream (missing end frame)")
            kind, raw_len, comp_len, _crc = _FRAME.unpack(rec)
            if kind not in (_KIND_DATA, _KIND_END):
                raise IOError(f"corrupt ZNS1 frame kind {kind}")
            if kind == _KIND_END:
                return
            blob = _read_exact(fin, comp_len)
            if len(blob) < comp_len:
                raise IOError("truncated ZNS1 frame body")
            yield raw_len, comp_len, blob
    finally:
        if own:
            fin.close()


def compress_file(
    src: PathOrFile,
    dst: PathOrFile,
    dtype_name: str,
    config=None,
    *,
    window_bytes: int = DEFAULT_WINDOW,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
    pipeline_depth: int = 2,
) -> Tuple[int, int]:
    """Stream-compress ``src`` into a ``ZNS1`` container at ``dst``.

    Reads/compresses/writes one window at a time — peak extra memory is
    O(window), so checkpoints larger than RAM round-trip.  With threads the
    read of later windows overlaps up to ``pipeline_depth`` windows'
    compression (see :class:`CompressWriter`).  Returns
    ``(raw_bytes, comp_bytes)``.
    """
    opts = resolve_options(
        options, threads=threads, backend=backend,
        entropy_backend=entropy_backend,
    )
    fin, own_in = _open(src, "rb")
    try:
        with CompressWriter(
            dst, dtype_name, config,
            window_bytes=window_bytes, options=opts,
            pipeline_depth=pipeline_depth,
        ) as w:
            while True:
                data = fin.read(w._window)
                if not data:
                    break
                w.write(data)
        return w.raw_bytes, w.comp_bytes
    finally:
        if own_in:
            fin.close()


def decompress_file(
    src: PathOrFile,
    dst: PathOrFile,
    config=None,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
    pipeline_depth: int = 2,
) -> int:
    """Stream-decompress a ``ZNS1`` container; returns raw bytes written."""
    opts = resolve_options(
        options, threads=threads, backend=backend,
        entropy_backend=entropy_backend,
    )
    fout, own_out = _open(dst, "wb")
    try:
        with DecompressReader(
            src, config, options=opts, pipeline_depth=pipeline_depth
        ) as r:
            total = 0
            for raw in r.frames():
                fout.write(raw)
                total += len(raw)
        fout.flush()
        return total
    finally:
        if own_out:
            fout.close()
