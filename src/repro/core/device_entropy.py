"""Device entropy-stage backend: fused Huffman bit-packing on accelerator.

PR 2/3 moved the compression *front half* (rotate + byte-group + probe) and
the decompression back half on device; the Huffman encode loop stayed the
last GIL-bound host pass on the compress path.  This module closes it:

* the probe histograms (host ``hist256`` or the device plane-producer's
  :class:`~repro.core.codec.ProbeStats`) feed the **canonical table build on
  host** — table construction is a 256-entry package-merge, microseconds,
  and keeping it host-side preserves the canonical-code contract that makes
  blobs testable;
* every (plane, chunk) work item the codec planned as ``HUFF`` then packs
  symbols→bits in **one fused Pallas dispatch**
  (:func:`repro.kernels.bitpack.bitpack_encode_chunks_multi` — per-chunk
  table selection, so all planes of a tensor ride one launch) followed by a
  **single device→host transfer** of packed words + true bit counts;
* the host does only container framing and the expansion guard: chunks
  whose packed size would reach their raw size are stored raw by
  :meth:`~repro.core.codec.PlaneCodec.finalize`, exactly as on the host
  path, so the metadata map is unchanged.

Output blobs are **byte-identical** to the host encoder for every thread
count and plane backend: the kernel packs MSB-first canonical codes with
per-chunk byte alignment — the same bitstream ``huffman.encode_chunks``
emits — and the method plan (probe + probe-skip) runs through the one
shared :meth:`~repro.core.codec.PlaneCodec.plan` implementation.

Backend selection mirrors :mod:`.device_plane`:

* ``"host"``   — the numpy/vectorized host encoder (default);
* ``"device"`` — the fused bit-pack dispatch whenever supported (canonical
  ``huffman`` coder, 4-byte-aligned chunks); silent host fallback
  otherwise, so the knob is always safe to set;
* ``"auto"``   — device only for accelerator-resident leaves.

Support envelope: the codec's ``backend == "huffman"`` coder only — the
``hufflib`` (zlib) coder's DEFLATE bitstream has no device formulation —
with ``chunk_bytes % 4 == 0`` (the uint32 word reduce).  ``ZERO`` /
``STORE`` / ``ZLIB`` chunks and the §4.2 delta LZ path stay host work
items, as does everything on fallback.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import bitlayout, codec

__all__ = [
    "BACKENDS",
    "is_available",
    "supports",
    "resolve",
    "encode_planes",
]

BACKENDS = ("host", "device", "auto")

# One fused dispatch is capped so symbols + packed words (2× the HUFF chunk
# bytes) stay comfortably in device memory; larger jobs split into several
# launches (payload bytes are per-chunk, so splitting never changes them).
MAX_BATCH_BYTES = 256 << 20


def is_available() -> bool:
    """True when jax (and therefore the Pallas kernels) can be imported."""
    from . import device_plane

    return device_plane.is_available()


def supports(layout: Optional[bitlayout.BitLayout], params: codec.CodecParams) -> bool:
    """Can the fused bit-pack path reproduce the host encoder's bytes?

    Requires the canonical ``huffman`` coder (``hufflib`` emits a DEFLATE
    stream we do not reproduce on device) and chunks that are whole uint32
    words.
    """
    if params.backend != "huffman":
        return False
    if params.chunk_bytes % 4 != 0:
        return False
    return is_available()


def resolve(
    requested: Optional[str],
    layout: Optional[bitlayout.BitLayout],
    params: codec.CodecParams,
    leaf=None,
) -> str:
    """Collapse a backend request to the concrete path: 'host' or 'device'."""
    if requested is None or requested == "host":
        return "host"
    if requested == "device":
        return "device" if supports(layout, params) else "host"
    if requested == "auto":
        from . import device_plane

        return (
            "device"
            if supports(layout, params) and device_plane._on_accelerator(leaf)
            else "host"
        )
    raise ValueError(
        f"unknown entropy backend {requested!r}; expected one of {BACKENDS}"
    )


# ---------------------------------------------------------------------------
# fused encode
# ---------------------------------------------------------------------------

PlaneResult = Tuple[List[codec.ChunkEntry], List[bytes], Optional[bytes]]


def _pack_jobs(
    planes: Sequence[np.ndarray],
    jobs: Sequence[Tuple[int, int, int]],
    len_tables: np.ndarray,
    code_tables: np.ndarray,
    chunk_bytes: int,
) -> List[bytes]:
    """Run one fused bit-pack dispatch over ``jobs`` and slice payloads.

    ``jobs`` is ``(plane_idx, chunk_idx, size)`` per HUFF chunk; the final
    partial chunk (``size < chunk_bytes``) is zero-padded on the symbol side
    and its pad bits are subtracted/masked on the host side — byte-identical
    to encoding exactly ``size`` symbols.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import bitpack

    c = len(jobs)
    syms = np.zeros(c * chunk_bytes, dtype=np.uint8)
    pids = np.empty(c, dtype=np.int32)
    for k, (p, ch, size) in enumerate(jobs):
        start = ch * chunk_bytes
        syms[k * chunk_bytes : k * chunk_bytes + size] = planes[p][start : start + size]
        pids[k] = p
    words, nbits = bitpack.bitpack_encode_chunks_multi(
        jnp.asarray(syms),
        jnp.asarray(pids),
        jnp.asarray(len_tables),
        jnp.asarray(code_tables),
        chunk_syms=chunk_bytes,
        interpret=jax.default_backend() != "tpu",
    )
    # The one device→host transfer: packed words + true bit counts together.
    words_h, nbits_h = jax.device_get((words, nbits))
    # uint32 words hold bit j of the chunk at word bit 31-j: big-endian byte
    # order recovers exactly the np.packbits stream the host encoder emits.
    stream = np.ascontiguousarray(words_h).byteswap().view(np.uint8).reshape(-1)

    out: List[bytes] = []
    for k, (p, ch, size) in enumerate(jobs):
        pad = chunk_bytes - size
        true_bits = int(nbits_h[k]) - pad * int(len_tables[p, 0])
        nbytes = (true_bits + 7) >> 3
        if nbytes > chunk_bytes:
            # Expanded past the kernel's raw-size capacity: bits were
            # truncated on device, but finalize() stores this chunk raw
            # (len >= raw_len) — only the payload *length* matters here.
            out.append(bytes(nbytes))
            continue
        blob = bytearray(stream[k * chunk_bytes : k * chunk_bytes + nbytes])
        slack = nbytes * 8 - true_bits
        if slack and nbytes:
            blob[-1] &= (0xFF << slack) & 0xFF  # zero pad-symbol bits
        out.append(bytes(blob))
    return out


def encode_planes(
    planes: Sequence[np.ndarray],
    probes: Sequence[Optional[codec.ProbeStats]],
    params: codec.CodecParams,
    pool=None,
) -> Tuple[List[List[codec.ChunkEntry]], List[List[bytes]], List[Optional[bytes]]]:
    """Device-backed equivalent of the per-plane host compress loop.

    Pass 1 (plan: probe + probe-skip + table build) runs host-side through
    the shared :meth:`~repro.core.codec.PlaneCodec.plan`; every planned
    ``HUFF`` chunk across *all* planes then packs in one fused device
    dispatch (split only at :data:`MAX_BATCH_BYTES`), while ``ZERO`` /
    ``STORE`` / ``ZLIB`` chunks encode as host work items on ``pool``.
    Pass 3 (expansion guard + metadata map) is the shared ``finalize``.

    Returns per-plane ``(entries, payloads, table_blob)`` lists matching
    :func:`repro.core.codec.compress_plane` byte-for-byte.
    """
    codecs = [codec.PlaneCodec(params) for _ in planes]
    methods_all: List[List[int]] = []
    for pc, plane, probe in zip(codecs, planes, probes):
        methods_all.append(pc.plan(plane, pool=pool, probe=probe))

    cb = params.chunk_bytes
    jobs: List[Tuple[int, int, int]] = []
    for p, (plane, methods) in enumerate(zip(planes, methods_all)):
        for ch, m in enumerate(methods):
            if m == codec.Method.HUFF:
                jobs.append((p, ch, min(cb, plane.size - ch * cb)))

    huff_payloads: dict = {}
    if jobs:
        len_tables = np.stack(
            [np.asarray(pc.table, dtype=np.int32) for pc in codecs]
        )
        code_tables = np.stack(
            [np.asarray(pc.codes, dtype=np.int32) for pc in codecs]
        )
        per_launch = max(1, MAX_BATCH_BYTES // (2 * cb))
        for lo in range(0, len(jobs), per_launch):
            batch = jobs[lo : lo + per_launch]
            for (p, ch, _), blob in zip(
                batch, _pack_jobs(planes, batch, len_tables, code_tables, cb)
            ):
                huff_payloads[(p, ch)] = blob

    entries_all: List[List[codec.ChunkEntry]] = []
    payloads_all: List[List[bytes]] = []
    tables_all: List[Optional[bytes]] = []
    for p, (pc, plane, methods) in enumerate(zip(codecs, planes, methods_all)):
        other = [ch for ch in range(len(methods)) if methods[ch] != codec.Method.HUFF]
        other_blobs = codec._fan_out(
            pool,
            len(other),
            lambda ids, plane=plane, methods=methods, other=other, pc=pc: (
                pc.encode_ids(plane, methods, [other[i] for i in ids])
            ),
        )
        payloads: List[bytes] = [b""] * len(methods)
        for ch, blob in zip(other, other_blobs):
            payloads[ch] = blob
        for ch, m in enumerate(methods):
            if m == codec.Method.HUFF:
                payloads[ch] = huff_payloads[(p, ch)]
        entries = pc.finalize(plane, methods, payloads)
        needs_table = any(e.method == codec.Method.HUFF for e in entries)
        entries_all.append(entries)
        payloads_all.append(payloads)
        tables_all.append(pc.table_blob() if needs_table else None)
    return entries_all, payloads_all, tables_all
