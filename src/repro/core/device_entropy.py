"""Device entropy-stage backend: fused Huffman bit-packing on accelerator.

PR 2/3 moved the compression *front half* (rotate + byte-group + probe) and
the decompression back half on device; the Huffman encode loop stayed the
last GIL-bound host pass on the compress path.  This module closes it:

* the probe histograms (host ``hist256`` or the device plane-producer's
  :class:`~repro.core.codec.ProbeStats`) feed the **canonical table build on
  host** — table construction is a 256-entry package-merge, microseconds,
  and keeping it host-side preserves the canonical-code contract that makes
  blobs testable;
* every (plane, chunk) work item the codec planned as ``HUFF`` then packs
  symbols→bits in **one fused Pallas dispatch**
  (:func:`repro.kernels.bitpack.bitpack_encode_chunks_multi` — per-chunk
  table selection, so all planes of a tensor ride one launch) followed by a
  **single device→host transfer** of packed words + true bit counts;
* the host does only container framing and the expansion guard: chunks
  whose packed size would reach their raw size are stored raw by
  :meth:`~repro.core.codec.PlaneCodec.finalize`, exactly as on the host
  path, so the metadata map is unchanged.

Output blobs are **byte-identical** to the host encoder for every thread
count and plane backend: the kernel packs MSB-first canonical codes with
per-chunk byte alignment — the same bitstream ``huffman.encode_chunks``
emits — and the method plan (probe + probe-skip) runs through the one
shared :meth:`~repro.core.codec.PlaneCodec.plan` implementation.

Backend selection mirrors :mod:`.device_plane`:

* ``"host"``   — the numpy/vectorized host encoder (default);
* ``"device"`` — the fused bit-pack dispatch whenever supported (canonical
  ``huffman`` coder, 4-byte-aligned chunks); silent host fallback
  otherwise, so the knob is always safe to set;
* ``"auto"``   — device only for accelerator-resident leaves.

Support envelope: the codec's ``backend == "huffman"`` coder only — the
``hufflib`` (zlib) coder's DEFLATE bitstream has no device formulation —
with ``chunk_bytes % 4 == 0`` (the uint32 word reduce).  ``ZERO`` /
``STORE`` / ``ZLIB`` chunks and the §4.2 delta LZ path stay host work
items, as does everything on fallback.

**Decode twin** (:func:`decode_planes`): every ``HUFF`` chunk of a parsed
container decodes in one fused Pallas dispatch
(:func:`repro.kernels.huffdecode.huffdecode_chunks_multi` — per-chunk LUT
row selection over stacked canonical tables, grid over chunks, serial bit
cursor per chunk).  The *compressed* payload words + stacked LUTs upload
once; decoded symbols can stay device-resident
(``device_resident=True``) so the fused un-plane consumer never re-uploads
them — the zero-bounce restore path.  CRC verification, the
``decode_many``-equivalent bit-cursor + pad-bit integrity checks, and
``ZERO``/``STORE``/``ZLIB`` chunk decode stay host-side; those spliced
chunks ride one additional upload on the device-resident path.  The decode
envelope (:func:`supports_decode`) keys off the *container's* chunk
geometry, not the config's coder: the stream records which chunks are
``HUFF``, so any blob the canonical coder produced decodes on device
regardless of the configured encode backend.
"""

from __future__ import annotations

import functools
import threading
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import bitlayout, codec, huffman

__all__ = [
    "BACKENDS",
    "LUT_CACHE_SIZE",
    "PayloadFeed",
    "is_available",
    "supports",
    "supports_decode",
    "resolve",
    "resolve_decode",
    "encode_planes",
    "decode_planes",
    "transfer_stats",
    "reset_transfer_stats",
]

BACKENDS = ("host", "device", "auto")

# One fused dispatch is capped so symbols + packed words (2× the HUFF chunk
# bytes) stay comfortably in device memory; larger jobs split into several
# launches (payload bytes are per-chunk, so splitting never changes them).
# Shares device_plane's env-tunable cap (ZIPNN_MAX_BATCH_BYTES) — window
# size changes wall-clock and peak memory only, never bytes.
from .device_plane import MAX_BATCH_BYTES  # noqa: E402

# _stacked_luts_cached's lru_cache bound.  The cache is keyed on raw table
# bytes, so a long-lived serving session decoding many *distinct* stores
# would grow host memory without limit if unbounded; 64 entries cover every
# plane-table combination a realistic ring re-decodes while still evicting
# dead stores.  Asserted by tests (cache_info().maxsize).
LUT_CACHE_SIZE = 64


# ---------------------------------------------------------------------------
# transfer instrumentation
# ---------------------------------------------------------------------------
#
# Every payload-sized host→device upload on this module's encode/decode
# paths is tallied here: HUFF symbol uploads (_pack_jobs host path), packed
# word uploads (_unpack_jobs / PayloadFeed build) and the non-HUFF splice
# upload.  The counters are the test hook behind the device-resident feed's
# headline contract — zero per-token payload uploads after warmup — and
# count bookkeeping only: they never touch the data path.

_transfer_lock = threading.Lock()
_transfer_stats: Dict[str, int] = {"payload_uploads": 0, "payload_bytes": 0}


def _count_payload_upload(nbytes: int) -> None:
    with _transfer_lock:
        _transfer_stats["payload_uploads"] += 1
        _transfer_stats["payload_bytes"] += int(nbytes)


def transfer_stats() -> Dict[str, int]:
    """Snapshot of payload host→device upload counters (test hook)."""
    with _transfer_lock:
        return dict(_transfer_stats)


def reset_transfer_stats() -> None:
    with _transfer_lock:
        for k in _transfer_stats:
            _transfer_stats[k] = 0


def is_available() -> bool:
    """True when jax (and therefore the Pallas kernels) can be imported."""
    from . import device_plane

    return device_plane.is_available()


def supports(layout: Optional[bitlayout.BitLayout], params: codec.CodecParams) -> bool:
    """Can the fused bit-pack path reproduce the host encoder's bytes?

    Requires the canonical ``huffman`` coder (``hufflib`` emits a DEFLATE
    stream we do not reproduce on device) and chunks that are whole uint32
    words.
    """
    if params.backend != "huffman":
        return False
    if params.chunk_bytes % 4 != 0:
        return False
    return is_available()


def resolve(
    requested: Optional[str],
    layout: Optional[bitlayout.BitLayout],
    params: codec.CodecParams,
    leaf=None,
) -> str:
    """Collapse a backend request to the concrete path: 'host' or 'device'."""
    if requested is None or requested == "host":
        return "host"
    if requested == "device":
        return "device" if supports(layout, params) else "host"
    if requested == "auto":
        from . import device_plane

        return (
            "device"
            if supports(layout, params) and device_plane._on_accelerator(leaf)
            else "host"
        )
    raise ValueError(
        f"unknown entropy backend {requested!r}; expected one of {BACKENDS}"
    )


def supports_decode(chunk_bytes: int) -> bool:
    """Can the fused decode path reproduce the host decoder's bytes?

    Decode keys off the *container*, not the config: the stream records
    which chunks are ``HUFF`` (only the canonical coder emits them), so the
    envelope is just whole-uint32-word chunks plus jax availability.
    """
    return chunk_bytes % 4 == 0 and is_available()


def resolve_decode(
    requested: Optional[str], chunk_bytes: int, base=None
) -> str:
    """Decode twin of :func:`resolve`.

    ``auto`` keys off accelerator attachment (or an accelerator-resident
    delta ``base``) — decoded symbols land on device, so residence of the
    hardware is the signal, mirroring ``device_unplane.resolve``.
    """
    if requested is None or requested == "host":
        return "host"
    if requested == "device":
        return "device" if supports_decode(chunk_bytes) else "host"
    if requested == "auto":
        from . import device_plane, device_unplane

        return (
            "device"
            if supports_decode(chunk_bytes)
            and (
                device_unplane._accelerator_attached()
                or device_plane._on_accelerator(base)
            )
            else "host"
        )
    raise ValueError(
        f"unknown entropy backend {requested!r}; expected one of {BACKENDS}"
    )


# ---------------------------------------------------------------------------
# fused encode
# ---------------------------------------------------------------------------

PlaneResult = Tuple[List[codec.ChunkEntry], List[bytes], Optional[bytes]]


def _gather_syms_device(
    planes: Sequence[np.ndarray],
    jobs: Sequence[Tuple[int, int, int]],
    chunk_bytes: int,
):
    """HUFF symbols for ``jobs`` gathered from device-resident plane rows.

    Returns a flat ``(len(jobs) * chunk_bytes,)`` device uint8 array, or
    ``None`` when any referenced plane lacks its device twin (host-planed
    leaves, mismatched chunk geometry) or the jobs are not plane-major —
    the caller then builds the symbols host-side as before.  Only the
    chunk-id index vectors cross host→device (metadata-sized); the symbol
    bytes themselves never leave the device.
    """
    import jax.numpy as jnp

    if not jobs:
        return None
    for k in range(1, len(jobs)):
        if jobs[k][0] < jobs[k - 1][0]:
            return None                     # per-plane grouping would reorder
    parts = []
    i = 0
    while i < len(jobs):
        p = jobs[i][0]
        j = i
        while j < len(jobs) and jobs[j][0] == p:
            j += 1
        dev = getattr(planes[p], "dev_chunks", None)
        if dev is None or dev.ndim != 2 or dev.shape[1] != chunk_bytes:
            return None
        ids = np.asarray([ch for (_, ch, _) in jobs[i:j]], dtype=np.int32)
        if ids.size and int(ids.max()) >= dev.shape[0]:
            return None
        parts.append(dev[jnp.asarray(ids)])
        i = j
    mat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return mat.reshape(-1)


def _pack_jobs(
    planes: Sequence[np.ndarray],
    jobs: Sequence[Tuple[int, int, int]],
    len_tables: np.ndarray,
    code_tables: np.ndarray,
    chunk_bytes: int,
) -> List[bytes]:
    """Run one fused bit-pack dispatch over ``jobs`` and slice payloads.

    ``jobs`` is ``(plane_idx, chunk_idx, size)`` per HUFF chunk; the final
    partial chunk (``size < chunk_bytes``) is zero-padded on the symbol side
    and its pad bits are subtracted/masked on the host side — byte-identical
    to encoding exactly ``size`` symbols.

    When the planes are the device producer's :class:`~repro.core.
    device_plane.PlanedArray` twins, the HUFF symbols are **gathered on
    device** from the still-resident chunk rows instead of re-uploaded from
    host — the rows carry the identical zero padding, so the packed bits
    cannot differ.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import bitpack

    c = len(jobs)
    pids = np.empty(c, dtype=np.int32)
    for k, (p, ch, size) in enumerate(jobs):
        pids[k] = p
    syms_dev = _gather_syms_device(planes, jobs, chunk_bytes)
    if syms_dev is None:
        syms = np.zeros(c * chunk_bytes, dtype=np.uint8)
        for k, (p, ch, size) in enumerate(jobs):
            start = ch * chunk_bytes
            syms[k * chunk_bytes : k * chunk_bytes + size] = (
                planes[p][start : start + size]
            )
        _count_payload_upload(syms.nbytes)
        syms_dev = jnp.asarray(syms)
    words, nbits = bitpack.bitpack_encode_chunks_multi(
        syms_dev,
        jnp.asarray(pids),
        jnp.asarray(len_tables),
        jnp.asarray(code_tables),
        chunk_syms=chunk_bytes,
        interpret=jax.default_backend() != "tpu",
    )
    # The one device→host transfer: packed words + true bit counts together.
    words_h, nbits_h = jax.device_get((words, nbits))
    # uint32 words hold bit j of the chunk at word bit 31-j: big-endian byte
    # order recovers exactly the np.packbits stream the host encoder emits.
    stream = np.ascontiguousarray(words_h).byteswap().view(np.uint8).reshape(-1)

    out: List[bytes] = []
    for k, (p, ch, size) in enumerate(jobs):
        pad = chunk_bytes - size
        true_bits = int(nbits_h[k]) - pad * int(len_tables[p, 0])
        nbytes = (true_bits + 7) >> 3
        if nbytes > chunk_bytes:
            # Expanded past the kernel's raw-size capacity: bits were
            # truncated on device, but finalize() stores this chunk raw
            # (len >= raw_len) — only the payload *length* matters here.
            out.append(bytes(nbytes))
            continue
        blob = bytearray(stream[k * chunk_bytes : k * chunk_bytes + nbytes])
        slack = nbytes * 8 - true_bits
        if slack and nbytes:
            blob[-1] &= (0xFF << slack) & 0xFF  # zero pad-symbol bits
        out.append(bytes(blob))
    return out


def encode_planes(
    planes: Sequence[np.ndarray],
    probes: Sequence[Optional[codec.ProbeStats]],
    params: codec.CodecParams,
    pool=None,
) -> Tuple[List[List[codec.ChunkEntry]], List[List[bytes]], List[Optional[bytes]]]:
    """Device-backed equivalent of the per-plane host compress loop.

    Pass 1 (plan: probe + probe-skip + table build) runs host-side through
    the shared :meth:`~repro.core.codec.PlaneCodec.plan`; every planned
    ``HUFF`` chunk across *all* planes then packs in one fused device
    dispatch (split only at :data:`MAX_BATCH_BYTES`), while ``ZERO`` /
    ``STORE`` / ``ZLIB`` chunks encode as host work items on ``pool``.
    Pass 3 (expansion guard + metadata map) is the shared ``finalize``.

    Returns per-plane ``(entries, payloads, table_blob)`` lists matching
    :func:`repro.core.codec.compress_plane` byte-for-byte.
    """
    codecs = [codec.PlaneCodec(params) for _ in planes]
    methods_all: List[List[int]] = []
    for pc, plane, probe in zip(codecs, planes, probes):
        methods_all.append(pc.plan(plane, pool=pool, probe=probe))

    cb = params.chunk_bytes
    jobs: List[Tuple[int, int, int]] = []
    for p, (plane, methods) in enumerate(zip(planes, methods_all)):
        for ch, m in enumerate(methods):
            if m == codec.Method.HUFF:
                jobs.append((p, ch, min(cb, plane.size - ch * cb)))

    huff_payloads: dict = {}
    if jobs:
        len_tables = np.stack(
            [np.asarray(pc.table, dtype=np.int32) for pc in codecs]
        )
        code_tables = np.stack(
            [np.asarray(pc.codes, dtype=np.int32) for pc in codecs]
        )
        per_launch = max(1, MAX_BATCH_BYTES // (2 * cb))
        for lo in range(0, len(jobs), per_launch):
            batch = jobs[lo : lo + per_launch]
            for (p, ch, _), blob in zip(
                batch, _pack_jobs(planes, batch, len_tables, code_tables, cb)
            ):
                huff_payloads[(p, ch)] = blob

    entries_all: List[List[codec.ChunkEntry]] = []
    payloads_all: List[List[bytes]] = []
    tables_all: List[Optional[bytes]] = []
    for p, (pc, plane, methods) in enumerate(zip(codecs, planes, methods_all)):
        other = [ch for ch in range(len(methods)) if methods[ch] != codec.Method.HUFF]
        other_blobs = codec._fan_out(
            pool,
            len(other),
            lambda ids, plane=plane, methods=methods, other=other, pc=pc: (
                pc.encode_ids(plane, methods, [other[i] for i in ids])
            ),
        )
        payloads: List[bytes] = [b""] * len(methods)
        for ch, blob in zip(other, other_blobs):
            payloads[ch] = blob
        for ch, m in enumerate(methods):
            if m == codec.Method.HUFF:
                payloads[ch] = huff_payloads[(p, ch)]
        entries = pc.finalize(plane, methods, payloads)
        needs_table = any(e.method == codec.Method.HUFF for e in entries)
        entries_all.append(entries)
        payloads_all.append(payloads)
        tables_all.append(pc.table_blob() if needs_table else None)
    return entries_all, payloads_all, tables_all


# ---------------------------------------------------------------------------
# fused decode
# ---------------------------------------------------------------------------

def _stacked_luts(
    tables_all: Sequence[Optional[bytes]],
) -> Tuple[np.ndarray, int]:
    """Fused ``(sym << 8) | len`` LUTs, one row per plane, at a shared width.

    The shared width is the max code length across every plane's table —
    canonical prefixes stay valid at any LUT width ≥ their own max length,
    so one kernel launch can gather against any plane's row.  Planes
    without a table (no HUFF chunks) get an all-zero row that is never
    selected.

    Memoized on the table bytes: the compressed-resident serving ring
    (``repro.serve.compressed``) decodes the *same* payloads every token,
    so the table unpack + LUT expansion is paid once per blob, not once
    per step.  The cached array is only ever read (it feeds the kernel's
    host→device upload), and the LUT is a pure function of the tables, so
    memoization cannot change decoded bytes.
    """
    return _stacked_luts_cached(tuple(tables_all))


@functools.lru_cache(maxsize=LUT_CACHE_SIZE)
def _stacked_luts_cached(
    tables_all: Tuple[Optional[bytes], ...],
) -> Tuple[np.ndarray, int]:
    lens_all: List[Optional[np.ndarray]] = []
    max_l = 1
    for tb in tables_all:
        if tb is None:
            lens_all.append(None)
            continue
        lens = huffman.unpack_table(tb)
        lens_all.append(lens)
        max_l = max(max_l, int(lens.max(initial=1)))
    luts = np.zeros((len(tables_all), 1 << max_l), dtype=np.int32)
    for p, lens in enumerate(lens_all):
        if lens is None:
            continue
        codes = huffman.canonical_codes(lens)
        lut_sym, lut_len = huffman._build_lut(lens, codes, max_l)
        luts[p] = (lut_sym.astype(np.int32) << 8) | lut_len.astype(np.int32)
    return luts, max_l


def _pack_words(
    jobs: Sequence[Tuple[int, int]],
    entries_all: Sequence[Sequence[codec.ChunkEntry]],
    payloads_all: Sequence[Sequence[bytes]],
    chunk_bytes: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pack each job's payload bytes into the decode kernel's word layout.

    Payload bytes pack into big-endian uint32 words (the encode kernel's
    bit convention), zero-padded to the ``chunk_bytes`` capacity — valid
    payloads are always shorter (expansion guard), and oversized ones are
    rejected up front so corrupt metadata can never drive an out-of-range
    copy.  Returns ``(words, plane_ids, counts, payload_sizes)``.
    """
    c = len(jobs)
    cw = chunk_bytes // 4
    words = np.zeros(c * cw, dtype=np.uint32)
    pids = np.empty(c, dtype=np.int32)
    counts = np.empty(c, dtype=np.int32)
    sizes = np.empty(c, dtype=np.int64)
    for k, (p, ch) in enumerate(jobs):
        payload = payloads_all[p][ch]
        if len(payload) > chunk_bytes:
            raise ValueError(
                "corrupt Huffman payload: payload larger than its chunk"
            )
        pad = -len(payload) % 4
        w = np.frombuffer(bytes(payload) + b"\x00" * pad, dtype=">u4")
        words[k * cw : k * cw + w.size] = w
        pids[k] = p
        counts[k] = entries_all[p][ch].raw_len
        sizes[k] = len(payload)
    return words, pids, counts, sizes


def _check_cursors(
    jobs: Sequence[Tuple[int, int]],
    payloads_all: Sequence[Sequence[bytes]],
    sizes: np.ndarray,
    cursors_h: np.ndarray,
) -> None:
    """The ``decode_many``-equivalent integrity checks on kernel cursors.

    Each chunk's final bit cursor must land inside its payload's final byte
    and the 0-7 pad bits must be zero — truncated or flipped words fail
    cleanly, never silently.
    """
    slack = sizes * 8 - cursors_h
    if np.any((slack < 0) | (slack >= 8)):
        raise ValueError(
            "corrupt Huffman payload: bit cursor did not land on the "
            "chunk's final byte"
        )
    for k, (p, ch) in enumerate(jobs):
        s = int(slack[k])
        payload = payloads_all[p][ch]
        if s and payload and payload[-1] & ((1 << s) - 1):
            raise ValueError(
                "corrupt Huffman payload: nonzero pad bits in the chunk's "
                "final byte"
            )


def _unpack_jobs(
    jobs: Sequence[Tuple[int, int]],
    entries_all: Sequence[Sequence[codec.ChunkEntry]],
    payloads_all: Sequence[Sequence[bytes]],
    luts: np.ndarray,
    chunk_bytes: int,
):
    """Run one fused decode dispatch over ``jobs``; return device symbols.

    ``jobs`` is ``(plane_idx, chunk_idx)`` per HUFF chunk.  The packed
    words are uploaded for this launch only (the :class:`PayloadFeed` path
    instead uploads them once and re-decodes from device memory); after the
    launch the per-chunk bit cursors (a metadata-sized transfer) feed the
    same integrity checks as ``huffman.decode_many``.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import huffdecode

    words, pids, counts, sizes = _pack_words(
        jobs, entries_all, payloads_all, chunk_bytes
    )
    _count_payload_upload(words.nbytes)
    syms, cursors = huffdecode.huffdecode_chunks_multi(
        jnp.asarray(words),
        jnp.asarray(pids),
        jnp.asarray(counts),
        jnp.asarray(luts),
        chunk_bytes=chunk_bytes,
        interpret=jax.default_backend() != "tpu",
    )
    cursors_h = np.asarray(jax.device_get(cursors), dtype=np.int64)
    _check_cursors(jobs, payloads_all, sizes, cursors_h)
    return syms


def _verify_payload_crcs(
    flat: Sequence[Tuple[int, int]],
    entries_all: Sequence[Sequence[codec.ChunkEntry]],
    payloads_all: Sequence[Sequence[bytes]],
    pool=None,
) -> None:
    """CRC-verify every chunk payload (same errors and order as
    :meth:`~repro.core.codec.PlaneCodec.decode_into`), fanned across
    ``pool``."""

    def verify(ids):
        for k in ids:
            p, c = flat[k]
            e = entries_all[p][c]
            if e.method == codec.Method.ZERO:
                if e.comp_len or e.crc:
                    raise IOError(
                        "corrupt chunk entry: ZERO chunk with a payload"
                    )
            elif zlib.crc32(payloads_all[p][c]) != e.crc:
                raise IOError(f"chunk payload CRC mismatch (chunk {c})")
        return [None] * len(ids)

    codec._fan_out(pool, len(flat), verify)


def _huff_jobs(
    flat: Sequence[Tuple[int, int]],
    entries_all: Sequence[Sequence[codec.ChunkEntry]],
    payloads_all: Sequence[Sequence[bytes]],
    tables_all: Sequence[Optional[bytes]],
) -> List[Tuple[int, int]]:
    """The stream's HUFF ``(plane, chunk)`` jobs, validated against its
    tables (a HUFF chunk without a plane table, or with an empty non-empty
    payload, is corrupt metadata)."""
    jobs = [
        (p, c) for (p, c) in flat
        if entries_all[p][c].method == codec.Method.HUFF
    ]
    for p in sorted({p for (p, _) in jobs}):
        if tables_all[p] is None:
            raise IOError("corrupt stream: HUFF chunks but no plane table")
    if any(
        not payloads_all[p][c] and entries_all[p][c].raw_len for (p, c) in jobs
    ):
        raise IOError("corrupt chunk entry: empty HUFF payload")
    return jobs


def _decode_other_chunks(
    others: Sequence[Tuple[int, int]],
    entries_all: Sequence[Sequence[codec.ChunkEntry]],
    payloads_all: Sequence[Sequence[bytes]],
    pool=None,
) -> Dict[Tuple[int, int], np.ndarray]:
    """Host-decode every non-HUFF chunk (identical decode + integrity
    checks to ``PlaneCodec.decode_into``), fanned across ``pool``."""

    def decode_other(ids):
        out = []
        for k in ids:
            p, c = others[k]
            e = entries_all[p][c]
            payload = payloads_all[p][c]
            if e.method == codec.Method.ZERO:
                out.append(np.zeros(e.raw_len, dtype=np.uint8))
            elif e.method == codec.Method.STORE:
                if e.comp_len != e.raw_len:
                    raise IOError(
                        "corrupt chunk entry: STORE length != raw length"
                    )
                out.append(np.frombuffer(payload, dtype=np.uint8))
            elif e.method in (codec.Method.ZLIB, codec.Method.HUFFLIB):
                blob = codec._unzlib(payload, e.raw_len)
                if len(blob) != e.raw_len:
                    raise IOError(
                        "corrupt zlib chunk payload: wrong decoded length"
                    )
                out.append(np.frombuffer(blob, dtype=np.uint8))
            else:
                raise ValueError(f"unknown method {e.method}")
        return out

    return dict(zip(others, codec._fan_out(pool, len(others), decode_other)))


def decode_planes(
    entries_all: Sequence[Sequence[codec.ChunkEntry]],
    payloads_all: Sequence[Sequence[bytes]],
    tables_all: Sequence[Optional[bytes]],
    params: codec.CodecParams,
    pool=None,
    device_resident: bool = False,
) -> List[Any]:
    """Device-backed equivalent of the per-plane host decompress loop.

    Every payload's CRC is verified first (same errors, same order as
    :meth:`~repro.core.codec.PlaneCodec.decode_into`), then every ``HUFF``
    chunk across *all* planes decodes in one fused device dispatch (split
    only at :data:`MAX_BATCH_BYTES`) — the compressed words + stacked LUTs
    are the only data-sized host→device transfer.  ``ZERO`` / ``STORE`` /
    ``ZLIB`` chunks decode as host work items on ``pool`` and are spliced
    back in.

    Returns per-plane flat uint8 arrays matching
    :func:`repro.core.codec.decompress_plane` byte-for-byte — numpy by
    default (one device→host transfer of decoded symbols), or
    device-resident ``jax.Array`` planes with ``device_resident=True``
    (spliced on device; no symbol download), ready for
    :func:`repro.core.device_unplane.consume_planes` to consume in place.
    """
    cb = params.chunk_bytes
    flat = [
        (p, c)
        for p in range(len(entries_all))
        for c in range(len(entries_all[p]))
    ]
    _verify_payload_crcs(flat, entries_all, payloads_all, pool)
    jobs = _huff_jobs(flat, entries_all, payloads_all, tables_all)

    huff_syms: dict = {}
    if jobs:
        luts, _ = _stacked_luts(tables_all)
        per_launch = max(1, MAX_BATCH_BYTES // (2 * cb))
        for lo in range(0, len(jobs), per_launch):
            batch = jobs[lo : lo + per_launch]
            syms = _unpack_jobs(batch, entries_all, payloads_all, luts, cb)
            if not device_resident:
                syms = np.asarray(syms)       # one transfer per launch window
            for k, (p, ch) in enumerate(batch):
                huff_syms[(p, ch)] = syms[k]

    # Host work items: every non-HUFF chunk (identical decode + integrity
    # checks to PlaneCodec.decode_into).
    others = [
        (p, c) for (p, c) in flat
        if entries_all[p][c].method != codec.Method.HUFF
    ]
    other_chunks = _decode_other_chunks(others, entries_all, payloads_all, pool)

    if not device_resident:
        planes: List[Any] = []
        for p in range(len(entries_all)):
            entries = entries_all[p]
            total = sum(e.raw_len for e in entries)
            out = np.empty(total, dtype=np.uint8)
            off = 0
            for c, e in enumerate(entries):
                piece = (
                    huff_syms[(p, c)][: e.raw_len]
                    if e.method == codec.Method.HUFF
                    else other_chunks[(p, c)]
                )
                out[off : off + e.raw_len] = piece
                off += e.raw_len
            planes.append(out)
        return planes

    import jax.numpy as jnp

    # Device splice: all host-decoded (non-HUFF) chunk bytes ride ONE
    # upload; per-chunk device slices interleave with the kernel-decoded
    # symbol rows so each plane assembles without a host bounce.
    splice_dev = None
    splice_off: dict = {}
    if others:
        off = 0
        parts = []
        for key in others:
            piece = other_chunks[key]
            splice_off[key] = (off, off + piece.size)
            parts.append(piece)
            off += piece.size
        cat = np.concatenate(parts) if len(parts) > 1 else parts[0]
        _count_payload_upload(cat.nbytes)
        splice_dev = jnp.asarray(cat)
    planes = []
    for p in range(len(entries_all)):
        entries = entries_all[p]
        pieces = []
        for c, e in enumerate(entries):
            if e.method == codec.Method.HUFF:
                pieces.append(huff_syms[(p, c)][: e.raw_len])
            else:
                lo, hi = splice_off[(p, c)]
                pieces.append(splice_dev[lo:hi])
        if not pieces:
            planes.append(np.empty(0, dtype=np.uint8))
        elif len(pieces) == 1:
            planes.append(pieces[0])
        else:
            planes.append(jnp.concatenate(pieces))
    return planes


# ---------------------------------------------------------------------------
# device-resident payload feed
# ---------------------------------------------------------------------------

class PayloadFeed:
    """Device-resident decode plan for one parsed ZNN1 stream.

    :func:`decode_planes` re-reads host payload bytes, re-packs kernel words
    and re-uploads them on *every* call — fine for one-shot restores, wasted
    work for the serving ring, which decodes the same immutable payloads
    every token.  A feed front-loads all of that exactly once:

    * payload CRCs, the ``decode_many``-equivalent bit-cursor / pad-bit
      checks, and the HUFF metadata validation run **at build time** (the
      payloads are immutable once parsed, so one verification covers every
      later decode — and the warmup launch that produces the cursors also
      compiles the dispatch);
    * the packed HUFF words, stacked LUTs and the host-decoded
      ``ZERO``/``STORE``/``ZLIB`` splice bytes upload **once** and stay
      resident in device memory;
    * :meth:`decode` then re-runs the fused kernel directly from those
      resident buffers — **zero host→device payload traffic per decode**
      (asserted via :func:`transfer_stats`), returning device planes
      byte-identical to ``decode_planes(..., device_resident=True)``.

    Residency and caching change wall-clock and memory only, never bytes:
    the kernel consumes the exact words ``_pack_words`` would rebuild, so
    decoded planes cannot differ from the per-call path.
    """

    def __init__(
        self,
        entries_all: Sequence[Sequence[codec.ChunkEntry]],
        payloads_all: Sequence[Sequence[bytes]],
        tables_all: Sequence[Optional[bytes]],
        params: codec.CodecParams,
        pool=None,
    ):
        import jax
        import jax.numpy as jnp

        from repro.kernels import huffdecode

        cb = params.chunk_bytes
        if not supports_decode(cb):
            raise ValueError(
                "device payload feed requires whole-uint32-word chunks "
                f"(chunk_bytes % 4 == 0, got {cb}) and an importable jax"
            )
        self.chunk_bytes = cb
        self._interpret = jax.default_backend() != "tpu"
        # Decode-time assembly needs only (method, raw_len) per chunk; the
        # payload bytes themselves are not retained host-side.
        self._meta = [
            [(int(e.method), int(e.raw_len)) for e in entries]
            for entries in entries_all
        ]

        flat = [
            (p, c)
            for p in range(len(entries_all))
            for c in range(len(entries_all[p]))
        ]
        _verify_payload_crcs(flat, entries_all, payloads_all, pool)
        jobs = _huff_jobs(flat, entries_all, payloads_all, tables_all)

        self._luts = None
        self._windows: List[Tuple[Tuple[Tuple[int, int], ...], Any, Any, Any]] = []
        if jobs:
            luts, _ = _stacked_luts(tables_all)
            self._luts = jnp.asarray(luts)
            per_launch = max(1, MAX_BATCH_BYTES // (2 * cb))
            for lo in range(0, len(jobs), per_launch):
                batch = jobs[lo : lo + per_launch]
                words, pids, counts, sizes = _pack_words(
                    batch, entries_all, payloads_all, cb
                )
                _count_payload_upload(words.nbytes)
                wd = jnp.asarray(words)
                pd = jnp.asarray(pids)
                cd = jnp.asarray(counts)
                # Warmup launch: compiles the dispatch and runs the cursor /
                # pad-bit integrity checks once for the feed's lifetime.
                _syms, cursors = huffdecode.huffdecode_chunks_multi(
                    wd, pd, cd, self._luts,
                    chunk_bytes=cb,
                    interpret=self._interpret,
                )
                cursors_h = np.asarray(jax.device_get(cursors), dtype=np.int64)
                _check_cursors(batch, payloads_all, sizes, cursors_h)
                self._windows.append((tuple(batch), wd, pd, cd))

        others = [
            (p, c) for (p, c) in flat
            if entries_all[p][c].method != codec.Method.HUFF
        ]
        other_chunks = _decode_other_chunks(others, entries_all, payloads_all, pool)
        self._splice = None
        self._splice_off: Dict[Tuple[int, int], Tuple[int, int]] = {}
        if others:
            off = 0
            parts = []
            for key in others:
                piece = other_chunks[key]
                self._splice_off[key] = (off, off + piece.size)
                parts.append(piece)
                off += piece.size
            cat = np.concatenate(parts) if len(parts) > 1 else parts[0]
            _count_payload_upload(cat.nbytes)
            self._splice = jnp.asarray(cat)

    @property
    def n_planes(self) -> int:
        return len(self._meta)

    @property
    def device_bytes(self) -> int:
        """Resident HBM footprint of the feed's payload buffers."""
        total = sum(int(wd.nbytes) for (_, wd, _, _) in self._windows)
        if self._splice is not None:
            total += int(self._splice.nbytes)
        return total

    def decode(self) -> List[Any]:
        """Device planes for this stream, straight from resident buffers.

        Byte-identical to ``decode_planes(..., device_resident=True)`` on
        the same parsed stream; no host payload bytes are touched and no
        payload-sized host→device transfer occurs.
        """
        import jax.numpy as jnp

        from repro.kernels import huffdecode

        huff_syms: dict = {}
        for batch, wd, pd, cd in self._windows:
            # Cursors were integrity-checked at build; the payload words are
            # immutable, so re-checking per decode would re-verify the same
            # bits — drop them without a device→host transfer.
            syms, _cursors = huffdecode.huffdecode_chunks_multi(
                wd, pd, cd, self._luts,
                chunk_bytes=self.chunk_bytes,
                interpret=self._interpret,
            )
            for k, key in enumerate(batch):
                huff_syms[key] = syms[k]

        planes: List[Any] = []
        for p, metas in enumerate(self._meta):
            pieces = []
            for c, (m, raw_len) in enumerate(metas):
                if m == codec.Method.HUFF:
                    pieces.append(huff_syms[(p, c)][:raw_len])
                else:
                    lo, hi = self._splice_off[(p, c)]
                    pieces.append(self._splice[lo:hi])
            if not pieces:
                planes.append(np.empty(0, dtype=np.uint8))
            elif len(pieces) == 1:
                planes.append(pieces[0])
            else:
                planes.append(jnp.concatenate(pieces))
        return planes
