"""ZipNN public API: lossless compression tailored to model weights.

Pipeline per tensor (paper §3):

    raw bytes ──rotate+byte-group──▶ planes ──chunk──▶ probe ──▶ entropy code
                                     │                     │
                                     └ plane 0 = exponent  └ STORE/ZERO/HUFF/ZLIB

Entry points:
  * :func:`compress_array` / :func:`decompress_array` — one numpy/JAX array.
  * :func:`compress_bytes` / :func:`decompress_bytes` — raw streams with an
    explicit dtype interpretation.
  * :func:`compress_pytree` / :func:`decompress_pytree` — whole model /
    optimizer states; returns a manifest + per-leaf blobs.
  * :func:`delta_compress` / :func:`delta_decompress` — §4.2 XOR deltas.
  * :func:`compress_file` / :func:`decompress_file` (re-exported from
    :mod:`.engine`) — bounded-memory streaming over files.

Every entry point takes a ``threads=`` override (default: the config's
``threads`` field).  With N > 1, (plane, chunk) work items fan out across a
shared thread pool (see :mod:`.engine`); output bytes are identical to the
serial path for any thread count.

Every *compression* entry point additionally takes a ``backend=`` override
(default: the config's ``plane_backend``): ``"host"`` runs the rotate/
byte-group/probe front half in numpy, ``"device"`` runs it as one fused
Pallas dispatch with a single device→host transfer of planed buffers +
probe stats (see :mod:`.device_plane`), ``"auto"`` picks device only for
accelerator-resident leaves.  Blobs are byte-identical across backends ×
thread counts — both knobs change wall-clock only.

``backend="device"`` now covers the **entropy stage** too: (plane, chunk)
work items planned as ``HUFF`` bit-pack on device in one fused dispatch
(see :mod:`.device_entropy`) instead of the vectorized host encoder, with
the canonical table still built on host and the expansion guard / container
framing unchanged.  The ``entropy_backend=`` override (also a
``ZipNNConfig`` field) decouples the two stages for mixed mode — e.g.
``backend="host", entropy_backend="device"`` probes on host but packs bits
on device.  The device entropy stage engages only for the canonical
``huffman`` coder; the ``hufflib`` (zlib) coder silently stays host-side.

Every *decompression* entry point takes the same ``backend=`` knob for the
decode back half (see :mod:`.device_unplane`): after the entropy stage
rebuilds the byte-group planes, ``"device"`` uploads them once and runs
un-byte-group + inverse rotate + inverse XOR-delta as one fused Pallas
dispatch; ``"auto"`` picks device only when an accelerator is attached (or
the delta base already lives on one).  Decoded bytes are bit-identical
across backends × thread counts — asserted by ``tests/parity.py``.

The ``entropy_backend=`` knob covers decode too: ``"device"`` decodes the
container's ``HUFF`` chunks in one fused Pallas dispatch (see
:mod:`.device_entropy` / :mod:`repro.kernels.huffdecode`) — only the
*compressed* payload crosses host→device, and when the plane backend is
also device the kernel-decoded symbols feed the fused consumer in place
(no uncompressed-plane upload).  Decode keys off the container, not the
config's coder: any blob with ``HUFF`` chunks qualifies, other blobs
silently stay host-side.  ``decompress_array`` / ``delta_decompress``
additionally take ``device_resident=True`` to keep the restored leaf on
device as a ``jax.Array`` (zero device→host bounce — the
``shard_restore`` path).  Decoded bits are identical across
``backend`` × ``entropy_backend`` × ``threads`` everywhere.

All of the above knobs also ride a single frozen bag: every entry point
takes ``options=CodecOptions(threads=..., backend=..., entropy_backend=...,
device_resident=...)`` (see :mod:`.options`), and :class:`ZipNNSession`
binds a config + options once for the whole surface.  The per-knob kwargs
keep working through a deprecation shim — an explicit legacy kwarg
overrides the options field and warns — and bytes are identical either
way.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import bitlayout, codec, container, engine
from .engine import (             # noqa: F401  (re-exported streaming API)
    CompressWriter,
    DecompressReader,
    compress_file,
    decompress_file,
)
from .options import (            # noqa: F401  (re-exported options API)
    CodecOptions,
    ZipNNSession,
    resolve_options as _resolve_options,
)

__all__ = [
    "ZipNNConfig",
    "CodecOptions",
    "ZipNNSession",
    "CompressedTensor",
    "ArrayFeed",
    "build_array_feed",
    "compress_array",
    "decompress_array",
    "compress_bytes",
    "decompress_bytes",
    "compress_pytree",
    "decompress_pytree",
    "delta_compress",
    "delta_compress_batched",
    "delta_decompress",
    "compress_file",
    "decompress_file",
    "CompressWriter",
    "DecompressReader",
    "compressed_size",
    "ratio",
]


@dataclasses.dataclass
class ZipNNConfig:
    """User-facing knobs (defaults = paper defaults)."""

    chunk_param_bytes: int = 1 << 18     # 256 KiB of parameters per chunk
    # Entropy backend. Both are Huffman-only coders (the ZipNN algorithm);
    # 'hufflib' uses zlib's C Huffman (as the paper used zstd's C Huffman)
    # for production speed, 'huffman' is our from-scratch vectorized
    # canonical coder (algorithm reference + Pallas-kernel oracle).
    backend: str = "hufflib"
    incompressible: float = 0.98
    skip_chunks: int = 8
    zlib_level: int = 6
    # Parallelism: 0/1 = serial, N > 1 = N pool workers, -1 = all cores
    # (the reference implementation's ``max_threads``).  Blob bytes are
    # identical for every setting.
    threads: int = 0
    # Plane-producer backend: 'host' (numpy rotate/split/probe), 'device'
    # (fused Pallas dispatch + single transfer, host fallback when the
    # layout/chunk combination is unsupported), or 'auto' (device only for
    # accelerator-resident jax arrays).  Blob bytes are identical for every
    # setting — see core/device_plane.py.
    plane_backend: str = "host"
    # Entropy-stage backend: None follows plane_backend; 'host' forces the
    # vectorized host Huffman encoder; 'device' bit-packs HUFF chunks as one
    # fused Pallas dispatch (canonical 'huffman' coder only — 'hufflib'
    # always encodes host-side); 'auto' device only for accelerator-resident
    # leaves.  Blob bytes are identical for every setting — see
    # core/device_entropy.py.
    entropy_backend: Optional[str] = None

    def plane_params(self, itemsize: int, delta: bool = False) -> codec.CodecParams:
        return codec.CodecParams(
            chunk_bytes=max(1, self.chunk_param_bytes // max(itemsize, 1)),
            incompressible=self.incompressible,
            skip_chunks=self.skip_chunks,
            delta_mode=delta,
            backend=self.backend,
            zlib_level=self.zlib_level,
        )


DEFAULT = ZipNNConfig()


@dataclasses.dataclass
class CompressedTensor:
    """A compressed leaf: blob + enough info to restore dtype/shape."""

    blob: bytes
    dtype: str
    shape: Tuple[int, ...]

    @property
    def nbytes(self) -> int:
        return len(self.blob)


# ---------------------------------------------------------------------------
# byte-stream compression
# ---------------------------------------------------------------------------

def _resolve_backend(
    backend: Optional[str],
    config: ZipNNConfig,
    layout: bitlayout.BitLayout,
    params: codec.CodecParams,
    leaf: Any = None,
) -> str:
    """Collapse the backend knob to 'host' or 'device' for one leaf."""
    requested = config.plane_backend if backend is None else backend
    if requested == "host":
        return "host"
    from . import device_plane  # lazy: pulls in jax/Pallas

    return device_plane.resolve(requested, layout, params, leaf=leaf)


def _resolve_entropy_backend(
    entropy_backend: Optional[str],
    backend: Optional[str],
    config: ZipNNConfig,
    layout: bitlayout.BitLayout,
    params: codec.CodecParams,
    leaf: Any = None,
) -> str:
    """Collapse the entropy-backend knob to 'host' or 'device' for one leaf.

    Precedence: explicit ``entropy_backend=`` argument, then the config's
    ``entropy_backend`` field, then the plane ``backend`` request — so
    ``backend="device"`` means plane *and* entropy on device unless the
    entropy knob overrides it (mixed mode).
    """
    requested = entropy_backend
    if requested is None:
        requested = config.entropy_backend
    if requested is None:
        requested = config.plane_backend if backend is None else backend
    if requested == "host":
        return "host"
    from . import device_entropy  # lazy: pulls in jax/Pallas

    return device_entropy.resolve(requested, layout, params, leaf=leaf)


def _entropy_stage(
    planes: Sequence[np.ndarray],
    probes: Sequence[Optional[codec.ProbeStats]],
    layout: bitlayout.BitLayout,
    body_bytes: int,
    rem: Optional[np.ndarray],
    params: codec.CodecParams,
    pool,
    delta: bool,
    entropy: str = "host",
) -> bytes:
    """Shared back half of every compression path: (plane, chunk) entropy
    work items + container packing.  ``planes`` may come from the host
    byte-split or the device plane producer; ``probes`` carry the device
    path's precomputed per-chunk statistics (None ⇒ host probe).

    ``entropy="device"`` routes the planned HUFF chunks of all planes
    through one fused bit-pack dispatch (:mod:`.device_entropy`); blobs are
    byte-identical either way."""
    tables: List[Optional[bytes]] = []
    entries: List[List[codec.ChunkEntry]] = []
    payloads: List[List[bytes]] = []
    if entropy == "device" and planes:
        from . import device_entropy

        entries, payloads, tables = device_entropy.encode_planes(
            planes, probes, params, pool=pool
        )
    else:
        for plane, probe in zip(planes, probes):
            e, p, t = codec.compress_plane(plane, params, pool=pool, probe=probe)
            entries.append(e)
            payloads.append(p)
            tables.append(t)
    blob = container.pack_stream(
        layout.name, body_bytes, params.chunk_bytes, tables, entries, payloads,
        delta=delta,
    )
    if rem is not None and rem.size:
        blob += b"TAIL" + bytes(rem)
    return blob


def compress_bytes(
    raw: bytes | np.ndarray,
    dtype_name: str,
    config: ZipNNConfig = DEFAULT,
    *,
    delta: bool = False,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
) -> bytes:
    """Compress a raw little-endian byte stream interpreted as ``dtype_name``."""
    opts = _resolve_options(
        options, threads=threads, backend=backend, entropy_backend=entropy_backend
    )
    threads, backend, entropy_backend = (
        opts.threads, opts.backend, opts.entropy_backend,
    )
    buf = np.frombuffer(raw, dtype=np.uint8) if isinstance(raw, (bytes, memoryview, bytearray)) else np.ascontiguousarray(raw, dtype=np.uint8)
    layout = bitlayout.layout_for(dtype_name)
    tail = buf.size % layout.align
    body, rem = (buf[: buf.size - tail], buf[buf.size - tail :]) if tail else (buf, None)
    pool = engine.get_pool(config.threads if threads is None else threads)
    params = config.plane_params(layout.itemsize, delta)
    if body.size and _resolve_backend(backend, config, layout, params) == "device":
        from . import device_plane

        planes, probes = device_plane.produce_planes(body, layout, params)
    else:
        planes = bitlayout.to_planes(body, layout, pool=pool)
        probes = [None] * len(planes)
    entropy = (
        _resolve_entropy_backend(entropy_backend, backend, config, layout, params)
        if body.size
        else "host"
    )
    return _entropy_stage(
        planes, probes, layout, body.size, rem, params, pool, delta,
        entropy=entropy,
    )


def _resolve_decode_backend(
    backend: Optional[str],
    config: ZipNNConfig,
    layout: bitlayout.BitLayout,
    base: Any = None,
) -> str:
    """Collapse the decode-backend knob to 'host' or 'device'."""
    requested = config.plane_backend if backend is None else backend
    if requested == "host":
        return "host"
    from . import device_unplane  # lazy: pulls in jax/Pallas

    return device_unplane.resolve(requested, layout, base=base)


def _resolve_decode_entropy(
    entropy_backend: Optional[str],
    backend: Optional[str],
    config: ZipNNConfig,
    chunk_bytes: int,
    base: Any = None,
) -> str:
    """Collapse the decode-side entropy knob to 'host' or 'device'.

    Same precedence as the encode side (:func:`_resolve_entropy_backend`):
    explicit argument, then the config field, then the plane ``backend``
    request.  The envelope differs — decode keys off the *container's*
    chunk geometry, not the config's coder, and ``auto`` keys off
    accelerator attachment (or a device-resident delta base) — see
    :func:`repro.core.device_entropy.resolve_decode`.
    """
    requested = entropy_backend
    if requested is None:
        requested = config.entropy_backend
    if requested is None:
        requested = config.plane_backend if backend is None else backend
    if requested == "host":
        return "host"
    from . import device_entropy  # lazy: pulls in jax/Pallas

    return device_entropy.resolve_decode(requested, chunk_bytes, base=base)


def _entropy_decode(
    blob: bytes,
    config: ZipNNConfig,
    pool,
    entropy_backend: Optional[str] = None,
    backend: Optional[str] = None,
    base: Any = None,
    device_resident: Optional[bool] = None,
) -> Tuple[bitlayout.BitLayout, List[Any], bytes]:
    """Shared front half of every decompression path: parse the container
    and entropy-decode every (plane, chunk) payload (CRC-verified work
    items fanned across ``pool``).  Returns ``(layout, planes, tail)`` —
    the byte-group planes still await un-grouping by either backend.

    ``entropy_backend``/``backend`` are the unresolved decode knobs: the
    fused device decoder (:func:`repro.core.device_entropy.decode_planes`)
    engages only when the parsed stream actually has ``HUFF`` chunks and
    the resolution lands on device; everything else (and every fallback)
    decodes through the host work items — bytes identical either way.
    ``device_resident`` asks the device decoder for device-resident plane
    arrays; ``None`` decides from the un-plane backend resolution, so
    kernel-decoded symbols stay on device exactly when the fused consumer
    will eat them in place.
    """
    meta, mv = container.unpack_stream(blob)
    layout = bitlayout.layout_by_name(meta.layout_name)
    params = codec.CodecParams(chunk_bytes=meta.chunk_bytes, backend=config.backend)
    payload_lists = [
        [
            container.payload_view(meta, mv, p, c)
            for c in range(len(meta.entries[p]))
        ]
        for p in range(meta.n_planes)
    ]
    use_device = any(
        e.method == codec.Method.HUFF for pe in meta.entries for e in pe
    ) and _resolve_decode_entropy(
        entropy_backend, backend, config, meta.chunk_bytes, base=base
    ) == "device"
    if use_device:
        from . import device_entropy

        if device_resident is None:
            device_resident = (
                _resolve_decode_backend(backend, config, layout, base=base)
                == "device"
            )
        planes = device_entropy.decode_planes(
            meta.entries, payload_lists, meta.tables, params,
            pool=pool, device_resident=device_resident,
        )
    else:
        planes = [
            codec.decompress_plane(
                meta.entries[p], payload_lists[p], meta.tables[p], params,
                pool=pool,
            )
            for p in range(meta.n_planes)
        ]
    # trailing unaligned bytes
    end = meta.payload_base + sum(
        e.comp_len for pe in meta.entries for e in pe
    )
    tail = blob[end:]
    return layout, planes, (tail[4:] if tail[:4] == b"TAIL" else b"")


def decompress_bytes(
    blob: bytes,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
) -> bytes:
    """Decompress one ZNN1 blob back to its raw little-endian byte stream."""
    opts = _resolve_options(
        options, threads=threads, backend=backend, entropy_backend=entropy_backend
    )
    threads, backend, entropy_backend = (
        opts.threads, opts.backend, opts.entropy_backend,
    )
    pool = engine.get_pool(config.threads if threads is None else threads)
    layout, planes, tail = _entropy_decode(
        blob, config, pool, entropy_backend=entropy_backend, backend=backend
    )
    if (
        planes
        and planes[0].size
        and _resolve_decode_backend(backend, config, layout) == "device"
    ):
        from . import device_unplane

        body = device_unplane.consume_planes(planes, layout)
    else:
        body = bitlayout.from_planes(tuple(planes), layout, pool=pool)
    return body.tobytes() + tail


# ---------------------------------------------------------------------------
# array / pytree compression
# ---------------------------------------------------------------------------

def _to_numpy(arr: Any) -> np.ndarray:
    if hasattr(arr, "addressable_data"):      # jax.Array → host
        arr = np.asarray(arr)
    shape = np.shape(arr)
    # ascontiguousarray promotes 0-d → 1-d; restore the true shape
    return np.ascontiguousarray(arr).reshape(shape)


def _leaf_layout(arr: Any) -> Optional[bitlayout.BitLayout]:
    """Layout for an array-like leaf, or None when it has no ZipNN layout."""
    name = getattr(getattr(arr, "dtype", None), "name", None)
    return bitlayout.LAYOUTS.get(name) if name else None


def _leaf_nbytes(arr: Any) -> int:
    """Raw byte size without forcing a device→host transfer."""
    dt = getattr(arr, "dtype", None)
    if dt is not None:
        return int(np.size(arr)) * np.dtype(dt).itemsize
    return int(np.asarray(arr).nbytes)


def compress_array(
    arr: Any,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
) -> CompressedTensor:
    opts = _resolve_options(
        options, threads=threads, backend=backend, entropy_backend=entropy_backend
    )
    threads, backend, entropy_backend = (
        opts.threads, opts.backend, opts.entropy_backend,
    )
    layout = _leaf_layout(arr)
    if layout is not None and np.size(arr):
        params = config.plane_params(layout.itemsize)
        if _resolve_backend(backend, config, layout, params, leaf=arr) == "device":
            from . import device_plane

            # Device leaves are planed in place: the only device→host
            # transfer is the planed uint8 buffers + probe stats.
            planes, probes = device_plane.produce_planes(arr, layout, params)
            pool = engine.get_pool(config.threads if threads is None else threads)
            n_bytes = int(np.size(arr)) * layout.itemsize
            entropy = _resolve_entropy_backend(
                entropy_backend, backend, config, layout, params, leaf=arr
            )
            blob = _entropy_stage(
                planes, probes, layout, n_bytes, None, params, pool, False,
                entropy=entropy,
            )
            name = arr.dtype.name
            return CompressedTensor(blob, name, tuple(np.shape(arr)))
        # Entropy may still go device (mixed mode): resolve it against the
        # leaf's accelerator residence before the plane request collapses.
        entropy_backend = _resolve_entropy_backend(
            entropy_backend, backend, config, layout, params, leaf=arr
        )
        backend = "host"             # resolved once; don't re-resolve below
    a = _to_numpy(arr)
    blob = compress_bytes(
        a.reshape(-1).view(np.uint8), a.dtype.name, config,
        options=CodecOptions(
            threads=threads, backend=backend, entropy_backend=entropy_backend
        ),
    )
    return CompressedTensor(blob, a.dtype.name, tuple(a.shape))


def _np_dtype(name: str) -> np.dtype:
    import ml_dtypes  # registered with numpy by jax

    return np.dtype(getattr(ml_dtypes, name, name))


def _decompress_array_device(
    ct: CompressedTensor,
    config: ZipNNConfig,
    threads: Optional[int],
    backend: Optional[str],
    entropy_backend: Optional[str],
) -> Optional[Any]:
    """Zero-bounce restore of one leaf: decode on device, stay on device.

    Returns a device-resident ``jax.Array`` (real dtype, real shape) built
    by bitcasting the fused consumer's element output in place — no
    ``device_get``, and with the device entropy stage only the *compressed*
    payload crosses host→device.  Returns ``None`` whenever any part of
    the leaf rides the host path (unsupported layout, empty leaf, tail
    bytes, host-resolved plane backend) — the caller falls back to the
    ordinary numpy restore.
    """
    layout = bitlayout.LAYOUTS.get(ct.dtype)
    if layout is None or not int(np.prod(ct.shape, dtype=np.int64)):
        return None
    if _resolve_decode_backend(backend, config, layout) != "device":
        return None
    pool = engine.get_pool(config.threads if threads is None else threads)
    blob_layout, planes, tail = _entropy_decode(
        ct.blob, config, pool,
        entropy_backend=entropy_backend, backend=backend,
        device_resident=True,
    )
    if tail or blob_layout.name != layout.name or not planes or not planes[0].size:
        return None                        # edge cases ride the host path
    import jax
    import jax.numpy as jnp

    from . import device_unplane

    elems = device_unplane.consume_planes(
        planes, layout, device_resident=True
    )
    return jax.lax.bitcast_convert_type(
        elems, jnp.dtype(_np_dtype(ct.dtype))
    ).reshape(ct.shape)


def decompress_array(
    ct: CompressedTensor,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    device_resident: Optional[bool] = None,
    options: Optional[CodecOptions] = None,
) -> Any:
    """Decompress one leaf back to its dtype/shape.

    Returns numpy by default.  ``device_resident=True`` (kwarg or options
    field) keeps the restored leaf on device as a ``jax.Array`` when the
    decode backend resolves to device (see :func:`_decompress_array_device`)
    — bits identical, zero device→host bounce; host-resolved leaves still
    come back as numpy.
    """
    opts = _resolve_options(
        options, threads=threads, backend=backend,
        entropy_backend=entropy_backend, device_resident=device_resident,
    )
    if opts.device_resident:
        out = _decompress_array_device(
            ct, config, opts.threads, opts.backend, opts.entropy_backend
        )
        if out is not None:
            return out
    raw = decompress_bytes(
        ct.blob, config,
        options=CodecOptions(
            threads=opts.threads, backend=opts.backend,
            entropy_backend=opts.entropy_backend,
        ),
    )
    return np.frombuffer(raw, dtype=_np_dtype(ct.dtype)).reshape(ct.shape).copy()


# ---------------------------------------------------------------------------
# device-resident payload feed (per-leaf)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ArrayFeed:
    """One leaf's device-resident decode plan: blob parsed once, payloads
    resident in device memory, :meth:`decode` re-runs the fused decoder from
    those buffers every call — zero host→device payload traffic per decode
    (see :class:`repro.core.device_entropy.PayloadFeed`).

    Build via :func:`build_array_feed`; residency changes wall-clock and
    memory only — decoded arrays are bit-identical to
    ``decompress_array(ct, device_resident=True)``.
    """

    dtype: str
    shape: Tuple[int, ...]
    _feed: Any
    _layout: bitlayout.BitLayout

    @property
    def device_bytes(self) -> int:
        """Resident HBM footprint of the compressed payload buffers."""
        return self._feed.device_bytes

    def decode(self) -> Any:
        """The restored leaf as a device-resident ``jax.Array``."""
        import jax
        import jax.numpy as jnp

        from . import device_unplane

        planes = self._feed.decode()
        elems = device_unplane.consume_planes(
            planes, self._layout, device_resident=True
        )
        return jax.lax.bitcast_convert_type(
            elems, jnp.dtype(_np_dtype(self.dtype))
        ).reshape(self.shape)


def build_array_feed(
    ct: CompressedTensor,
    config: ZipNNConfig = DEFAULT,
    *,
    options: Optional[CodecOptions] = None,
) -> Optional[ArrayFeed]:
    """Parse one leaf's blob into a device-resident :class:`ArrayFeed`.

    The container parse, CRC + cursor integrity checks, word packing and
    payload upload all happen **here, once**; every later
    :meth:`ArrayFeed.decode` drives the fused decoder + consumer straight
    from device memory.  Returns ``None`` when the leaf cannot ride the
    device path end to end (unsupported layout, empty leaf, tail bytes,
    chunk geometry the kernels cannot decode, or no jax) — callers fall
    back to the per-call decode, which is always available.

    ``options`` carries the thread knob for the build-time host work items
    (non-HUFF chunk decode + CRC fan-out); it cannot change decoded bits.
    """
    opts = _resolve_options(options)
    layout = bitlayout.LAYOUTS.get(ct.dtype)
    if layout is None or not int(np.prod(ct.shape, dtype=np.int64)):
        return None
    from . import device_entropy, device_unplane

    if not device_unplane.supports(layout):
        return None
    meta, mv = container.unpack_stream(ct.blob)
    if meta.layout_name != layout.name:
        return None
    if not device_entropy.supports_decode(meta.chunk_bytes):
        return None
    end = meta.payload_base + sum(e.comp_len for pe in meta.entries for e in pe)
    if ct.blob[end:]:
        return None                            # tail bytes ride the host path
    if not meta.entries or not sum(e.raw_len for e in meta.entries[0]):
        return None
    payload_lists = [
        [
            container.payload_view(meta, mv, p, c)
            for c in range(len(meta.entries[p]))
        ]
        for p in range(meta.n_planes)
    ]
    params = codec.CodecParams(chunk_bytes=meta.chunk_bytes, backend=config.backend)
    pool = engine.get_pool(config.threads if opts.threads is None else opts.threads)
    feed = device_entropy.PayloadFeed(
        meta.entries, payload_lists, meta.tables, params, pool=pool
    )
    return ArrayFeed(ct.dtype, tuple(ct.shape), feed, layout)


def compress_pytree(
    tree: Any,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
) -> Dict[str, Any]:
    """Compress every leaf of a pytree. Returns a manifest dict.

    Chunk-level parallelism applies within each leaf; leaves are walked in
    order so the manifest layout is deterministic.

    With the device backend, same-dtype leaves are packed into **batched
    multi-leaf dispatches** (see :mod:`.device_plane`): one kernel launch +
    one transfer covers many small tensors, so per-leaf dispatch overhead
    does not dominate real model trees.  Blobs per leaf are identical to
    compressing each leaf alone on either backend.
    """
    import jax

    opts = _resolve_options(
        options, threads=threads, backend=backend, entropy_backend=entropy_backend
    )
    threads, backend, entropy_backend = (
        opts.threads, opts.backend, opts.entropy_backend,
    )
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    comp: List[Optional[CompressedTensor]] = [None] * len(leaves)

    requested = config.plane_backend if backend is None else backend
    if requested != "host" and leaves:
        from . import device_plane

        groups: Dict[str, List[int]] = {}
        for i, leaf in enumerate(leaves):
            layout = _leaf_layout(leaf)
            if layout is None or not np.size(leaf):
                continue
            params = config.plane_params(layout.itemsize)
            if device_plane.resolve(requested, layout, params, leaf=leaf) == "device":
                groups.setdefault(leaf.dtype.name, []).append(i)
        pool = engine.get_pool(config.threads if threads is None else threads)
        for name, idxs in groups.items():
            layout = bitlayout.LAYOUTS[name]
            params = config.plane_params(layout.itemsize)
            produced = device_plane.produce_planes_batched(
                [leaves[i] for i in idxs], layout, params
            )
            for i, (planes, probes) in zip(idxs, produced):
                n_bytes = int(np.size(leaves[i])) * layout.itemsize
                entropy = _resolve_entropy_backend(
                    entropy_backend, backend, config, layout, params,
                    leaf=leaves[i],
                )
                blob = _entropy_stage(
                    planes, probes, layout, n_bytes, None, params, pool, False,
                    entropy=entropy,
                )
                comp[i] = CompressedTensor(blob, name, tuple(np.shape(leaves[i])))

    for i, leaf in enumerate(leaves):
        if comp[i] is None:
            # The plane path is host for these leaves, but a 'device'/'auto'
            # request still covers their entropy stage (mixed mode).
            comp[i] = compress_array(
                leaf, config,
                options=CodecOptions(
                    threads=threads, backend="host",
                    entropy_backend=(
                        entropy_backend if entropy_backend is not None else backend
                    ),
                ),
            )
    return {
        "treedef": treedef,
        "leaves": comp,
        "raw_bytes": sum(_leaf_nbytes(l) for l in leaves),
        "comp_bytes": sum(c.nbytes for c in comp),
    }


def decompress_pytree(
    manifest: Dict[str, Any],
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    device_resident: Optional[bool] = None,
    options: Optional[CodecOptions] = None,
) -> Any:
    """Decompress every leaf of a :func:`compress_pytree` manifest.

    With the device backend, same-layout leaves are decoded through
    **batched multi-leaf dispatches** (see :mod:`.device_unplane`): each
    leaf's planes are entropy-decoded (host chunk work items, or the device
    Huffman decoder kernel under ``entropy_backend``), then one fused
    kernel launch + one transfer reconstruct the whole group.  With the
    device entropy stage the decoded planes are already device-resident,
    so only compressed bytes cross host→device.  Decoded arrays are
    bit-identical to decompressing each leaf alone on any backend combo.

    ``device_resident=True`` keeps leaves whose decode resolves to the
    device backend on device as ``jax.Array``\\ s (bitcast straight from the
    batched consumer's element output — zero device→host bounce); leaves
    that ride the host path still come back as numpy.  The compressed-
    resident serving store (:mod:`repro.serve.compressed`) decodes its ring
    slots through exactly this path.
    """
    import jax
    import jax.numpy as jnp

    opts = _resolve_options(
        options, threads=threads, backend=backend,
        entropy_backend=entropy_backend, device_resident=device_resident,
    )
    threads, backend, entropy_backend, device_resident = (
        opts.threads, opts.backend, opts.entropy_backend, opts.device_resident,
    )
    cts: List[CompressedTensor] = manifest["leaves"]
    arrays: List[Optional[Any]] = [None] * len(cts)

    requested = config.plane_backend if backend is None else backend
    if requested != "host" and cts:
        from . import device_plane, device_unplane

        pool = engine.get_pool(config.threads if threads is None else threads)
        groups: Dict[str, List[int]] = {}
        for i, ct in enumerate(cts):
            layout = bitlayout.LAYOUTS.get(ct.dtype)
            if (
                layout is not None
                and device_unplane.resolve(requested, layout) == "device"
            ):
                groups.setdefault(layout.name, []).append(i)
        # Entropy-decode and dispatch one MAX_BATCH_BYTES window at a time:
        # peak host memory is one window of planes + the output arrays, not
        # every leaf's planes at once — the O(window) story of the file API
        # applied to tree restores.
        for name, idxs in groups.items():
            layout = bitlayout.layout_by_name(name)
            win_idx: List[int] = []
            win_planes: List[List[np.ndarray]] = []
            acc = 0

            def flush():
                if device_resident:
                    elems = device_unplane.consume_planes_batched(
                        win_planes, layout, device_resident=True
                    )
                    for i, el in zip(win_idx, elems):
                        arrays[i] = jax.lax.bitcast_convert_type(
                            el, jnp.dtype(_np_dtype(cts[i].dtype))
                        ).reshape(cts[i].shape)
                else:
                    raws = device_unplane.consume_planes_batched(
                        win_planes, layout
                    )
                    for i, raw in zip(win_idx, raws):
                        arrays[i] = (
                            np.frombuffer(raw.tobytes(), dtype=_np_dtype(cts[i].dtype))
                            .reshape(cts[i].shape)
                            .copy()
                        )
                win_idx.clear()
                win_planes.clear()

            for i in idxs:
                blob_layout, planes, tail = _entropy_decode(
                    cts[i].blob, config, pool,
                    entropy_backend=entropy_backend, backend=backend,
                )
                if (
                    tail
                    or blob_layout.name != layout.name
                    or not planes
                    or not planes[0].size
                ):
                    continue                   # edge cases ride the host path
                win_idx.append(i)
                win_planes.append(planes)
                acc += planes[0].size * layout.itemsize
                if acc >= device_plane.MAX_BATCH_BYTES:
                    flush()
                    acc = 0
            if win_idx:
                flush()

    for i, ct in enumerate(cts):
        if arrays[i] is None:
            # Leaves the device batch skipped decode host-planed, but a
            # 'device'/'auto' request still covers their entropy stage.
            arrays[i] = decompress_array(
                ct, config,
                options=CodecOptions(
                    threads=threads, backend="host",
                    entropy_backend=(
                        entropy_backend if entropy_backend is not None else backend
                    ),
                    device_resident=device_resident,
                ),
            )
    return jax.tree_util.tree_unflatten(manifest["treedef"], arrays)


# ---------------------------------------------------------------------------
# delta compression (§4.2)
# ---------------------------------------------------------------------------

def delta_compress(
    new: Any,
    base: Any,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
) -> CompressedTensor:
    """XOR-delta two same-shape tensors and compress the delta stream.

    XOR is used (not subtraction) because it is exactly reversible with no
    extra bits (paper §4.2).  The delta stream is byte-grouped like a normal
    tensor — Fig. 8(b) shows per-byte-group change rates differ, so grouping
    helps deltas too — and the §4.2 Huffman/LZ auto-selection runs per chunk.

    On the device backend the XOR itself is fused into the plane-producer
    dispatch (rotation is a bit permutation, so it commutes with XOR): the
    delta never materializes host-side, only its planes do.
    """
    opts = _resolve_options(
        options, threads=threads, backend=backend, entropy_backend=entropy_backend
    )
    threads, backend, entropy_backend = (
        opts.threads, opts.backend, opts.entropy_backend,
    )
    if np.shape(new) != np.shape(base) or getattr(new, "dtype", None) != getattr(
        base, "dtype", None
    ):
        raise ValueError("delta requires matching shape/dtype")
    layout = _leaf_layout(new)
    if layout is not None and np.size(new):
        params = config.plane_params(layout.itemsize, delta=True)
        if _resolve_backend(backend, config, layout, params, leaf=new) == "device":
            from . import device_plane

            planes, probes = device_plane.produce_planes(
                new, layout, params, base=base
            )
            pool = engine.get_pool(config.threads if threads is None else threads)
            n_bytes = int(np.size(new)) * layout.itemsize
            entropy = _resolve_entropy_backend(
                entropy_backend, backend, config, layout, params, leaf=new
            )
            blob = _entropy_stage(
                planes, probes, layout, n_bytes, None, params, pool, True,
                entropy=entropy,
            )
            return CompressedTensor(blob, new.dtype.name, tuple(np.shape(new)))
        entropy_backend = _resolve_entropy_backend(
            entropy_backend, backend, config, layout, params, leaf=new
        )
        backend = "host"             # resolved once; don't re-resolve below
    a = _to_numpy(new)
    b = _to_numpy(base)
    if a.shape != b.shape or a.dtype != b.dtype:
        raise ValueError("delta requires matching shape/dtype")
    x = np.bitwise_xor(a.reshape(-1).view(np.uint8), b.reshape(-1).view(np.uint8))
    blob = compress_bytes(
        x, a.dtype.name, config, delta=True,
        options=CodecOptions(
            threads=threads, backend=backend, entropy_backend=entropy_backend
        ),
    )
    return CompressedTensor(blob, a.dtype.name, tuple(a.shape))


def delta_compress_batched(
    news: Sequence[Any],
    bases: Sequence[Any],
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    options: Optional[CodecOptions] = None,
) -> List[CompressedTensor]:
    """Delta-compress many ``(new, base)`` pairs; returns blobs in order.

    With the device backend, same-dtype pairs are packed into **batched
    multi-leaf dispatches** through
    :func:`repro.core.device_plane.produce_planes_batched` (``bases=``):
    one fused XOR→rotate+byte-group→probe launch + one transfer covers many
    small tensors — the checkpoint manager's delta-save path.  Blobs per
    pair are identical to calling :func:`delta_compress` one pair at a time
    on either backend.
    """
    opts = _resolve_options(
        options, threads=threads, backend=backend, entropy_backend=entropy_backend
    )
    threads, backend, entropy_backend = (
        opts.threads, opts.backend, opts.entropy_backend,
    )
    if len(news) != len(bases):
        raise ValueError("news and bases must pair 1:1")
    out: List[Optional[CompressedTensor]] = [None] * len(news)

    requested = config.plane_backend if backend is None else backend
    if requested != "host" and news:
        from . import device_plane

        groups: Dict[str, List[int]] = {}
        for i, (a, b) in enumerate(zip(news, bases)):
            layout = _leaf_layout(a)
            if layout is None or not np.size(a):
                continue
            if np.shape(a) != np.shape(b) or getattr(a, "dtype", None) != getattr(
                b, "dtype", None
            ):
                continue                       # host path raises the clean error
            params = config.plane_params(layout.itemsize, delta=True)
            if device_plane.resolve(requested, layout, params, leaf=a) == "device":
                groups.setdefault(a.dtype.name, []).append(i)
        pool = engine.get_pool(config.threads if threads is None else threads)
        for name, idxs in groups.items():
            layout = bitlayout.LAYOUTS[name]
            params = config.plane_params(layout.itemsize, delta=True)
            produced = device_plane.produce_planes_batched(
                [news[i] for i in idxs], layout, params,
                bases=[bases[i] for i in idxs],
            )
            for i, (planes, probes) in zip(idxs, produced):
                n_bytes = int(np.size(news[i])) * layout.itemsize
                entropy = _resolve_entropy_backend(
                    entropy_backend, backend, config, layout, params,
                    leaf=news[i],
                )
                blob = _entropy_stage(
                    planes, probes, layout, n_bytes, None, params, pool, True,
                    entropy=entropy,
                )
                out[i] = CompressedTensor(blob, name, tuple(np.shape(news[i])))

    for i, (a, b) in enumerate(zip(news, bases)):
        if out[i] is None:
            # Pairs the device batch skipped take the host delta path; the
            # entropy stage still follows the request (mixed mode).
            out[i] = delta_compress(
                a, b, config,
                options=CodecOptions(
                    threads=threads, backend="host",
                    entropy_backend=(
                        entropy_backend if entropy_backend is not None else backend
                    ),
                ),
            )
    return out


def delta_decompress(
    ct: CompressedTensor,
    base: Any,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    device_resident: Optional[bool] = None,
    options: Optional[CodecOptions] = None,
) -> Any:
    """Invert :func:`delta_compress`: decode the delta stream and XOR it
    with ``base``.

    On the device backend the inverse XOR is fused into the plane-consumer
    dispatch (see :mod:`.device_unplane`): the decoded planes upload once
    (or, under the device entropy stage, are already device-resident —
    only compressed bytes cross host→device), un-group + inverse-rotate +
    XOR run on device against the base at its device residence, and only
    the reconstructed tensor bytes come back — the delta stream never
    materializes host-side.  ``device_resident=True`` additionally keeps
    the restored tensor on device as a ``jax.Array`` (zero device→host
    bounce) when the decode backend resolves to device; host-resolved
    decodes still return numpy.
    """
    opts = _resolve_options(
        options, threads=threads, backend=backend,
        entropy_backend=entropy_backend, device_resident=device_resident,
    )
    threads, backend, entropy_backend, device_resident = (
        opts.threads, opts.backend, opts.entropy_backend, opts.device_resident,
    )
    base_dtype = getattr(getattr(base, "dtype", None), "name", None)
    if tuple(ct.shape) != tuple(np.shape(base)) or ct.dtype != base_dtype:
        # Same clean contract as delta_compress: a mismatched base would
        # otherwise surface as an opaque numpy broadcast error (host path)
        # or an undefined kernel-shape failure (device path).
        raise ValueError("delta requires matching shape/dtype")
    layout = bitlayout.LAYOUTS.get(getattr(getattr(base, "dtype", None), "name", ""))
    if (
        layout is not None
        and np.size(base)
        and _resolve_decode_backend(backend, config, layout, base=base) == "device"
    ):
        pool = engine.get_pool(config.threads if threads is None else threads)
        blob_layout, planes, tail = _entropy_decode(
            ct.blob, config, pool,
            entropy_backend=entropy_backend, backend=backend, base=base,
        )
        if (
            not tail
            and blob_layout.name == layout.name
            and planes
            and planes[0].size
        ):
            from . import device_unplane

            if device_resident:
                import jax
                import jax.numpy as jnp

                elems = device_unplane.consume_planes(
                    planes, layout, base=base, device_resident=True
                )
                return jax.lax.bitcast_convert_type(
                    elems, jnp.dtype(_np_dtype(ct.dtype))
                ).reshape(ct.shape)
            raw = device_unplane.consume_planes(planes, layout, base=base)
            return (
                np.frombuffer(raw.tobytes(), dtype=_np_dtype(ct.dtype))
                .reshape(ct.shape)
                .copy()
            )
    b = _to_numpy(base)
    x = np.frombuffer(
        # The delta XOR happens host-side here, so the plane decode is
        # pinned to host; the entropy stage still follows the request.
        decompress_bytes(
            ct.blob, config,
            options=CodecOptions(
                threads=threads, backend="host",
                entropy_backend=(
                    entropy_backend if entropy_backend is not None else backend
                ),
            ),
        ),
        dtype=np.uint8,
    )
    raw = np.bitwise_xor(x, b.reshape(-1).view(np.uint8))
    return np.frombuffer(raw.tobytes(), dtype=_np_dtype(ct.dtype)).reshape(ct.shape).copy()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def compressed_size(manifest_or_ct: Any) -> int:
    if isinstance(manifest_or_ct, CompressedTensor):
        return manifest_or_ct.nbytes
    return manifest_or_ct["comp_bytes"]


def ratio(raw_bytes: int, comp_bytes: int) -> float:
    """Compressed size in percent — lower is better (paper's metric)."""
    return 100.0 * comp_bytes / max(raw_bytes, 1)
