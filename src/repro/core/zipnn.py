"""ZipNN public API: lossless compression tailored to model weights.

Pipeline per tensor (paper §3):

    raw bytes ──rotate+byte-group──▶ planes ──chunk──▶ probe ──▶ entropy code
                                     │                     │
                                     └ plane 0 = exponent  └ STORE/ZERO/HUFF/ZLIB

Entry points:
  * :func:`compress_array` / :func:`decompress_array` — one numpy/JAX array.
  * :func:`compress_bytes` / :func:`decompress_bytes` — raw streams with an
    explicit dtype interpretation.
  * :func:`compress_pytree` / :func:`decompress_pytree` — whole model /
    optimizer states; returns a manifest + per-leaf blobs.
  * :func:`delta_compress` / :func:`delta_decompress` — §4.2 XOR deltas.
  * :func:`compress_file` / :func:`decompress_file` (re-exported from
    :mod:`.engine`) — bounded-memory streaming over files.

Every entry point takes a ``threads=`` override (default: the config's
``threads`` field).  With N > 1, (plane, chunk) work items fan out across a
shared thread pool (see :mod:`.engine`); output bytes are identical to the
serial path for any thread count.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from . import bitlayout, codec, container, engine
from .engine import (             # noqa: F401  (re-exported streaming API)
    CompressWriter,
    DecompressReader,
    compress_file,
    decompress_file,
)

__all__ = [
    "ZipNNConfig",
    "CompressedTensor",
    "compress_array",
    "decompress_array",
    "compress_bytes",
    "decompress_bytes",
    "compress_pytree",
    "decompress_pytree",
    "delta_compress",
    "delta_decompress",
    "compress_file",
    "decompress_file",
    "CompressWriter",
    "DecompressReader",
    "compressed_size",
    "ratio",
]


@dataclasses.dataclass
class ZipNNConfig:
    """User-facing knobs (defaults = paper defaults)."""

    chunk_param_bytes: int = 1 << 18     # 256 KiB of parameters per chunk
    # Entropy backend. Both are Huffman-only coders (the ZipNN algorithm);
    # 'hufflib' uses zlib's C Huffman (as the paper used zstd's C Huffman)
    # for production speed, 'huffman' is our from-scratch vectorized
    # canonical coder (algorithm reference + Pallas-kernel oracle).
    backend: str = "hufflib"
    incompressible: float = 0.98
    skip_chunks: int = 8
    zlib_level: int = 6
    # Parallelism: 0/1 = serial, N > 1 = N pool workers, -1 = all cores
    # (the reference implementation's ``max_threads``).  Blob bytes are
    # identical for every setting.
    threads: int = 0

    def plane_params(self, itemsize: int, delta: bool = False) -> codec.CodecParams:
        return codec.CodecParams(
            chunk_bytes=max(1, self.chunk_param_bytes // max(itemsize, 1)),
            incompressible=self.incompressible,
            skip_chunks=self.skip_chunks,
            delta_mode=delta,
            backend=self.backend,
            zlib_level=self.zlib_level,
        )


DEFAULT = ZipNNConfig()


@dataclasses.dataclass
class CompressedTensor:
    """A compressed leaf: blob + enough info to restore dtype/shape."""

    blob: bytes
    dtype: str
    shape: Tuple[int, ...]

    @property
    def nbytes(self) -> int:
        return len(self.blob)


# ---------------------------------------------------------------------------
# byte-stream compression
# ---------------------------------------------------------------------------

def compress_bytes(
    raw: bytes | np.ndarray,
    dtype_name: str,
    config: ZipNNConfig = DEFAULT,
    *,
    delta: bool = False,
    threads: Optional[int] = None,
) -> bytes:
    """Compress a raw little-endian byte stream interpreted as ``dtype_name``."""
    buf = np.frombuffer(raw, dtype=np.uint8) if isinstance(raw, (bytes, memoryview, bytearray)) else np.ascontiguousarray(raw, dtype=np.uint8)
    layout = bitlayout.layout_for(dtype_name)
    tail = buf.size % layout.itemsize
    body, rem = (buf[: buf.size - tail], buf[buf.size - tail :]) if tail else (buf, None)
    pool = engine.get_pool(config.threads if threads is None else threads)
    planes = bitlayout.to_planes(body, layout, pool=pool)
    params = config.plane_params(layout.itemsize, delta)

    tables: List[Optional[bytes]] = []
    entries: List[List[codec.ChunkEntry]] = []
    payloads: List[List[bytes]] = []
    for plane in planes:
        e, p, t = codec.compress_plane(plane, params, pool=pool)
        entries.append(e)
        payloads.append(p)
        tables.append(t)
    blob = container.pack_stream(
        layout.name, body.size, params.chunk_bytes, tables, entries, payloads,
        delta=delta,
    )
    if rem is not None and rem.size:
        blob += b"TAIL" + bytes(rem)
    return blob


def decompress_bytes(
    blob: bytes, config: ZipNNConfig = DEFAULT, *, threads: Optional[int] = None
) -> bytes:
    meta, mv = container.unpack_stream(blob)
    layout = next(l for l in bitlayout.LAYOUTS.values() if l.name == meta.layout_name)
    params = codec.CodecParams(chunk_bytes=meta.chunk_bytes, backend=config.backend)
    pool = engine.get_pool(config.threads if threads is None else threads)
    planes = []
    for p in range(meta.n_planes):
        payload_list = [
            container.payload_view(meta, mv, p, c)
            for c in range(len(meta.entries[p]))
        ]
        planes.append(
            codec.decompress_plane(
                meta.entries[p], payload_list, meta.tables[p], params, pool=pool
            )
        )
    body = bitlayout.from_planes(tuple(planes), layout, pool=pool)
    # trailing unaligned bytes
    end = meta.payload_base + sum(
        e.comp_len for pe in meta.entries for e in pe
    )
    tail = blob[end:]
    if tail[:4] == b"TAIL":
        return body.tobytes() + tail[4:]
    return body.tobytes()


# ---------------------------------------------------------------------------
# array / pytree compression
# ---------------------------------------------------------------------------

def _to_numpy(arr: Any) -> np.ndarray:
    if hasattr(arr, "addressable_data"):      # jax.Array → host
        arr = np.asarray(arr)
    shape = np.shape(arr)
    # ascontiguousarray promotes 0-d → 1-d; restore the true shape
    return np.ascontiguousarray(arr).reshape(shape)


def compress_array(
    arr: Any, config: ZipNNConfig = DEFAULT, *, threads: Optional[int] = None
) -> CompressedTensor:
    a = _to_numpy(arr)
    blob = compress_bytes(
        a.reshape(-1).view(np.uint8), a.dtype.name, config, threads=threads
    )
    return CompressedTensor(blob, a.dtype.name, tuple(a.shape))


def decompress_array(
    ct: CompressedTensor,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
) -> np.ndarray:
    raw = decompress_bytes(ct.blob, config, threads=threads)
    import ml_dtypes  # registered with numpy by jax

    dtype = np.dtype(getattr(ml_dtypes, ct.dtype, ct.dtype))
    return np.frombuffer(raw, dtype=dtype).reshape(ct.shape).copy()


def compress_pytree(
    tree: Any, config: ZipNNConfig = DEFAULT, *, threads: Optional[int] = None
) -> Dict[str, Any]:
    """Compress every leaf of a pytree. Returns a manifest dict.

    Chunk-level parallelism applies within each leaf; leaves are walked in
    order so the manifest layout is deterministic.
    """
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    comp = [compress_array(l, config, threads=threads) for l in leaves]
    return {
        "treedef": treedef,
        "leaves": comp,
        "raw_bytes": sum(int(np.asarray(l).nbytes) for l in leaves),
        "comp_bytes": sum(c.nbytes for c in comp),
    }


def decompress_pytree(
    manifest: Dict[str, Any],
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
) -> Any:
    import jax

    leaves = [decompress_array(c, config, threads=threads) for c in manifest["leaves"]]
    return jax.tree_util.tree_unflatten(manifest["treedef"], leaves)


# ---------------------------------------------------------------------------
# delta compression (§4.2)
# ---------------------------------------------------------------------------

def delta_compress(
    new: Any, base: Any, config: ZipNNConfig = DEFAULT, *, threads: Optional[int] = None
) -> CompressedTensor:
    """XOR-delta two same-shape tensors and compress the delta stream.

    XOR is used (not subtraction) because it is exactly reversible with no
    extra bits (paper §4.2).  The delta stream is byte-grouped like a normal
    tensor — Fig. 8(b) shows per-byte-group change rates differ, so grouping
    helps deltas too — and the §4.2 Huffman/LZ auto-selection runs per chunk.
    """
    a = _to_numpy(new)
    b = _to_numpy(base)
    if a.shape != b.shape or a.dtype != b.dtype:
        raise ValueError("delta requires matching shape/dtype")
    x = np.bitwise_xor(a.reshape(-1).view(np.uint8), b.reshape(-1).view(np.uint8))
    blob = compress_bytes(x, a.dtype.name, config, delta=True, threads=threads)
    return CompressedTensor(blob, a.dtype.name, tuple(a.shape))


def delta_decompress(
    ct: CompressedTensor,
    base: Any,
    config: ZipNNConfig = DEFAULT,
    *,
    threads: Optional[int] = None,
) -> np.ndarray:
    b = _to_numpy(base)
    x = np.frombuffer(decompress_bytes(ct.blob, config, threads=threads), dtype=np.uint8)
    raw = np.bitwise_xor(x, b.reshape(-1).view(np.uint8))
    import ml_dtypes

    dtype = np.dtype(getattr(ml_dtypes, ct.dtype, ct.dtype))
    return np.frombuffer(raw.tobytes(), dtype=dtype).reshape(ct.shape).copy()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def compressed_size(manifest_or_ct: Any) -> int:
    if isinstance(manifest_or_ct, CompressedTensor):
        return manifest_or_ct.nbytes
    return manifest_or_ct["comp_bytes"]


def ratio(raw_bytes: int, comp_bytes: int) -> float:
    """Compressed size in percent — lower is better (paper's metric)."""
    return 100.0 * comp_bytes / max(raw_bytes, 1)
