"""Floating-point bit layouts and the exponent-extraction transform.

ZipNN's first key mechanism (paper §3.1, Fig. 3) is *exponent extraction*:
the exponent bits of each parameter are separated from the sign/fraction
bits so that the highly-skewed exponent distribution can be entropy coded
on its own stream.

For the IEEE-ish layouts used by models::

    FP32:  [ s | e e e e e e e e | f*23 ]          (1, 8, 23)
    BF16:  [ s | e e e e e e e e | f*7  ]          (1, 8, 7)
    FP16:  [ s | e e e e e | f*10 ]                (1, 5, 10)

the exponent does not live on a byte boundary — the sign bit sits above it.
We therefore apply a *rotate-left-by-1* to the underlying uint before byte
splitting.  After rotation the most-significant byte of a BF16/FP32 value is
the pure 8-bit exponent and the sign bit is appended as the LSB of the last
byte.  The rotation is a bijection on the uint domain, hence lossless, and
costs one shift+or per element.

Byte grouping (paper §3.2, Fig. 5) then splits the (rotated) values into
per-byte planes: plane 0 = exponent byte, planes 1..k = fraction bytes.
Each plane is compressed independently.

**Sub-byte layouts (fp8).**  For one-byte floats the exponent field does
not fill a byte, so whole-byte grouping would leave the skewed exponent
bits interleaved with sign/fraction noise in a single plane — order-0
entropy coding gains nothing from a plain rotation (it only permutes the
byte histogram).  fp8 layouts therefore set ``sub_byte``: after the
rotate-left-1 (which parks the exponent at the top of the byte —
``e4m3``: ``[eeee|fffs]``, ``e5m2``: ``[eeeee|ffs]``), *element pairs*
are split at the nibble: plane 0 packs the two high nibbles
(exponent-dominated), plane 1 the two low nibbles (fraction/sign).  The
split is a bijection on byte pairs, hence lossless; bodies align to 2
bytes (``layout.align``), with an odd trailing element riding the
container's ``TAIL`` mechanism.  ``int8`` gets its own whole-byte layout
(no rotation — two's complement already clusters small magnitudes for the
order-0 histogram).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

__all__ = [
    "BitLayout",
    "LAYOUTS",
    "layout_for",
    "layout_by_name",
    "to_planes",
    "from_planes",
    "exponent_view",
]


@dataclasses.dataclass(frozen=True)
class BitLayout:
    """Describes how a parameter dtype maps onto byte-group planes."""

    name: str
    itemsize: int              # bytes per parameter
    uint_dtype: np.dtype       # unsigned container dtype
    sign_bits: int
    exp_bits: int
    frac_bits: int
    rotate: bool               # apply rotate-left-1 so plane0 == exponent
    sub_byte: bool = False     # nibble-split element pairs (fp8 layouts)

    @property
    def total_bits(self) -> int:
        return 8 * self.itemsize

    @property
    def n_planes(self) -> int:
        return 2 if self.sub_byte else self.itemsize

    @property
    def align(self) -> int:
        """Plane-split granule in bytes: bodies must be a multiple of this
        (sub-byte layouts split element *pairs*, so 2 even at itemsize 1)."""
        return 2 if self.sub_byte else self.itemsize


_LAYOUT_FP32 = BitLayout("fp32", 4, np.dtype(np.uint32), 1, 8, 23, True)
_LAYOUT_BF16 = BitLayout("bf16", 2, np.dtype(np.uint16), 1, 8, 7, True)
_LAYOUT_FP16 = BitLayout("fp16", 2, np.dtype(np.uint16), 1, 5, 10, True)
_LAYOUT_FP64 = BitLayout("fp64", 8, np.dtype(np.uint64), 1, 11, 52, True)
# Integer / quantized tensors: plain byte grouping, no rotation (there is no
# exponent; paper §3: "tensors of parameters that contain integers ... hardly
# affect the model compression ratio" — we still byte-group them).
_LAYOUT_U8 = BitLayout("u8", 1, np.dtype(np.uint8), 0, 0, 8, False)
# int8 quantized tensors: identical plane geometry to u8 but carried as a
# distinct layout so corpus/bench rows and container headers name it.
_LAYOUT_I8 = BitLayout("i8", 1, np.dtype(np.uint8), 0, 0, 8, False)
_LAYOUT_I32 = BitLayout("i32", 4, np.dtype(np.uint32), 0, 0, 32, False)
_LAYOUT_I64 = BitLayout("i64", 8, np.dtype(np.uint64), 0, 0, 64, False)
_LAYOUT_U16 = BitLayout("u16", 2, np.dtype(np.uint16), 0, 0, 16, False)
# fp8 (paper-adjacent: the component-compression papers' quantized formats).
# rotate=True parks the exponent at the byte top before the nibble split.
_LAYOUT_F8E4M3 = BitLayout(
    "f8e4", 1, np.dtype(np.uint8), 1, 4, 3, True, sub_byte=True
)
_LAYOUT_F8E5M2 = BitLayout(
    "f8e5", 1, np.dtype(np.uint8), 1, 5, 2, True, sub_byte=True
)

LAYOUTS: Dict[str, BitLayout] = {
    "float32": _LAYOUT_FP32,
    "bfloat16": _LAYOUT_BF16,
    "float16": _LAYOUT_FP16,
    "float64": _LAYOUT_FP64,
    "uint8": _LAYOUT_U8,
    "int8": _LAYOUT_I8,
    "bool": _LAYOUT_U8,
    # ml_dtypes fp8 family: same (sign, exp, frac) geometry per pair; the
    # fn/fnuz bias variants share the bit layout, which is all we touch.
    "float8_e4m3fn": _LAYOUT_F8E4M3,
    "float8_e4m3": _LAYOUT_F8E4M3,
    "float8_e4m3fnuz": _LAYOUT_F8E4M3,
    "float8_e5m2": _LAYOUT_F8E5M2,
    "float8_e5m2fnuz": _LAYOUT_F8E5M2,
    "int32": _LAYOUT_I32,
    "uint32": _LAYOUT_I32,
    "int64": _LAYOUT_I64,
    "uint64": _LAYOUT_I64,
    "int16": _LAYOUT_U16,
    "uint16": _LAYOUT_U16,
}


def layout_for(dtype_name: str) -> BitLayout:
    """Layout for a dtype name ('bfloat16', 'float32', ...)."""
    try:
        return LAYOUTS[dtype_name]
    except KeyError:
        raise ValueError(f"no ZipNN bit layout for dtype {dtype_name!r}") from None


def layout_by_name(layout_name: str) -> BitLayout:
    """Layout for a *layout* name ('bf16', 'fp32', ...) as stored in ZNN1
    container headers.  Unknown names raise ``ValueError`` — a corrupted
    header byte must surface as a clean parse error, not a StopIteration."""
    for layout in LAYOUTS.values():
        if layout.name == layout_name:
            return layout
    raise ValueError(f"unknown ZNN1 layout name {layout_name!r}")


# Rotations run segment-at-a-time into a preallocated output: whole-array
# expressions allocate multi-16MB temps (page-fault churn past the allocator
# cache), and per-segment ufuncs release the GIL so segments fan across the
# engine pool.
_ROT_SEG = 1 << 20      # elements per rotate work item


def _rot1_segmented(u: np.ndarray, bits: int, left: bool, pool) -> np.ndarray:
    out = np.empty_like(u)
    a, b = (1, bits - 1) if left else (bits - 1, 1)

    def seg(i0):
        s = u[i0 : i0 + _ROT_SEG]
        d = out[i0 : i0 + _ROT_SEG]
        np.left_shift(s, a, out=d)
        d |= s >> b

    starts = range(0, u.size, _ROT_SEG)
    if pool is not None and len(starts) > 1:
        list(pool.map(seg, starts))
    else:
        for i0 in starts:
            seg(i0)
    return out


def _rotl1(u: np.ndarray, bits: int, pool=None) -> np.ndarray:
    return _rot1_segmented(u, bits, True, pool)


def _rotr1(u: np.ndarray, bits: int, pool=None) -> np.ndarray:
    return _rot1_segmented(u, bits, False, pool)


def to_planes(
    raw: np.ndarray, layout: BitLayout, pool=None
) -> Tuple[np.ndarray, ...]:
    """Split a flat uint8 buffer of parameters into byte-group planes.

    ``raw`` is the little-endian byte view of the tensor, length divisible by
    ``layout.itemsize``.  Returns ``layout.n_planes`` uint8 arrays, plane 0
    being the (pure, if ``layout.rotate``) exponent byte — most significant
    byte after rotation — matching paper Fig. 3/Fig. 5.

    The per-plane strided gathers are independent memcpy loops (which
    release the GIL), so ``pool`` fans them across threads.
    """
    if raw.dtype != np.uint8:
        raise TypeError("to_planes expects a uint8 byte view")
    if raw.size % layout.align:
        raise ValueError(
            f"buffer of {raw.size} bytes is not a multiple of align {layout.align}"
        )
    if layout.sub_byte:
        u = raw
        if layout.rotate:
            u = _rotl1(np.ascontiguousarray(u), 8, pool)
        pairs = u.reshape(-1, 2)
        hi = ((pairs[:, 0] & 0xF0) | (pairs[:, 1] >> 4)).astype(np.uint8)
        lo = (((pairs[:, 0] & 0x0F) << 4) | (pairs[:, 1] & 0x0F)).astype(np.uint8)
        return (np.ascontiguousarray(hi), np.ascontiguousarray(lo))
    if layout.itemsize == 1:
        return (np.ascontiguousarray(raw),)
    u = raw.view(layout.uint_dtype)
    if layout.rotate:
        u = _rotl1(u, layout.total_bits, pool)
    # Big-endian byte split: plane 0 = MSB (exponent after rotation).
    # Strided views over the little-endian byte image — one memcpy per plane
    # instead of shift+mask+downcast per plane.
    bytes_le = u.view(np.uint8).reshape(-1, layout.itemsize)
    cols = [layout.itemsize - 1 - i for i in range(layout.itemsize)]
    if pool is not None:
        return tuple(
            pool.map(lambda c: np.ascontiguousarray(bytes_le[:, c]), cols)
        )
    return tuple(np.ascontiguousarray(bytes_le[:, c]) for c in cols)


def from_planes(
    planes: Tuple[np.ndarray, ...], layout: BitLayout, pool=None
) -> np.ndarray:
    """Inverse of :func:`to_planes` — returns the flat uint8 byte view.

    Each plane scatters into its own byte column of the output, so the
    per-plane writes are disjoint and safe to fan across ``pool``.
    """
    if len(planes) != layout.n_planes:
        raise ValueError(f"expected {layout.n_planes} planes, got {len(planes)}")
    if layout.sub_byte:
        hi, lo = planes
        if hi.size != lo.size:
            raise ValueError("sub-byte planes must pair 1:1")
        out = np.empty(hi.size * 2, dtype=np.uint8)
        pairs = out.reshape(-1, 2)
        pairs[:, 0] = (hi & 0xF0) | (lo >> 4)
        pairs[:, 1] = ((hi & 0x0F) << 4) | (lo & 0x0F)
        if layout.rotate:
            out = _rotr1(out, 8, pool)
        return out
    if layout.itemsize == 1:
        return np.ascontiguousarray(planes[0])
    n = planes[0].size
    bytes_le = np.empty((n, layout.itemsize), dtype=np.uint8)

    def scatter(i_p):
        i, p = i_p
        bytes_le[:, layout.itemsize - 1 - i] = p

    if pool is not None:
        list(pool.map(scatter, enumerate(planes)))
    else:
        for ip in enumerate(planes):
            scatter(ip)
    u = bytes_le.reshape(-1).view(layout.uint_dtype)
    if layout.rotate:
        u = _rotr1(u, layout.total_bits, pool)
    return u.view(np.uint8)


def exponent_view(arr: np.ndarray) -> np.ndarray:
    """Return the biased exponent of every element of a float array.

    Used by the Fig. 2 benchmark (exponent histograms) and by entropy probes.
    """
    name = arr.dtype.name
    layout = layout_for(name)
    if layout.exp_bits == 0:
        raise ValueError(f"dtype {name} has no exponent")
    u = np.ascontiguousarray(arr).view(layout.uint_dtype)
    shift = layout.frac_bits
    mask = (1 << layout.exp_bits) - 1
    return ((u >> np.asarray(shift, dtype=u.dtype)) & np.asarray(mask, dtype=u.dtype)).astype(
        np.int32
    )
