"""Baseline compressors the paper compares ZipNN against.

The paper's baseline family is "LZ + entropy" (zstd, zlib) and "fast LZ"
(lz4, snappy).  Offline container has no zstd/lz4 binaries, so:

  * ``zstd``-class LZ+entropy  → zlib level 6        (same family, §2.3)
  * ``zstd -1``-class          → zlib level 1
  * fast-LZ (lz4/snappy) proxy → zlib level 1 w/ Z_FILTERED (match-light)
  * zstd's Huffman-only path   → zlib Z_HUFFMAN_ONLY
  * EE+Zstd (paper Table 3)    → exponent extraction + zlib on each plane

All functions return (compressed_bytes, seconds) so speed tables can be
built uniformly.
"""

from __future__ import annotations

import time
import zlib
from typing import Callable, Dict, Tuple

import numpy as np

from . import bitlayout

__all__ = ["BASELINES", "run_baseline", "ee_zlib"]


def _timed(fn: Callable[[bytes], bytes], data: bytes) -> Tuple[bytes, float]:
    t0 = time.perf_counter()
    out = fn(data)
    return out, time.perf_counter() - t0


def zlib6(data: bytes) -> bytes:
    return zlib.compress(data, 6)


def zlib1(data: bytes) -> bytes:
    return zlib.compress(data, 1)


def huffman_only(data: bytes) -> bytes:
    co = zlib.compressobj(6, zlib.DEFLATED, -15, 9, zlib.Z_HUFFMAN_ONLY)
    return co.compress(data) + co.flush()


def fast_lz(data: bytes) -> bytes:
    co = zlib.compressobj(1, zlib.DEFLATED, -15, 9, zlib.Z_FILTERED)
    return co.compress(data) + co.flush()


def ee_zlib(data: bytes, dtype_name: str, level: int = 6) -> bytes:
    """Exponent-Extraction + zlib per plane (paper Table 3's 'EE+Zstd')."""
    layout = bitlayout.layout_for(dtype_name)
    buf = np.frombuffer(data, dtype=np.uint8)
    tail = buf.size % layout.itemsize
    body = buf[: buf.size - tail] if tail else buf
    planes = bitlayout.to_planes(body, layout)
    blobs = [zlib.compress(p.tobytes(), level) for p in planes]
    out = b"".join(len(b).to_bytes(8, "little") + b for b in blobs)
    if tail:
        out += bytes(buf[buf.size - tail :])
    return out


BASELINES: Dict[str, Callable[[bytes], bytes]] = {
    "zlib": zlib6,
    "zlib-1": zlib1,
    "huffman-only(zlib)": huffman_only,
    "fast-lz": fast_lz,
}


def run_baseline(name: str, data: bytes) -> Tuple[int, float]:
    """Returns (compressed_size_bytes, seconds)."""
    out, dt = _timed(BASELINES[name], data)
    return len(out), dt


def decompress_time(name: str, data: bytes) -> Tuple[bytes, float]:
    comp = BASELINES[name](data)
    t0 = time.perf_counter()
    if name in ("huffman-only(zlib)", "fast-lz"):
        out = zlib.decompress(comp, -15)
    else:
        out = zlib.decompress(comp)
    return out, time.perf_counter() - t0
