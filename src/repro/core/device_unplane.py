"""Device plane-consumer backend for the decompression engine.

Mirror of :mod:`.device_plane`.  The host decompression path rebuilds each
byte-group plane from the entropy stage, then runs two more host passes —
the per-plane byte scatter + inverse rotate (:func:`repro.core.bitlayout.
from_planes`) and, for §4.2 delta streams, the XOR against the base tensor.
For device-bound restores that means the planed uint8 buffers are
materialized, scattered and rotated on the host before the result is
uploaded anyway.

This module instead uploads the entropy-decoded planes **once** and runs
un-byte-group, inverse rotate and inverse XOR-delta in one fused Pallas
dispatch (:func:`repro.kernels.fused_unplane.plane_consumer`), followed by
a single device→host transfer of the reconstructed bytes.  Decoded bytes
are **bit-identical** to the host path for every thread count — the
backend knob changes wall-clock only.

Backend selection (the ``backend`` knob on every decompression entry
point, defaulting to :class:`repro.core.zipnn.ZipNNConfig` ``plane_backend``):

* ``"host"``   — always the numpy path (default).
* ``"device"`` — the fused Pallas path whenever the layout is supported;
  silent host fallback otherwise, so the knob is always safe to set.
* ``"auto"``   — device only when it can pay for the plane upload: a
  non-CPU accelerator is attached, or the delta base is already
  accelerator-resident.  (Encode-side ``auto`` keys off the *leaf*
  residence; decode planes always start host-side after the entropy
  stage, so residence of the hardware/base is the signal here.)

Support envelope: 2- and 4-byte rotated layouts (bf16 / fp16 / fp32).  The
decode side has no histogram stage, so — unlike the producer — there is no
chunk-size constraint.  Everything else falls back to the host path.

Batched multi-leaf dispatch: :func:`consume_planes_batched` concatenates
many same-layout leaves' planes into one padded ``(M, 128)`` grid per
plane index, launches once, and slices per-leaf bytes out of the single
transferred element buffer — per-leaf kernel-launch latency never
dominates real model trees.  Decode needs no chunk alignment between
leaves, only the total row-block pad; zero pad bytes reconstruct to zero
elements and are sliced off.

Zero-bounce composition with the device entropy stage: plane arrays may
already be **device-resident** ``jax.Array``\\ s (the output of
:func:`repro.core.device_entropy.decode_planes` with
``device_resident=True``) — they are concatenated and padded on device
instead of re-uploaded.  :func:`consume_payloads` is the compressed-payload
entry point that chains the two: kernel-decoded symbols feed straight into
the fused un-byte-group/rotate/XOR dispatch, so the only data-sized
host→device transfer is the compressed payload itself.  With
``device_resident=True`` the *output* also stays on device (per-leaf flat
uint16/uint32 element arrays, no ``device_get``), which is what
``CheckpointManager.shard_restore`` consumes for restores that never
round-trip through host memory.
"""

from __future__ import annotations

from typing import Any, List, Optional, Sequence

import numpy as np

from . import bitlayout
from .device_plane import (
    MAX_BATCH_BYTES,
    _dev_elems,
    _on_accelerator,
    is_available,
)

__all__ = [
    "BACKENDS",
    "is_available",
    "supports",
    "resolve",
    "consume_planes",
    "consume_planes_batched",
    "consume_payloads",
]

BACKENDS = ("host", "device", "auto")


def supports(layout: bitlayout.BitLayout) -> bool:
    """Can the fused device path reconstruct bit-identical bytes?

    Requires a rotated 2- or 4-byte layout (the un-group kernels always
    inverse-rotate); no chunk constraint — decode has no histogram stage.
    """
    if not layout.rotate or layout.itemsize not in (2, 4):
        return False
    return is_available()


def _accelerator_attached() -> bool:
    if not is_available():
        return False
    import jax

    try:
        return jax.default_backend() != "cpu"
    except Exception:  # pragma: no cover - defensive
        return False


def resolve(
    requested: Optional[str],
    layout: bitlayout.BitLayout,
    base: Any = None,
) -> str:
    """Collapse a decode-backend request to the concrete path."""
    if requested is None or requested == "host":
        return "host"
    if requested == "device":
        return "device" if supports(layout) else "host"
    if requested == "auto":
        return (
            "device"
            if supports(layout)
            and (_accelerator_attached() or _on_accelerator(base))
            else "host"
        )
    raise ValueError(
        f"unknown plane backend {requested!r}; expected one of {BACKENDS}"
    )


def consume_planes(
    planes: Sequence[Any],
    layout: bitlayout.BitLayout,
    base: Any = None,
    device_resident: bool = False,
) -> Any:
    """Single-leaf convenience wrapper around :func:`consume_planes_batched`.

    ``base`` enables the fused §4.2 inverse XOR-delta path (the
    reconstructed delta is XORed with ``base`` on device, so the delta
    stream never materializes host-side).  Returns the flat uint8 byte
    view — the exact inverse of :func:`repro.core.bitlayout.to_planes` —
    or, with ``device_resident=True``, the flat device-resident
    uint16/uint32 element array (no device→host transfer).
    """
    return consume_planes_batched(
        [planes], layout, bases=None if base is None else [base],
        device_resident=device_resident,
    )[0]


def consume_payloads(
    entries_all: Sequence[Sequence[Any]],
    payloads_all: Sequence[Sequence[bytes]],
    tables_all: Sequence[Optional[bytes]],
    params: Any,
    layout: bitlayout.BitLayout,
    base: Any = None,
    pool=None,
    device_resident: bool = False,
) -> Any:
    """Compressed-payload entry point: decode + consume without a bounce.

    The parsed container's ``HUFF`` payloads decode on device
    (:func:`repro.core.device_entropy.decode_planes`,
    ``device_resident=True``) and the kernel-decoded symbol planes feed
    straight into the fused un-byte-group/rotate/XOR dispatch — the
    compressed payload is the only data-sized host→device transfer
    (STORE/expansion-guard chunks splice in via one side upload).  Returns
    the leaf's flat uint8 bytes, or the device-resident element array with
    ``device_resident=True``.
    """
    from . import device_entropy

    planes = device_entropy.decode_planes(
        entries_all, payloads_all, tables_all, params,
        pool=pool, device_resident=True,
    )
    return consume_planes(
        planes, layout, base=base, device_resident=device_resident
    )


def consume_planes_batched(
    planes_list: Sequence[Sequence[Any]],
    layout: bitlayout.BitLayout,
    bases: Optional[Sequence[Any]] = None,
    device_resident: bool = False,
) -> List[Any]:
    """Pack many leaves' planes into one fused dispatch; return per-leaf bytes.

    All leaves must share ``layout``.  Each plane index is concatenated
    across leaves, the total is zero-padded to the kernel's row-block
    alignment, and a single ``plane_consumer`` launch + a single
    ``jax.device_get`` reconstruct every leaf's raw bytes.  Oversized
    batches split at :data:`~repro.core.device_plane.MAX_BATCH_BYTES`.

    Plane arrays may be host numpy or device-resident ``jax.Array``\\ s
    (the device entropy stage's output) — device planes concatenate on
    device instead of re-uploading.  With ``device_resident=True`` the
    per-leaf results stay on device as flat uint16/uint32 element arrays
    and no ``device_get`` happens at all.
    """
    if bases is not None and len(bases) != len(planes_list):
        raise ValueError("bases must pair 1:1 with planes_list")
    if not planes_list:
        return []
    if not supports(layout):
        raise ValueError(
            f"device plane-consumer backend does not support layout "
            f"{layout.name!r}"
        )
    for planes in planes_list:
        if len(planes) != layout.n_planes:
            raise ValueError(
                f"expected {layout.n_planes} planes, got {len(planes)}"
            )
    sizes = [int(planes[0].size) for planes in planes_list]
    # Split oversized batches up front; recursion depth is 1.
    if len(planes_list) > 1 and sum(sizes) * layout.itemsize > MAX_BATCH_BYTES:
        out: List[np.ndarray] = []
        start, acc = 0, 0
        for i, s in enumerate(sizes):
            nb = s * layout.itemsize
            if acc and acc + nb > MAX_BATCH_BYTES:
                out.extend(
                    consume_planes_batched(
                        planes_list[start:i], layout,
                        None if bases is None else bases[start:i],
                        device_resident=device_resident,
                    )
                )
                start, acc = i, 0
            acc += nb
        out.extend(
            consume_planes_batched(
                planes_list[start:], layout,
                None if bases is None else bases[start:],
                device_resident=device_resident,
            )
        )
        return out

    import jax
    import jax.numpy as jnp

    from repro.kernels import fused_unplane

    total = sum(sizes)
    if total == 0:                               # every leaf empty: no dispatch
        return [np.empty(0, np.uint8) for _ in sizes]
    align = (
        fused_unplane.ALIGN_ELEMS_U16
        if layout.itemsize == 2
        else fused_unplane.ALIGN_ELEMS_U32
    )
    tail = -total % align

    # One upload per plane index: the concatenation of every leaf's plane.
    # Device-resident planes (the fused entropy decoder's output) stay on
    # device — concatenation/padding happen there, never a re-upload.
    dev_planes = []
    for p in range(layout.n_planes):
        parts = [planes[p] for planes in planes_list]
        if any(not isinstance(x, np.ndarray) for x in parts):
            jparts = [
                x if not isinstance(x, np.ndarray)
                else jnp.asarray(np.ascontiguousarray(x))
                for x in parts
            ]
            if tail:
                jparts.append(jnp.zeros(tail, jnp.uint8))
            cat = jparts[0] if len(jparts) == 1 else jnp.concatenate(jparts)
        else:
            nparts = [np.ascontiguousarray(x) for x in parts]
            if tail:
                nparts.append(np.zeros(tail, np.uint8))
            cat = nparts[0] if len(nparts) == 1 else np.concatenate(nparts)
        dev_planes.append(
            jnp.asarray(cat).reshape(-1, fused_unplane.LANES)
        )

    base2 = None
    if bases is not None and any(b is not None for b in bases):
        bparts = []
        for b, s in zip(bases, sizes):
            if s == 0:
                continue
            e = (
                jnp.zeros((s,), dtype=jnp.dtype(layout.uint_dtype))
                if b is None                    # XOR identity
                else _dev_elems(b, layout)
            )
            if e.shape[0] != s:
                raise ValueError("delta base must match the leaf's element count")
            bparts.append(e)
        if tail:
            bparts.append(
                jnp.zeros((tail,), dtype=jnp.dtype(layout.uint_dtype))
            )
        base2 = jnp.concatenate(bparts).reshape(-1, fused_unplane.LANES)

    x2 = fused_unplane.plane_consumer(
        tuple(dev_planes), base2, itemsize=layout.itemsize,
        interpret=jax.default_backend() != "tpu",
    )
    if device_resident:
        # Zero-bounce: per-leaf element slices stay on device for the
        # caller (bitcast to the real dtype / device_put re-shard there).
        elems_dev = x2.reshape(-1)
        out = []
        off = 0
        for s in sizes:
            out.append(elems_dev[off : off + s])
            off += s
        return out
    # The one device→host transfer: reconstructed elements for the batch.
    elems = np.asarray(jax.device_get(x2)).reshape(-1)

    out = []
    off = 0
    for s in sizes:
        if s == 0:
            out.append(np.empty(0, np.uint8))
            continue
        out.append(np.ascontiguousarray(elems[off : off + s]).view(np.uint8))
        off += s
    return out
