"""Device plane-producer backend for the compression engine.

The host compression path runs three pre-entropy passes in numpy — rotate +
byte-group split (:mod:`.bitlayout`), optional XOR delta, and the per-chunk
``np.bincount`` probe — before the (plane, chunk) entropy work items start.
For device-resident pytrees that means a device→host transfer of the *raw*
tensor followed by three more host passes, with the GIL-bound probe
serializing ~15 % of compress time across engine workers.

This module instead runs all three stages **on device in one fused
dispatch** (:func:`repro.kernels.fused_plane.plane_producer`) and performs a
single device→host transfer of the already-planed uint8 buffers plus the
per-chunk probe histograms.  The planes and :class:`~repro.core.codec.ProbeStats`
feed straight into :func:`repro.core.codec.compress_plane`; pass 1 of the
codec then never histograms anything.  Output blobs are **byte-identical**
to the host path for every thread count — the backend knob changes
wall-clock only.

Backend selection (the ``backend`` knob on :class:`repro.core.zipnn.ZipNNConfig`
(``plane_backend``) and on ``compress_array`` / ``compress_pytree`` /
``delta_compress``):

* ``"host"``   — always the numpy path (default).
* ``"device"`` — the fused Pallas path whenever the (layout, chunk-size)
  combination is supported; silent host fallback otherwise, so the knob is
  always safe to set.
* ``"auto"``   — device only for leaves that are already accelerator-
  resident ``jax.Array``\\ s (no upload is ever *added*); host otherwise.

Support envelope: 2- and 4-byte rotated layouts (bf16 / fp16 / fp32) with a
per-plane chunk size that is a whole number of histogram blocks
(``chunk_bytes % 16384 == 0`` — the paper-default 256 KiB parameter chunks
qualify).  Everything else falls back to the host path.

Batched multi-leaf dispatch: real pytrees are dominated by *small* tensors
(biases, norms, embeddings rows) whose per-leaf kernel launch + transfer
latency would swamp the fused win.  :func:`produce_planes_batched` packs
many same-dtype leaves into one padded element grid, launches once, and
slices per-leaf planes/histograms out of the single transferred buffer.
Leaves are padded to whole codec chunks so chunk boundaries never straddle
two leaves; zero padding is invariant under rotate/XOR, so the only
correction is subtracting the pad count from bin 0 of each leaf's final
chunk histogram.
"""

from __future__ import annotations

import math
import os
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from . import bitlayout, codec

__all__ = [
    "BACKENDS",
    "DEFAULT_BATCH_BYTES",
    "PlanedArray",
    "is_available",
    "supports",
    "resolve",
    "produce_planes",
    "produce_planes_batched",
]

BACKENDS = ("host", "device", "auto")

DEFAULT_BATCH_BYTES = 256 << 20


def _batch_bytes_from_env(default: int = DEFAULT_BATCH_BYTES) -> int:
    """Resolve the launch-window cap, honoring ``ZIPNN_MAX_BATCH_BYTES``.

    Real-TPU tuning runs sweep the window without editing source.  The env
    var is read once at import and must be a positive integer (plain or
    ``0x``-prefixed).  Window size is exempt from the determinism rules by
    construction: launches split on per-chunk boundaries, and payload bytes
    are per-chunk, so the cap changes wall-clock and peak memory only —
    never bytes (the same reason the ``threads`` knob is byte-safe).
    """
    raw = os.environ.get("ZIPNN_MAX_BATCH_BYTES")
    if raw is None:
        return default
    try:
        value = int(raw, 0)
    except ValueError:
        raise ValueError(
            f"ZIPNN_MAX_BATCH_BYTES={raw!r} is not an integer byte count"
        ) from None
    if value <= 0:
        raise ValueError(
            f"ZIPNN_MAX_BATCH_BYTES must be positive, got {value}"
        )
    return value


# One batched dispatch is capped so the packed element grid (+ its planes)
# stays comfortably in device memory; larger groups split into several
# launches.  Env-tunable — see _batch_bytes_from_env.
MAX_BATCH_BYTES = _batch_bytes_from_env()


class PlanedArray(np.ndarray):
    """Host plane bytes that also carry their device-resident twin.

    The fused plane producer computes every plane ON DEVICE and downloads a
    host copy for the codec's plan/finalize passes.  Historically the device
    buffer was then dropped, and the entropy stage re-uploaded HUFF-chunk
    symbols it had just downloaded.  ``PlanedArray`` keeps the device copy
    reachable: ``dev_chunks`` is the same plane as a ``(n_chunks,
    chunk_bytes)`` device array (zero-padded final chunk — the exact symbol
    rows the bit-pack kernel consumes), so ``device_entropy._pack_jobs``
    gathers symbols on device instead of re-uploading them.

    Any slice / view / ufunc result drops the device reference
    (``__array_finalize__``): the pairing is only valid for the whole plane.
    """

    def __array_finalize__(self, obj) -> None:
        self.dev_chunks = None


def is_available() -> bool:
    """True when jax (and therefore the Pallas kernels) can be imported."""
    try:
        import jax  # noqa: F401

        return True
    except Exception:  # pragma: no cover - jax is baked into the image
        return False


def supports(layout: bitlayout.BitLayout, params: codec.CodecParams) -> bool:
    """Can the fused device path produce byte-identical planes/probes?

    Requires a rotated 2- or 4-byte layout (the byte-group kernels always
    rotate) and codec chunks that are whole histogram blocks.
    """
    if not layout.rotate or layout.itemsize not in (2, 4):
        return False
    if not is_available():
        return False
    from repro.kernels import fused_plane

    return params.chunk_bytes % fused_plane.CHUNK_ALIGN_BYTES == 0


def _on_accelerator(leaf: Any) -> bool:
    """True when ``leaf`` is a jax.Array living on a non-CPU device."""
    if not is_available():
        return False
    import jax

    if not isinstance(leaf, jax.Array):
        return False
    try:
        return any(d.platform != "cpu" for d in leaf.devices())
    except Exception:
        return False


def resolve(
    requested: Optional[str],
    layout: bitlayout.BitLayout,
    params: codec.CodecParams,
    leaf: Any = None,
) -> str:
    """Collapse a backend request to the concrete path: 'host' or 'device'."""
    if requested is None or requested == "host":
        return "host"
    if requested == "device":
        return "device" if supports(layout, params) else "host"
    if requested == "auto":
        return (
            "device"
            if supports(layout, params) and _on_accelerator(leaf)
            else "host"
        )
    raise ValueError(
        f"unknown plane backend {requested!r}; expected one of {BACKENDS}"
    )


# ---------------------------------------------------------------------------
# element marshalling
# ---------------------------------------------------------------------------


def _dev_elems(buf: Any, layout: bitlayout.BitLayout):
    """``buf`` → flat device array of the layout's uint element dtype.

    Accepts host uint8 byte views, host arrays of a same-width dtype, and
    jax.Arrays (bitcast on device — device-resident leaves are never pulled
    to the host as raw values).
    """
    import jax
    import jax.numpy as jnp

    target = jnp.uint16 if layout.itemsize == 2 else jnp.uint32
    if isinstance(buf, np.ndarray):
        if buf.dtype == np.uint8:
            if buf.size % layout.itemsize:
                raise ValueError(
                    f"byte buffer of {buf.size} is not a multiple of "
                    f"itemsize {layout.itemsize}"
                )
            return jnp.asarray(
                np.ascontiguousarray(buf).view(layout.uint_dtype)
            )
        if buf.dtype.itemsize != layout.itemsize:
            raise TypeError(
                f"dtype {buf.dtype} does not match layout itemsize "
                f"{layout.itemsize}"
            )
        return jnp.asarray(
            np.ascontiguousarray(buf).reshape(-1).view(layout.uint_dtype)
        )
    x = buf.reshape(-1)
    if x.dtype.itemsize != layout.itemsize:
        raise TypeError(
            f"dtype {x.dtype} does not match layout itemsize {layout.itemsize}"
        )
    if x.dtype == target:
        return x
    return jax.lax.bitcast_convert_type(x, target)


# ---------------------------------------------------------------------------
# fused production
# ---------------------------------------------------------------------------

PlanesAndProbes = Tuple[List[np.ndarray], List[Optional[codec.ProbeStats]]]


def produce_planes(
    buf: Any,
    layout: bitlayout.BitLayout,
    params: codec.CodecParams,
    base: Any = None,
) -> PlanesAndProbes:
    """Single-leaf convenience wrapper around :func:`produce_planes_batched`.

    ``base`` enables the fused §4.2 XOR-delta path (``buf ^ base`` is planed
    instead of ``buf``; rotation is a bit permutation, hence XOR-compatible).
    """
    return produce_planes_batched(
        [buf], layout, params, bases=None if base is None else [base]
    )[0]


def produce_planes_batched(
    bufs: Sequence[Any],
    layout: bitlayout.BitLayout,
    params: codec.CodecParams,
    bases: Optional[Sequence[Any]] = None,
) -> List[PlanesAndProbes]:
    """Pack ``bufs`` into one fused dispatch; return per-leaf (planes, probes).

    All leaves must share ``layout``.  Each leaf is zero-padded to a whole
    number of codec chunks, the concatenation is zero-padded to the kernels'
    row-block alignment, and a single ``plane_producer`` launch + a single
    ``jax.device_get`` produce every leaf's uint8 planes and exact per-chunk
    probe histograms.  Oversized batches split at :data:`MAX_BATCH_BYTES`.
    """
    if bases is not None and len(bases) != len(bufs):
        raise ValueError("bases must pair 1:1 with bufs")
    if not bufs:
        return []
    if not supports(layout, params):
        raise ValueError(
            f"device plane backend does not support layout {layout.name!r} "
            f"with chunk_bytes={params.chunk_bytes}"
        )
    # Split oversized batches up front; recursion depth is 1.
    sizes_bytes = [_leaf_nbytes(b, layout) for b in bufs]
    if len(bufs) > 1 and sum(sizes_bytes) > MAX_BATCH_BYTES:
        out: List[PlanesAndProbes] = []
        start, acc = 0, 0
        for i, nb in enumerate(sizes_bytes):
            if acc and acc + nb > MAX_BATCH_BYTES:
                out.extend(
                    produce_planes_batched(
                        bufs[start:i], layout, params,
                        None if bases is None else bases[start:i],
                    )
                )
                start, acc = i, 0
            acc += nb
        out.extend(
            produce_planes_batched(
                bufs[start:], layout, params,
                None if bases is None else bases[start:],
            )
        )
        return out

    import jax
    import jax.numpy as jnp

    from repro.kernels import fused_plane

    cb = params.chunk_bytes                      # elements per (plane) chunk
    align = (
        fused_plane.ALIGN_ELEMS_U16
        if layout.itemsize == 2
        else fused_plane.ALIGN_ELEMS_U32
    )
    total_align = cb * align // math.gcd(cb, align)

    us = [_dev_elems(b, layout) for b in bufs]
    bs = (
        [None if b is None else _dev_elems(b, layout) for b in bases]
        if bases is not None
        else [None] * len(us)
    )
    use_delta = any(b is not None for b in bs)
    sizes = [int(u.shape[0]) for u in us]
    pads = [-s % cb for s in sizes]
    for u, b in zip(us, bs):
        if b is not None and b.shape != u.shape:
            raise ValueError("delta base must match the leaf's element count")

    parts, bparts = [], []
    for u, b, pad in zip(us, bs, pads):
        parts.append(u if pad == 0 else jnp.pad(u, (0, pad)))
        if use_delta:
            if b is None:
                b = jnp.zeros_like(u)            # XOR identity
            bparts.append(b if pad == 0 else jnp.pad(b, (0, pad)))
    total = sum(s + p for s, p in zip(sizes, pads))
    if total == 0:                               # every leaf empty: no dispatch
        return [
            (
                [np.empty(0, np.uint8) for _ in range(layout.n_planes)],
                [None] * layout.n_planes,
            )
            for _ in sizes
        ]
    tail = -total % total_align
    if tail:
        parts.append(jnp.zeros((tail,), dtype=us[0].dtype))
        if use_delta:
            bparts.append(jnp.zeros((tail,), dtype=us[0].dtype))
    x2 = jnp.concatenate(parts).reshape(-1, fused_plane.LANES)
    base2 = (
        jnp.concatenate(bparts).reshape(-1, fused_plane.LANES)
        if use_delta
        else None
    )

    planes2d, hists_dev = fused_plane.plane_producer(
        x2, base2, itemsize=layout.itemsize, chunk_elems=cb,
        interpret=jax.default_backend() != "tpu",
    )
    # The one device→host transfer of the whole batch: planed uint8 buffers
    # + probe histograms together.
    planes_host, hists_host = jax.device_get((planes2d, hists_dev))
    flat = [np.asarray(p).reshape(-1) for p in planes_host]
    flat_dev = [p.reshape(-1) for p in planes2d]   # stays resident on device
    hists = np.asarray(hists_host).astype(np.int64)  # (chunks, n_planes, 256)

    out = []
    off = choff = 0
    for s, pad in zip(sizes, pads):
        if s == 0:
            out.append(
                (
                    [np.empty(0, np.uint8) for _ in range(layout.n_planes)],
                    [None] * layout.n_planes,
                )
            )
            continue
        n_chunks = (s + pad) // cb
        # Host copy drives plan/probe/finalize; the device twin rides along
        # chunk-rowed so the entropy stage never re-uploads HUFF symbols.
        leaf_planes: List[np.ndarray] = []
        for f, fd in zip(flat, flat_dev):
            host = f[off : off + s].view(PlanedArray)
            host.dev_chunks = fd[off : off + s + pad].reshape(n_chunks, cb)
            leaf_planes.append(host)
        leaf_h = hists[choff : choff + n_chunks].copy()
        if pad:
            leaf_h[-1, :, 0] -= pad              # padding is all-zero bytes
        probes: List[Optional[codec.ProbeStats]] = [
            codec.ProbeStats(
                chunk_hists=leaf_h[:, p, :],
                table_hist=codec.table_probe_hist(leaf_planes[p]),
            )
            for p in range(layout.n_planes)
        ]
        out.append((leaf_planes, probes))
        off += s + pad
        choff += n_chunks
    return out


def _leaf_nbytes(buf: Any, layout: bitlayout.BitLayout) -> int:
    if isinstance(buf, np.ndarray) and buf.dtype == np.uint8:
        return buf.size
    size = 1
    for d in np.shape(buf):
        size *= int(d)
    return size * layout.itemsize
