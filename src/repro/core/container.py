"""Binary container (.znn) for one compressed byte stream / tensor.

Layout (little-endian)::

    magic       4s   b'ZNN1'
    version     u16
    flags       u16  bit0: planes-mode, bit1: delta stream
    layout      16s  bit-layout name (padded)
    n_bytes     u64  raw byte length
    chunk_bytes u32  per-plane chunk size
    n_planes    u8
    pad         3x
    -- per plane --
    has_table   u8   (+ 128-byte nibble table when set)
    -- metadata map (n_chunks × n_planes records, chunk-major) --
    method      u8
    comp_len    u32
    crc         u32
    -- payloads, same order, byte-aligned --

The metadata map is the paper's §5.1 "map for the whole model containing
metadata for each byte-group and each chunk": every payload's offset is
computable up front, so any (chunk, plane) can be decompressed independently
and in parallel.
"""

from __future__ import annotations

import struct
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .codec import ChunkEntry

__all__ = ["pack_stream", "unpack_stream", "StreamMeta"]

_MAGIC = b"ZNN1"
_HDR = struct.Struct("<4sHH16sQIB3x")
_REC = struct.Struct("<BII")

FLAG_PLANES = 1
FLAG_DELTA = 2


class StreamMeta:
    """Parsed header + metadata map of a .znn stream."""

    def __init__(
        self,
        layout_name: str,
        n_bytes: int,
        chunk_bytes: int,
        flags: int,
        tables: List[Optional[bytes]],
        entries: List[List[ChunkEntry]],
        payload_offsets: List[List[int]],
        payload_base: int,
    ):
        self.layout_name = layout_name
        self.n_bytes = n_bytes
        self.chunk_bytes = chunk_bytes
        self.flags = flags
        self.tables = tables
        self.entries = entries               # [plane][chunk]
        self.payload_offsets = payload_offsets
        self.payload_base = payload_base

    @property
    def n_planes(self) -> int:
        return len(self.entries)

    @property
    def is_delta(self) -> bool:
        return bool(self.flags & FLAG_DELTA)


def pack_stream(
    layout_name: str,
    n_bytes: int,
    chunk_bytes: int,
    plane_tables: Sequence[Optional[bytes]],
    plane_entries: Sequence[Sequence[ChunkEntry]],
    plane_payloads: Sequence[Sequence[bytes]],
    *,
    delta: bool = False,
) -> bytes:
    """Serialize compressed planes into one blob."""
    n_planes = len(plane_entries)
    flags = FLAG_PLANES | (FLAG_DELTA if delta else 0)
    parts: List[bytes] = [
        _HDR.pack(
            _MAGIC,
            1,
            flags,
            layout_name.encode().ljust(16, b"\x00"),
            n_bytes,
            chunk_bytes,
            n_planes,
        )
    ]
    for t in plane_tables:
        if t is None:
            parts.append(b"\x00")
        else:
            if len(t) != 128:
                raise ValueError(
                    f"plane table must be 128 packed bytes, got {len(t)}"
                )
            parts.append(b"\x01" + t)
    # Metadata map, chunk-major so a prefix read yields a prefix of chunks.
    n_chunks = len(plane_entries[0]) if n_planes else 0
    for c in range(n_chunks):
        for p in range(n_planes):
            e = plane_entries[p][c]
            parts.append(_REC.pack(e.method, e.comp_len, e.crc))
    for c in range(n_chunks):
        for p in range(n_planes):
            parts.append(plane_payloads[p][c])
    return b"".join(parts)


def unpack_stream(blob: bytes) -> Tuple[StreamMeta, memoryview]:
    """Parse header + metadata map; payloads stay as a zero-copy memoryview.

    Corrupt or truncated input raises ``ValueError`` — every size that
    drives a parse loop is bounds-checked against the blob before the loop
    runs, so a flipped header byte can never turn into an unbounded
    allocation, a hang, or a struct error escaping as something unclean.
    """
    mv = memoryview(blob)
    try:
        magic, version, flags, layout_b, n_bytes, chunk_bytes, n_planes = (
            _HDR.unpack_from(mv, 0)
        )
    except struct.error:
        raise ValueError("truncated ZNN1 header") from None
    if magic != _MAGIC:
        raise ValueError("not a ZNN1 stream")
    if version != 1:
        raise ValueError(f"unsupported ZNN version {version}")
    if chunk_bytes <= 0:
        raise ValueError("corrupt ZNN1 header: chunk_bytes must be positive")
    off = _HDR.size
    try:
        layout_name = layout_b.rstrip(b"\x00").decode()
    except UnicodeDecodeError:
        raise ValueError("corrupt ZNN1 header: bad layout name") from None

    tables: List[Optional[bytes]] = []
    for _ in range(n_planes):
        if off >= len(mv):
            raise ValueError("truncated ZNN1 plane-table section")
        has = mv[off]
        off += 1
        if has:
            if off + 128 > len(mv):
                raise ValueError("truncated ZNN1 plane table")
            tables.append(bytes(mv[off : off + 128]))
            off += 128
        else:
            tables.append(None)

    plane_bytes = -(-n_bytes // (chunk_bytes * n_planes)) if n_planes else 0
    n_per_plane = n_bytes // n_planes if n_planes else 0
    n_chunks = -(-n_per_plane // chunk_bytes) if n_per_plane else 0

    if off + n_chunks * n_planes * _REC.size > len(mv):
        raise ValueError("truncated ZNN1 metadata map")
    entries: List[List[ChunkEntry]] = [[] for _ in range(n_planes)]
    for c in range(n_chunks):
        for p in range(n_planes):
            method, comp_len, crc = _REC.unpack_from(mv, off)
            off += _REC.size
            raw = min(chunk_bytes, n_per_plane - c * chunk_bytes)
            entries[p].append(ChunkEntry(method, comp_len, raw, crc))

    payload_offsets: List[List[int]] = [[0] * n_chunks for _ in range(n_planes)]
    cursor = off
    for c in range(n_chunks):
        for p in range(n_planes):
            payload_offsets[p][c] = cursor
            cursor += entries[p][c].comp_len

    del plane_bytes  # (derivable; kept for clarity of the format doc)
    meta = StreamMeta(
        layout_name, n_bytes, chunk_bytes, flags, tables, entries, payload_offsets, off
    )
    return meta, mv


def payload_view(meta: StreamMeta, mv: memoryview, plane: int, chunk: int) -> bytes:
    e = meta.entries[plane][chunk]
    o = meta.payload_offsets[plane][chunk]
    return bytes(mv[o : o + e.comp_len])
