"""Unified codec options: one frozen bag for the knobs every entry point takes.

PRs 1–8 grew the same four keyword arguments — ``threads=``, ``backend=``,
``entropy_backend=`` and (on decode paths) ``device_resident=`` — across
~20 entry points: the :mod:`.zipnn` byte/array/pytree/delta functions, the
streaming engine, the checkpoint manager and hub, grad-sync, and the
serving stores.  Each call site threaded the three codec knobs by hand,
and ``zipnn-lint`` had to police every edge per-kwarg.

:class:`CodecOptions` collapses them into one frozen dataclass that rides
an ``options=`` keyword instead:

    opts = CodecOptions(threads=-1, backend="device")
    blob = zipnn.compress_bytes(raw, "bfloat16", options=opts)

The legacy kwargs keep working through a deprecation shim
(:func:`resolve_options`): an explicit legacy kwarg **overrides** the
corresponding ``options`` field and emits a :class:`DeprecationWarning`.
``None`` fields mean "defer to the ``ZipNNConfig``" exactly as the legacy
``None`` defaults did, so the resolution precedence is unchanged:

    explicit legacy kwarg  >  options field  >  ZipNNConfig field

``device_resident`` also lives on the options bag (it rides the same
calls), but the standalone kwarg is *not* deprecated: it is a semantic
flag — it changes the return type — not a performance knob, and
``docs/INVARIANTS.md`` keeps it outside the byte-identity knob set.

:class:`ZipNNSession` is the facade over the whole surface: bind a config
and an options bag once, then call ``session.compress_pytree(...)`` /
``session.decompress_array(...)`` without re-threading anything.  Bytes
are identical to the legacy per-kwarg calls on every combination — the
options bag only *routes* the same values, which ``tests/test_options.py``
asserts and ``zipnn-lint``'s knob checker enforces statically (an edge
that forwards ``options=`` satisfies all three legacy knobs).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional

__all__ = ["CodecOptions", "DEFAULT_OPTIONS", "resolve_options", "ZipNNSession"]


@dataclasses.dataclass(frozen=True)
class CodecOptions:
    """Per-call codec knobs, unified.

    Every field defaults to "defer to the config" (``None``) so a default
    ``CodecOptions()`` is exactly the legacy no-kwargs call.  The bag is
    frozen and hashable: share one instance across threads, stores and
    sessions freely.

    threads:          0/1 serial, N>1 pool workers, -1 all cores.
    backend:          plane-stage backend — 'host' | 'device' | 'auto'.
    entropy_backend:  entropy-stage backend — None follows ``backend``.
    device_resident:  decode paths only — keep restored leaves on device
                      as ``jax.Array``s (zero device→host bounce).

    Bytes are identical across every setting of the first three — they are
    wall-clock knobs, enforced by ``tests/parity.py`` and zipnn-lint.
    """

    threads: Optional[int] = None
    backend: Optional[str] = None
    entropy_backend: Optional[str] = None
    device_resident: bool = False

    def replace(self, **changes: Any) -> "CodecOptions":
        return dataclasses.replace(self, **changes)


DEFAULT_OPTIONS = CodecOptions()

_LEGACY_MSG = (
    "passing threads=/backend=/entropy_backend= per call is deprecated; "
    "pass options=CodecOptions(...) instead (explicit legacy kwargs still "
    "override the options fields)"
)


def resolve_options(
    options: Optional[CodecOptions] = None,
    *,
    threads: Optional[int] = None,
    backend: Optional[str] = None,
    entropy_backend: Optional[str] = None,
    device_resident: Optional[bool] = None,
    _stacklevel: int = 4,
) -> CodecOptions:
    """Merge legacy per-call kwargs onto an options bag.

    Explicit legacy kwargs win over the corresponding ``options`` field and
    emit one :class:`DeprecationWarning` (the three codec knobs only —
    ``device_resident`` stays a supported standalone flag).  Returns a
    :class:`CodecOptions` whose fields are fully merged; ``None`` fields
    still mean "defer to the ``ZipNNConfig``" downstream.
    """
    if options is None:
        options = DEFAULT_OPTIONS
    legacy: Dict[str, Any] = {}
    if threads is not None:
        legacy["threads"] = threads
    if backend is not None:
        legacy["backend"] = backend
    if entropy_backend is not None:
        legacy["entropy_backend"] = entropy_backend
    if legacy:
        warnings.warn(_LEGACY_MSG, DeprecationWarning, stacklevel=_stacklevel)
    if device_resident is not None:
        legacy["device_resident"] = device_resident
    return dataclasses.replace(options, **legacy) if legacy else options


class ZipNNSession:
    """Bind a :class:`~repro.core.zipnn.ZipNNConfig` + :class:`CodecOptions`
    once; call the whole ZipNN surface without re-threading knobs.

        session = ZipNNSession(options=CodecOptions(backend="device"))
        manifest = session.compress_pytree(params)
        back = session.decompress_pytree(manifest)

    Every method produces bytes identical to the corresponding module-level
    call with the same config/options — the session is pure routing.
    """

    def __init__(
        self,
        config: Optional[Any] = None,
        options: CodecOptions = DEFAULT_OPTIONS,
    ) -> None:
        from . import zipnn  # lazy: zipnn imports this module

        self.config = zipnn.DEFAULT if config is None else config
        self.options = options

    def _opts(self, device_resident: Optional[bool]) -> CodecOptions:
        if device_resident is None:
            return self.options
        return dataclasses.replace(self.options, device_resident=device_resident)

    # -- byte streams -------------------------------------------------------
    def compress_bytes(self, raw: Any, dtype_name: str, *, delta: bool = False) -> bytes:
        from . import zipnn

        return zipnn.compress_bytes(
            raw, dtype_name, self.config, delta=delta, options=self.options
        )

    def decompress_bytes(self, blob: bytes) -> bytes:
        from . import zipnn

        return zipnn.decompress_bytes(blob, self.config, options=self.options)

    # -- arrays / pytrees ---------------------------------------------------
    def compress_array(self, arr: Any) -> "Any":
        from . import zipnn

        return zipnn.compress_array(arr, self.config, options=self.options)

    def decompress_array(
        self, ct: Any, *, device_resident: Optional[bool] = None
    ) -> Any:
        from . import zipnn

        return zipnn.decompress_array(
            ct, self.config, options=self._opts(device_resident)
        )

    def compress_pytree(self, tree: Any) -> Dict[str, Any]:
        from . import zipnn

        return zipnn.compress_pytree(tree, self.config, options=self.options)

    def decompress_pytree(
        self, manifest: Dict[str, Any], *, device_resident: Optional[bool] = None
    ) -> Any:
        from . import zipnn

        return zipnn.decompress_pytree(
            manifest, self.config, options=self._opts(device_resident)
        )

    # -- deltas (§4.2) ------------------------------------------------------
    def delta_compress(self, new: Any, base: Any) -> Any:
        from . import zipnn

        return zipnn.delta_compress(new, base, self.config, options=self.options)

    def delta_compress_batched(self, news: Any, bases: Any) -> Any:
        from . import zipnn

        return zipnn.delta_compress_batched(
            news, bases, self.config, options=self.options
        )

    def delta_decompress(
        self, ct: Any, base: Any, *, device_resident: Optional[bool] = None
    ) -> Any:
        from . import zipnn

        return zipnn.delta_decompress(
            ct, base, self.config, options=self._opts(device_resident)
        )

    # -- streaming files ----------------------------------------------------
    def compress_file(self, src: str, dst: str, dtype_name: str, **kw: Any) -> Any:
        from . import zipnn

        return zipnn.compress_file(
            src, dst, dtype_name, self.config, options=self.options, **kw
        )

    def decompress_file(self, src: str, dst: str, **kw: Any) -> Any:
        from . import zipnn

        return zipnn.decompress_file(src, dst, self.config, options=self.options, **kw)
