"""ZipNN core: lossless compression tailored to AI models (the paper's
primary contribution), plus the baselines it is evaluated against."""

from .bitlayout import BitLayout, LAYOUTS, layout_for, to_planes, from_planes, exponent_view
from .codec import CodecParams, Method, ProbeStats, longest_zero_run
from .engine import (
    CompressWriter,
    DecompressReader,
    compress_file,
    decompress_file,
    get_pool,
    resolve_threads,
)
from .zipnn import (
    ZipNNConfig,
    CompressedTensor,
    compress_array,
    decompress_array,
    compress_bytes,
    decompress_bytes,
    compress_pytree,
    decompress_pytree,
    delta_compress,
    delta_compress_batched,
    delta_decompress,
    ratio,
)
from .stats import byte_entropy, exponent_histogram, plane_report, classify_model
from . import baselines

__all__ = [
    "BitLayout", "LAYOUTS", "layout_for", "to_planes", "from_planes",
    "exponent_view", "CodecParams", "Method", "ProbeStats", "longest_zero_run",
    "CompressWriter", "DecompressReader", "compress_file", "decompress_file",
    "get_pool", "resolve_threads",
    "ZipNNConfig", "CompressedTensor", "compress_array", "decompress_array",
    "compress_bytes", "decompress_bytes", "compress_pytree",
    "decompress_pytree", "delta_compress", "delta_compress_batched",
    "delta_decompress", "ratio",
    "byte_entropy", "exponent_histogram", "plane_report", "classify_model",
    "baselines",
]
