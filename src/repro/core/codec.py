"""Chunked plane codec: methods, auto-detection, per-chunk metadata map.

Implements the paper's §5.1 container semantics:

* fixed-size input chunks (default 256 KiB of parameters → per-plane chunks
  of ``chunk_size // itemsize`` bytes, i.e. 128 KiB for BF16, 64 KiB for
  FP32 — exactly the sizes quoted in the paper);
* independent per-(chunk, plane) payloads + a metadata map so decompression
  parallelizes at both chunk and byte-group granularity;
* compressibility probing with probe-skip (§3.2 "Identifying
  compressibility"): incompressible planes/chunks are stored raw and the
  next ``skip_chunks`` chunks skip the probe;
* per-chunk method auto-selection for delta streams (§4.2 "Auto Detection"):
  Zstd-class LZ beats Huffman when zeros > 90 % of a chunk or a zero run
  exceeds 3 % of the chunk — we implement the same two criteria with zlib as
  the LZ+entropy coder.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import huffman

__all__ = [
    "Method",
    "ChunkEntry",
    "PlaneCodec",
    "CodecParams",
    "ProbeStats",
    "compress_plane",
    "decompress_plane",
    "longest_zero_run",
    "split_ids",
    "table_probe_hist",
]

# Work-item granularity for the thread-pool paths: several batches per
# worker so a slow batch (e.g. one with every HUFF chunk) cannot serialize
# the tail of the schedule.
_BATCHES_PER_WORKER = 4


def split_ids(n_items: int, n_parts: int) -> List[range]:
    """Partition ``range(n_items)`` into ≤ ``n_parts`` contiguous ranges.

    Contiguity keeps each work item operating on one dense slice of the
    plane (cache-friendly) and makes result concatenation order-preserving —
    the pool path's output is byte-identical to the serial path's.
    """
    if n_items <= 0:
        return []
    n_parts = max(1, min(n_parts, n_items))
    step = -(-n_items // n_parts)
    return [range(i, min(i + step, n_items)) for i in range(0, n_items, step)]


def _fan_out(pool, n_items: int, work) -> List:
    """Run ``work(ids)`` over all of ``range(n_items)``, fanning contiguous
    id batches across ``pool`` (serial when ``pool`` is None or trivial).

    Batch results are concatenated in id order — the determinism contract.
    ``work`` may return None for pure side-effect items (disjoint writes);
    the empty list is returned in that case.
    """
    if pool is None or n_items < 2:
        out = work(range(n_items))
        return [] if out is None else list(out)
    workers = getattr(pool, "_max_workers", None) or 1
    batches = split_ids(n_items, workers * _BATCHES_PER_WORKER)
    results = list(pool.map(work, batches))
    if results and results[0] is None:
        return []
    return [x for r in results for x in r]


class Method:
    STORE = 0       # raw bytes
    ZERO = 1        # all-zero chunk: zero-length payload (paper: truncated)
    HUFF = 2        # ZipNN canonical Huffman, shared per-plane table
    ZLIB = 3        # LZ77+Huffman (zlib) — delta / embedding-layer path
    HUFFLIB = 4     # zlib Z_HUFFMAN_ONLY — C-speed Huffman-only backend

    NAMES = {0: "store", 1: "zero", 2: "huff", 3: "zlib", 4: "hufflib"}


@dataclasses.dataclass
class ChunkEntry:
    """Metadata-map record for one (chunk, plane) payload."""

    method: int
    comp_len: int
    raw_len: int
    crc: int


@dataclasses.dataclass
class CodecParams:
    """Tunables for the plane codec (paper defaults)."""

    chunk_bytes: int = 1 << 17          # per-plane chunk (128 KiB, BF16 default)
    incompressible: float = 0.98        # probe threshold: est ratio ⇒ STORE
    skip_chunks: int = 8                # probe-skip run length after a STORE
    delta_mode: bool = False            # enable §4.2 zeros/zero-run criteria
    zeros_frac_zlib: float = 0.90       # zeros fraction ⇒ prefer LZ
    zero_run_frac_zlib: float = 0.03    # longest zero-run fraction ⇒ prefer LZ
    backend: str = "huffman"            # 'huffman' (ours) | 'hufflib' (zlib -2)
    zlib_level: int = 6


def hist256(a: np.ndarray) -> np.ndarray:
    """Byte histogram, chunked.

    ``np.bincount`` casts its input to intp; above ~2^22 elements the temp
    buffer exceeds the allocator cache and per-call page faults make it ~5×
    slower per byte.  Summing sub-2^21 pieces keeps every temp cached.
    """
    if a.size <= (1 << 21):
        return np.bincount(a, minlength=256)
    if not a.flags.c_contiguous or a.size % 2:
        h = np.zeros(256, dtype=np.int64)
        for i in range(0, a.size, 1 << 21):
            h += np.bincount(a[i : i + (1 << 21)], minlength=256)
        return h
    # Count byte *pairs* as uint16 and fold the 256×256 table: skewed model
    # bytes hammer a handful of counters, and pairing halves the
    # store-to-load dependency chains on those hot counters (~2×).
    h = np.zeros(256, dtype=np.int64)
    u16 = a.view(np.uint16)
    for i in range(0, u16.size, 1 << 20):
        c16 = np.bincount(u16[i : i + (1 << 20)], minlength=65536).reshape(256, 256)
        h += c16.sum(axis=0, dtype=np.int64)
        h += c16.sum(axis=1, dtype=np.int64)
    return h


def table_probe_hist(plane: np.ndarray) -> np.ndarray:
    """Smoothed whole-plane histogram used for the Huffman table and the
    §3.1 plane-level probes.

    Built from a strided sample (≤ 4 MiB) with +1 smoothing on large planes
    so every byte value keeps a code; ratio impact is < 0.1 % and the probe
    cost drops ~10× on large planes.  One implementation shared by the host
    path and the device plane-producer backend — the table (and therefore
    every output byte) is identical no matter which backend probed.
    """
    n = plane.size
    if n > (1 << 22):
        stride = n // (1 << 22)
        return hist256(plane[::stride]) * stride + 1
    return hist256(plane) + (1 if n else 0)


@dataclasses.dataclass
class ProbeStats:
    """Externally supplied probe statistics for one plane.

    Produced by the device plane-producer backend (``core.device_plane``):
    the per-chunk histograms come straight off the fused Pallas dispatch, so
    :meth:`PlaneCodec.plan` consumes them without running ``hist256`` /
    ``np.bincount`` at all — the GIL-bound probe disappears from the host
    schedule.  Counts are exact, so the chosen methods (and the output
    bytes) are identical to the host probe's.
    """

    chunk_hists: np.ndarray            # (n_chunks, 256) exact per-chunk counts
    table_hist: np.ndarray             # == table_probe_hist(plane)

    @property
    def n_chunks(self) -> int:
        return int(self.chunk_hists.shape[0])


def longest_zero_run(chunk: np.ndarray) -> int:
    """Length of the longest run of zero bytes (vectorized)."""
    nz = np.flatnonzero(chunk)
    if nz.size == 0:
        return int(chunk.size)
    gaps = np.diff(nz) - 1
    head = int(nz[0])
    tail = int(chunk.size - nz[-1] - 1)
    best = max(head, tail)
    if gaps.size:
        best = max(best, int(gaps.max()))
    return best


def _huffman_only_zlib(data: bytes, level: int) -> bytes:
    co = zlib.compressobj(level, zlib.DEFLATED, -15, 9, zlib.Z_HUFFMAN_ONLY)
    return co.compress(data) + co.flush()


def _zlib(data: bytes, level: int) -> bytes:
    co = zlib.compressobj(level, zlib.DEFLATED, -15)
    return co.compress(data) + co.flush()


def _unzlib(data: bytes, raw_len: int) -> bytes:
    try:
        return zlib.decompress(data, -15, raw_len)
    except zlib.error as e:
        raise IOError(f"corrupt zlib chunk payload: {e}") from None


@dataclasses.dataclass
class PlaneCodec:
    """Compresses one byte-group plane into chunk payloads + metadata map."""

    params: CodecParams
    table: Optional[np.ndarray] = None          # shared canonical lengths
    codes: Optional[np.ndarray] = None

    def build_table(self, plane: np.ndarray) -> None:
        hist = hist256(plane)
        self.table = huffman.code_lengths(hist)
        self.codes = huffman.canonical_codes(self.table)

    def table_blob(self) -> bytes:
        if self.table is None:
            raise RuntimeError("table_blob() called before build_table()")
        return huffman.pack_table(self.table)

    # -- compression ------------------------------------------------------
    #
    # compress() is split into three per-chunk work-item stages so the
    # serial path, the thread-pool path (engine.py), and the streaming file
    # path share ONE implementation:
    #
    #   plan()        pass 1 — per-chunk method selection (sequential: the
    #                 probe-skip state machine carries state across chunks);
    #   encode_ids()  pass 2 — pure batch encoder over an arbitrary subset
    #                 of chunk ids.  Chunk payloads are byte-aligned and
    #                 independent, so any partition of the id space produces
    #                 byte-identical blobs — the invariant that makes the
    #                 pool path deterministic;
    #   finalize()    pass 3 — expansion fallback + metadata map.

    def plan(self, plane: np.ndarray, pool=None, probe: Optional[ProbeStats] = None) -> List[int]:
        """Pass 1: choose a method per chunk (probe + probe-skip logic).

        The per-chunk probe *statistics* (histogram → estimated size, zero
        run) are pure per-chunk work items and fan out across ``pool``; the
        probe-skip state machine that consumes them stays sequential, so the
        chosen methods are identical for any thread count.

        When ``probe`` is supplied (the device plane-producer backend
        already histogrammed every chunk on-accelerator), no histogram is
        computed here at all — the whole pass 1 is a cheap host-side walk
        over precomputed counts, and the chosen methods are identical
        because the counts are exact.
        """
        p = self.params
        n = plane.size
        n_chunks = -(-n // p.chunk_bytes) if n else 0

        # Whole-plane fast path (§3.1): regular-model fraction planes are
        # incompressible — detect once, store raw, skip all per-chunk work.
        # See table_probe_hist() for the sampled-histogram rationale.
        hist = probe.table_hist if probe is not None else table_probe_hist(plane)
        if self.table is None:
            self.table = huffman.code_lengths(hist)
            self.codes = huffman.canonical_codes(self.table)
        hist_mass = max(int(hist.sum()), 1)
        est_plane = huffman.estimate_encoded_bits(hist, self.table) / 8.0
        if probe is not None:
            if probe.n_chunks != n_chunks:
                raise ValueError(
                    f"probe has {probe.n_chunks} chunk histograms, plane has "
                    f"{n_chunks} chunks"
                )
            plane_zero = n > 0 and int(probe.chunk_hists[:, 0].sum()) == n
        else:
            plane_zero = n > 0 and not plane.any()
        plane_incompressible = (
            not p.delta_mode and n > 0 and est_plane / hist_mass >= p.incompressible
        )
        if plane_zero:
            return [Method.ZERO] * n_chunks
        if plane_incompressible:
            return [Method.STORE] * n_chunks

        if probe is not None:
            stats = self._stats_from_probe(plane, probe)
        else:
            stats = _fan_out(
                pool, n_chunks, lambda ids: self._chunk_stats(plane, ids)
            )

        methods: List[int] = []
        skip = 0
        for c in range(n_chunks):
            m = self._method_from_stats(*stats[c], skip)
            if m == Method.STORE and skip == 0:
                skip = p.skip_chunks          # probe fired: skip next chunks
            elif skip > 0:
                skip -= 1
            methods.append(m)
        return methods

    def _chunk_stats(
        self, plane: np.ndarray, ids: Sequence[int]
    ) -> List[Tuple[int, int, int, int]]:
        """Probe work item: (n, zeros, est_bytes, zero_run) per chunk id."""
        p = self.params
        out = []
        for c in ids:
            chunk = plane[c * p.chunk_bytes : (c + 1) * p.chunk_bytes]
            hist = np.bincount(chunk, minlength=256)
            zeros = int(hist[0])
            est = huffman.estimate_encoded_bits(hist, self.table) / 8.0
            zrun = (
                longest_zero_run(chunk)
                if p.delta_mode and 0 < zeros < chunk.size
                else zeros
            )
            out.append((chunk.size, zeros, est, zrun))
        return out

    def _stats_from_probe(
        self, plane: np.ndarray, probe: ProbeStats
    ) -> List[Tuple[int, int, float, int]]:
        """Per-chunk (n, zeros, est_bytes, zero_run) from device histograms.

        Mirrors :meth:`_chunk_stats` exactly, except the counts come from
        ``probe.chunk_hists`` instead of ``np.bincount``.  The zero-run
        statistic (needed only for §4.2 delta chunks that are neither all-
        nor mostly-zero) is not derivable from a histogram, so those chunks
        fall back to the vectorized host scan — same values, same methods.
        """
        p = self.params
        n = plane.size
        out: List[Tuple[int, int, float, int]] = []
        for c in range(probe.n_chunks):
            hist = probe.chunk_hists[c]
            size = min(p.chunk_bytes, n - c * p.chunk_bytes)
            zeros = int(hist[0])
            est = huffman.estimate_encoded_bits(hist, self.table) / 8.0
            zrun = (
                longest_zero_run(plane[c * p.chunk_bytes : (c + 1) * p.chunk_bytes])
                if p.delta_mode and 0 < zeros < size
                else zeros
            )
            out.append((size, zeros, est, zrun))
        return out

    def _method_from_stats(
        self, n: int, zeros: int, est: float, zrun: int, skip: int
    ) -> int:
        """§3.2/§4.2 method selection from precomputed probe statistics."""
        p = self.params
        if zeros == n:
            return Method.ZERO
        if p.delta_mode:
            # §4.2 auto-detection: zeros fraction / longest zero run ⇒ LZ.
            if zeros >= p.zeros_frac_zlib * n:
                return Method.ZLIB
            if zrun >= p.zero_run_frac_zlib * n:
                return Method.ZLIB
        if skip > 0:
            return Method.STORE               # inside a probe-skip run
        if est / n >= p.incompressible:
            return Method.STORE
        return Method.HUFF if p.backend == "huffman" else Method.HUFFLIB

    def encode_ids(
        self, plane: np.ndarray, methods: Sequence[int], ids: Sequence[int]
    ) -> List[bytes]:
        """Pass 2 work item: encode the given chunk ids, in ``ids`` order.

        Pure w.r.t. shared state (the table is read-only), so any number of
        these can run concurrently.  All HUFF chunks of the batch go through
        one vectorized :func:`huffman.encode_chunks` call.
        """
        cb = self.params.chunk_bytes
        huff_blobs = {}
        huff_ids = [c for c in ids if methods[c] == Method.HUFF]
        if huff_ids:
            segs = [plane[c * cb : (c + 1) * cb] for c in huff_ids]
            blobs = huffman.encode_chunks(
                np.concatenate(segs),
                np.asarray([s.size for s in segs]),
                self.table,
                self.codes,
            )
            huff_blobs = dict(zip(huff_ids, blobs))
        out: List[bytes] = []
        for c in ids:
            m = methods[c]
            if m == Method.HUFF:
                out.append(huff_blobs[c])
            elif m == Method.ZERO:
                out.append(b"")
            else:
                out.append(self._encode(plane[c * cb : (c + 1) * cb], m))
        return out

    def finalize(
        self, plane: np.ndarray, methods: List[int], payloads: List[bytes]
    ) -> List[ChunkEntry]:
        """Pass 3: metadata map (+ raw fallback for expansion).

        Mutates ``payloads`` in place where a chunk expanded.
        """
        p = self.params
        n = plane.size
        entries: List[ChunkEntry] = []
        for c in range(len(methods)):
            raw_len = min(p.chunk_bytes, n - c * p.chunk_bytes)
            m, blob = methods[c], payloads[c]
            if m not in (Method.ZERO, Method.STORE) and len(blob) >= raw_len:
                chunk = plane[c * p.chunk_bytes : (c + 1) * p.chunk_bytes]
                m, blob = Method.STORE, chunk.tobytes()
                payloads[c] = blob
            entries.append(
                ChunkEntry(m, len(blob), raw_len, 0 if m == Method.ZERO else zlib.crc32(blob))
            )
        return entries

    def compress(
        self, plane: np.ndarray, pool=None, probe: Optional[ProbeStats] = None
    ) -> Tuple[List[ChunkEntry], List[bytes]]:
        """Compress one plane; ``pool`` (a ThreadPoolExecutor) fans the
        encode work items across threads with deterministic ordering.
        ``probe`` injects device-computed probe statistics (see
        :class:`ProbeStats`) — bytes out are identical either way."""
        methods = self.plan(plane, pool=pool, probe=probe)
        payloads = _fan_out(
            pool, len(methods), lambda ids: self.encode_ids(plane, methods, ids)
        )
        entries = self.finalize(plane, methods, payloads)
        return entries, payloads

    def _choose_method(self, chunk: np.ndarray, skip: int) -> int:
        """Single-chunk probe (stats + selection in one call)."""
        hist = np.bincount(chunk, minlength=256)
        zeros = int(hist[0])
        est = huffman.estimate_encoded_bits(hist, self.table) / 8.0
        zrun = (
            longest_zero_run(chunk)
            if self.params.delta_mode and 0 < zeros < chunk.size
            else zeros
        )
        return self._method_from_stats(chunk.size, zeros, est, zrun, skip)

    def _encode(self, chunk: np.ndarray, method: int) -> bytes:
        if method == Method.ZERO:
            return b""
        if method == Method.STORE:
            return chunk.tobytes()
        if method == Method.HUFF:
            return huffman.encode(chunk, self.table, self.codes)
        if method == Method.ZLIB:
            return _zlib(chunk.tobytes(), self.params.zlib_level)
        if method == Method.HUFFLIB:
            return _huffman_only_zlib(chunk.tobytes(), self.params.zlib_level)
        raise ValueError(f"unknown method {method}")

    # -- decompression ----------------------------------------------------

    def decode_into(
        self,
        out: np.ndarray,
        offs: np.ndarray,
        entries: Sequence[ChunkEntry],
        payloads: Sequence[bytes],
        ids: Sequence[int],
    ) -> None:
        """Decode work item: rebuild the given chunk ids into ``out``.

        Each id writes a disjoint slice of ``out`` so work items are safe to
        run concurrently.  HUFF chunks of a batch decode in lockstep
        (chunk-parallel) through one :func:`huffman.decode_many` call.

        Every payload's CRC (recorded in the metadata map at encode time) is
        verified *before* its bytes reach a decoder, so a flipped payload
        byte raises a clean ``IOError`` instead of feeding garbage to the
        entropy stage — the corruption-fuzz contract.  Verification is part
        of the work item, so it parallelizes with the decode itself.
        """
        for i in ids:
            e = entries[i]
            if e.method == Method.ZERO:
                if e.comp_len or e.crc:
                    raise IOError(
                        "corrupt chunk entry: ZERO chunk with a payload"
                    )
            elif zlib.crc32(payloads[i]) != e.crc:
                raise IOError(f"chunk payload CRC mismatch (chunk {i})")
        huff_idx = [i for i in ids if entries[i].method == Method.HUFF]
        if huff_idx:
            if self.table is None:
                raise IOError("corrupt stream: HUFF chunks but no plane table")
            if any(not payloads[i] and entries[i].raw_len for i in huff_idx):
                raise IOError("corrupt chunk entry: empty HUFF payload")
            decoded = huffman.decode_many(
                [payloads[i] for i in huff_idx],
                [entries[i].raw_len for i in huff_idx],
                self.table,
            )
            for i, d in zip(huff_idx, decoded):
                out[offs[i] : offs[i + 1]] = d

        for i in ids:
            e = entries[i]
            if e.method == Method.HUFF:
                continue
            dst = out[offs[i] : offs[i + 1]]
            if e.method == Method.ZERO:
                dst[:] = 0
            elif e.method == Method.STORE:
                if e.comp_len != e.raw_len:
                    raise IOError(
                        "corrupt chunk entry: STORE length != raw length"
                    )
                dst[:] = np.frombuffer(payloads[i], dtype=np.uint8)
            elif e.method in (Method.ZLIB, Method.HUFFLIB):
                blob = _unzlib(payloads[i], e.raw_len)
                if len(blob) != e.raw_len:
                    raise IOError(
                        "corrupt zlib chunk payload: wrong decoded length"
                    )
                dst[:] = np.frombuffer(blob, dtype=np.uint8)
            else:
                raise ValueError(f"unknown method {e.method}")

    def decompress(
        self, entries: Sequence[ChunkEntry], payloads: Sequence[bytes], pool=None
    ) -> np.ndarray:
        """Rebuild a plane, optionally fanning chunk decodes across a pool."""
        total = sum(e.raw_len for e in entries)
        out = np.empty(total, dtype=np.uint8)
        offs = np.concatenate(
            [[0], np.cumsum([e.raw_len for e in entries])]
        ).astype(np.int64)

        _fan_out(
            pool,
            len(entries),
            lambda ids: self.decode_into(out, offs, entries, payloads, ids),
        )
        return out


def compress_plane(
    plane: np.ndarray,
    params: CodecParams,
    pool=None,
    probe: Optional[ProbeStats] = None,
) -> Tuple[List[ChunkEntry], List[bytes], Optional[bytes]]:
    """One-shot plane compression. Returns (entries, payloads, table_blob).

    ``plane`` may come from anywhere — the host byte-split
    (:func:`repro.core.bitlayout.to_planes`) or the device plane-producer
    backend (:mod:`repro.core.device_plane`); with ``probe`` supplied the
    probe pass consumes precomputed statistics instead of histogramming.
    """
    codec = PlaneCodec(params)
    entries, payloads = codec.compress(plane, pool=pool, probe=probe)
    needs_table = any(e.method == Method.HUFF for e in entries)
    return entries, payloads, (codec.table_blob() if needs_table else None)


def decompress_plane(
    entries: Sequence[ChunkEntry],
    payloads: Sequence[bytes],
    table_blob: Optional[bytes],
    params: CodecParams,
    pool=None,
) -> np.ndarray:
    codec = PlaneCodec(params)
    if table_blob is not None:
        codec.table = huffman.unpack_table(table_blob)
    return codec.decompress(entries, payloads, pool=pool)
