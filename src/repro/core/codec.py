"""Chunked plane codec: methods, auto-detection, per-chunk metadata map.

Implements the paper's §5.1 container semantics:

* fixed-size input chunks (default 256 KiB of parameters → per-plane chunks
  of ``chunk_size // itemsize`` bytes, i.e. 128 KiB for BF16, 64 KiB for
  FP32 — exactly the sizes quoted in the paper);
* independent per-(chunk, plane) payloads + a metadata map so decompression
  parallelizes at both chunk and byte-group granularity;
* compressibility probing with probe-skip (§3.2 "Identifying
  compressibility"): incompressible planes/chunks are stored raw and the
  next ``skip_chunks`` chunks skip the probe;
* per-chunk method auto-selection for delta streams (§4.2 "Auto Detection"):
  Zstd-class LZ beats Huffman when zeros > 90 % of a chunk or a zero run
  exceeds 3 % of the chunk — we implement the same two criteria with zlib as
  the LZ+entropy coder.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import huffman

__all__ = [
    "Method",
    "ChunkEntry",
    "PlaneCodec",
    "CodecParams",
    "compress_plane",
    "decompress_plane",
    "longest_zero_run",
]


class Method:
    STORE = 0       # raw bytes
    ZERO = 1        # all-zero chunk: zero-length payload (paper: truncated)
    HUFF = 2        # ZipNN canonical Huffman, shared per-plane table
    ZLIB = 3        # LZ77+Huffman (zlib) — delta / embedding-layer path
    HUFFLIB = 4     # zlib Z_HUFFMAN_ONLY — C-speed Huffman-only backend

    NAMES = {0: "store", 1: "zero", 2: "huff", 3: "zlib", 4: "hufflib"}


@dataclasses.dataclass
class ChunkEntry:
    """Metadata-map record for one (chunk, plane) payload."""

    method: int
    comp_len: int
    raw_len: int
    crc: int


@dataclasses.dataclass
class CodecParams:
    """Tunables for the plane codec (paper defaults)."""

    chunk_bytes: int = 1 << 17          # per-plane chunk (128 KiB, BF16 default)
    incompressible: float = 0.98        # probe threshold: est ratio ⇒ STORE
    skip_chunks: int = 8                # probe-skip run length after a STORE
    delta_mode: bool = False            # enable §4.2 zeros/zero-run criteria
    zeros_frac_zlib: float = 0.90       # zeros fraction ⇒ prefer LZ
    zero_run_frac_zlib: float = 0.03    # longest zero-run fraction ⇒ prefer LZ
    backend: str = "huffman"            # 'huffman' (ours) | 'hufflib' (zlib -2)
    zlib_level: int = 6


def longest_zero_run(chunk: np.ndarray) -> int:
    """Length of the longest run of zero bytes (vectorized)."""
    nz = np.flatnonzero(chunk)
    if nz.size == 0:
        return int(chunk.size)
    gaps = np.diff(nz) - 1
    head = int(nz[0])
    tail = int(chunk.size - nz[-1] - 1)
    best = max(head, tail)
    if gaps.size:
        best = max(best, int(gaps.max()))
    return best


def _huffman_only_zlib(data: bytes, level: int) -> bytes:
    co = zlib.compressobj(level, zlib.DEFLATED, -15, 9, zlib.Z_HUFFMAN_ONLY)
    return co.compress(data) + co.flush()


def _zlib(data: bytes, level: int) -> bytes:
    co = zlib.compressobj(level, zlib.DEFLATED, -15)
    return co.compress(data) + co.flush()


def _unzlib(data: bytes, raw_len: int) -> bytes:
    return zlib.decompress(data, -15, raw_len)


@dataclasses.dataclass
class PlaneCodec:
    """Compresses one byte-group plane into chunk payloads + metadata map."""

    params: CodecParams
    table: Optional[np.ndarray] = None          # shared canonical lengths
    codes: Optional[np.ndarray] = None

    def build_table(self, plane: np.ndarray) -> None:
        hist = np.bincount(plane, minlength=256)
        self.table = huffman.code_lengths(hist)
        self.codes = huffman.canonical_codes(self.table)

    def table_blob(self) -> bytes:
        assert self.table is not None
        return huffman.pack_table(self.table)

    # -- compression ------------------------------------------------------

    def compress(self, plane: np.ndarray) -> Tuple[List[ChunkEntry], List[bytes]]:
        p = self.params
        n = plane.size
        n_chunks = -(-n // p.chunk_bytes) if n else 0

        # Whole-plane fast path (§3.1): regular-model fraction planes are
        # incompressible — detect once, store raw, skip all per-chunk work.
        # The histogram/table is built from a strided sample (≤ 4 MiB) with
        # +1 smoothing so every byte value keeps a code; ratio impact is
        # < 0.1 % and the probe cost drops ~10× on large planes.
        if n > (1 << 22):
            stride = n // (1 << 22)
            hist = np.bincount(plane[::stride], minlength=256) * stride + 1
        else:
            hist = np.bincount(plane, minlength=256) + (1 if n else 0)
        if self.table is None:
            self.table = huffman.code_lengths(hist)
            self.codes = huffman.canonical_codes(self.table)
        hist_mass = max(int(hist.sum()), 1)
        est_plane = huffman.estimate_encoded_bits(hist, self.table) / 8.0
        plane_zero = n > 0 and not plane.any()
        plane_incompressible = (
            not p.delta_mode and n > 0 and est_plane / hist_mass >= p.incompressible
        )

        # Pass 1: choose a method per chunk (probe + skip logic).
        methods: List[int] = []
        skip = 0
        for c in range(n_chunks):
            chunk = plane[c * p.chunk_bytes : (c + 1) * p.chunk_bytes]
            if plane_zero:
                methods.append(Method.ZERO)
                continue
            if plane_incompressible:
                methods.append(Method.STORE)
                continue
            m = self._choose_method(chunk, skip)
            if m == Method.STORE and skip == 0:
                skip = p.skip_chunks          # probe fired: skip next chunks
            elif skip > 0:
                skip -= 1
            methods.append(m)

        # Pass 2: encode. All HUFF chunks go through one vectorized call.
        payloads: List[bytes] = [b""] * n_chunks
        huff_ids = [c for c in range(n_chunks) if methods[c] == Method.HUFF]
        if huff_ids:
            segs = [
                plane[c * p.chunk_bytes : (c + 1) * p.chunk_bytes] for c in huff_ids
            ]
            blobs = huffman.encode_chunks(
                np.concatenate(segs),
                np.asarray([s.size for s in segs]),
                self.table,
                self.codes,
            )
            for c, b in zip(huff_ids, blobs):
                payloads[c] = b
        for c in range(n_chunks):
            if methods[c] in (Method.HUFF, Method.ZERO):
                continue
            chunk = plane[c * p.chunk_bytes : (c + 1) * p.chunk_bytes]
            payloads[c] = self._encode(chunk, methods[c])

        # Pass 3: metadata map (+ raw fallback for expansion).
        entries: List[ChunkEntry] = []
        for c in range(n_chunks):
            raw_len = min(p.chunk_bytes, n - c * p.chunk_bytes)
            m, blob = methods[c], payloads[c]
            if m != Method.ZERO and len(blob) >= raw_len:
                chunk = plane[c * p.chunk_bytes : (c + 1) * p.chunk_bytes]
                m, blob = Method.STORE, chunk.tobytes()
                payloads[c] = blob
            entries.append(
                ChunkEntry(m, len(blob), raw_len, 0 if m == Method.ZERO else zlib.crc32(blob))
            )
        return entries, payloads

    def _choose_method(self, chunk: np.ndarray, skip: int) -> int:
        p = self.params
        n = chunk.size
        hist = np.bincount(chunk, minlength=256)
        if hist[0] == n:
            return Method.ZERO
        if p.delta_mode:
            # §4.2 auto-detection: zeros fraction / longest zero run ⇒ LZ.
            if hist[0] >= p.zeros_frac_zlib * n:
                return Method.ZLIB
            if longest_zero_run(chunk) >= p.zero_run_frac_zlib * n:
                return Method.ZLIB
        if skip > 0:
            return Method.STORE               # inside a probe-skip run
        est = huffman.estimate_encoded_bits(hist, self.table) / 8.0
        if est / n >= p.incompressible:
            return Method.STORE
        return Method.HUFF if p.backend == "huffman" else Method.HUFFLIB

    def _encode(self, chunk: np.ndarray, method: int) -> bytes:
        if method == Method.ZERO:
            return b""
        if method == Method.STORE:
            return chunk.tobytes()
        if method == Method.HUFF:
            return huffman.encode(chunk, self.table, self.codes)
        if method == Method.ZLIB:
            return _zlib(chunk.tobytes(), self.params.zlib_level)
        if method == Method.HUFFLIB:
            return _huffman_only_zlib(chunk.tobytes(), self.params.zlib_level)
        raise ValueError(f"unknown method {method}")

    # -- decompression ----------------------------------------------------

    def decompress(
        self, entries: Sequence[ChunkEntry], payloads: Sequence[bytes]
    ) -> np.ndarray:
        """Rebuild a plane. HUFF chunks decode in lockstep (chunk-parallel)."""
        total = sum(e.raw_len for e in entries)
        out = np.empty(total, dtype=np.uint8)
        offs = np.concatenate(
            [[0], np.cumsum([e.raw_len for e in entries])]
        ).astype(np.int64)

        huff_idx = [i for i, e in enumerate(entries) if e.method == Method.HUFF]
        if huff_idx:
            assert self.table is not None, "HUFF chunks require a table"
            decoded = huffman.decode_many(
                [payloads[i] for i in huff_idx],
                [entries[i].raw_len for i in huff_idx],
                self.table,
            )
            for i, d in zip(huff_idx, decoded):
                out[offs[i] : offs[i + 1]] = d

        for i, e in enumerate(entries):
            if e.method == Method.HUFF:
                continue
            dst = out[offs[i] : offs[i + 1]]
            if e.method == Method.ZERO:
                dst[:] = 0
            elif e.method == Method.STORE:
                dst[:] = np.frombuffer(payloads[i], dtype=np.uint8)
            elif e.method in (Method.ZLIB, Method.HUFFLIB):
                dst[:] = np.frombuffer(
                    _unzlib(payloads[i], e.raw_len), dtype=np.uint8
                )
            else:
                raise ValueError(f"unknown method {e.method}")
        return out


def compress_plane(
    plane: np.ndarray, params: CodecParams
) -> Tuple[List[ChunkEntry], List[bytes], Optional[bytes]]:
    """One-shot plane compression. Returns (entries, payloads, table_blob)."""
    codec = PlaneCodec(params)
    entries, payloads = codec.compress(plane)
    needs_table = any(e.method == Method.HUFF for e in entries)
    return entries, payloads, (codec.table_blob() if needs_table else None)


def decompress_plane(
    entries: Sequence[ChunkEntry],
    payloads: Sequence[bytes],
    table_blob: Optional[bytes],
    params: CodecParams,
) -> np.ndarray:
    codec = PlaneCodec(params)
    if table_blob is not None:
        codec.table = huffman.unpack_table(table_blob)
    return codec.decompress(entries, payloads)
