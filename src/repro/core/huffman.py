"""Canonical, length-limited Huffman codec over byte alphabets.

ZipNN drops the LZ stage entirely and entropy-codes each byte-group plane
with Huffman codes (paper §3.1, "Huffman only Compression").  This module is
our independent implementation:

* code-length assignment via **package-merge** (optimal length-limited codes,
  max length 15 → every code fits a uint16 and any symbol spans ≤ 2 bytes of
  output), matching DEFLATE/zstd table constraints;
* **canonical** code assignment so the table serializes as 256 4-bit lengths
  (128 bytes);
* a **vectorized two-pass encoder** (lengths → exclusive prefix sum of bit
  offsets → scatter code bits → packbits).  This is the same formulation the
  Pallas TPU kernel uses (kernels/bitpack.py): TPUs have no serial bit I/O,
  so the parallel prefix-sum form is the hardware-appropriate one;
* a **lockstep chunk-parallel decoder**: all chunks of a stream decode in
  SIMD lockstep, one symbol per iteration across every chunk.  This mirrors
  the paper's §5.1 design where the per-chunk metadata map makes
  decompression embarrassingly parallel.
"""

from __future__ import annotations

import heapq
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "MAX_CODE_LEN",
    "code_lengths",
    "canonical_codes",
    "pack_table",
    "unpack_table",
    "encode",
    "encode_chunks",
    "decode",
    "decode_many",
    "estimate_encoded_bits",
]

MAX_CODE_LEN = 15


# ---------------------------------------------------------------------------
# Code construction
# ---------------------------------------------------------------------------

def _plain_huffman_lengths(freqs: np.ndarray) -> np.ndarray:
    """Unlimited-depth Huffman code lengths via the classic heap algorithm."""
    lens = np.zeros(256, dtype=np.int64)
    syms = np.nonzero(freqs)[0]
    if syms.size == 0:
        return lens
    if syms.size == 1:
        lens[syms[0]] = 1
        return lens
    # heap of (weight, tiebreak, [symbols])
    heap: List[Tuple[int, int, List[int]]] = [
        (int(freqs[s]), int(s), [int(s)]) for s in syms
    ]
    heapq.heapify(heap)
    tie = 256
    while len(heap) > 1:
        w1, _, s1 = heapq.heappop(heap)
        w2, _, s2 = heapq.heappop(heap)
        for s in s1:
            lens[s] += 1
        for s in s2:
            lens[s] += 1
        heapq.heappush(heap, (w1 + w2, tie, s1 + s2))
        tie += 1
    return lens


def _kraft_fixup(lens: np.ndarray, max_len: int) -> np.ndarray:
    """Clamp code lengths to ``max_len`` and restore the Kraft equality.

    Standard zlib-style adjustment: clamp, then while the Kraft sum exceeds
    one, deepen the shallowest clamp-violating leaves; finally shorten codes
    while slack remains (keeps optimality loss negligible, guarantees a
    decodable prefix code).
    """
    lens = lens.copy()
    over = lens > max_len
    if not over.any():
        return lens
    lens[over] = max_len
    # Kraft sum in units of 2^-max_len.
    unit = 1 << max_len
    used = np.nonzero(lens)[0]
    kraft = int(sum(unit >> int(lens[s]) for s in used))
    # Deepen nodes (increase length ⇒ decrease Kraft contribution).
    order = sorted(used, key=lambda s: (lens[s], s), reverse=True)
    i = 0
    while kraft > unit:
        s = order[i % len(order)]
        if lens[s] < max_len:
            kraft -= (unit >> int(lens[s])) - (unit >> int(lens[s] + 1))
            lens[s] += 1
        i += 1
    # Tighten: give back slack to the most frequent long codes.
    for s in sorted(used, key=lambda s: (-lens[s], s)):
        while lens[s] > 1 and kraft + (unit >> int(lens[s])) <= unit:
            kraft += unit >> int(lens[s])
            lens[s] -= 1
    return lens


def code_lengths(freqs: np.ndarray, max_len: int = MAX_CODE_LEN) -> np.ndarray:
    """Length-limited Huffman code lengths for a 256-symbol alphabet."""
    freqs = np.asarray(freqs, dtype=np.int64)
    if freqs.shape != (256,):
        raise ValueError("freqs must have shape (256,)")
    lens = _plain_huffman_lengths(freqs)
    if lens.max(initial=0) > max_len:
        lens = _kraft_fixup(lens, max_len)
    return lens


def canonical_codes(lens: np.ndarray) -> np.ndarray:
    """Canonical code values (uint16) for given lengths: sorted by (len, sym)."""
    lens = np.asarray(lens, dtype=np.int64)
    codes = np.zeros(256, dtype=np.uint16)
    code = 0
    prev_len = 0
    order = sorted(np.nonzero(lens)[0], key=lambda s: (lens[s], s))
    for s in order:
        code <<= int(lens[s]) - prev_len
        codes[s] = code
        code += 1
        prev_len = int(lens[s])
    return codes


def pack_table(lens: np.ndarray) -> bytes:
    """Serialize 256 code lengths (each ≤ 15) as 128 bytes of nibbles."""
    lens = np.asarray(lens, dtype=np.uint8)
    return ((lens[0::2] << 4) | lens[1::2]).tobytes()


def unpack_table(blob: bytes) -> np.ndarray:
    b = np.frombuffer(blob, dtype=np.uint8)
    lens = np.empty(256, dtype=np.int64)
    lens[0::2] = b >> 4
    lens[1::2] = b & 0xF
    return lens


# ---------------------------------------------------------------------------
# Encoder (vectorized two-pass)
# ---------------------------------------------------------------------------

def encode_chunks(
    data: np.ndarray, chunk_counts: np.ndarray, lens: np.ndarray, codes: np.ndarray
) -> List[bytes]:
    """Encode many chunks of one stream in a single vectorized pass.

    ``data`` is the concatenation of the chunks (uint8), ``chunk_counts``
    their symbol counts.  Every chunk's bitstream is byte-aligned so chunks
    stay independently decodable (the §5.1 parallel-decode requirement).

    Two-pass parallel formulation (also the Pallas kernel's schedule):
      1. gather code lengths, exclusive prefix-sum → per-symbol bit offsets
         (with per-chunk byte-aligned bases);
      2. scatter code bits.  Symbols are bucketed by code length so the
         scatter work is proportional to *total output bits* (≈ entropy),
         not ``N × max_len``.
    """
    data = np.ascontiguousarray(data, dtype=np.uint8)
    chunk_counts = np.asarray(chunk_counts, dtype=np.int64)
    n_chunks = chunk_counts.size
    if data.size == 0:
        return [b""] * n_chunks

    sym_lens = lens[data]                        # (N,)
    csum = np.cumsum(sym_lens)
    ends = np.cumsum(chunk_counts)               # symbol-index chunk ends
    chunk_csum_end = csum[ends - 1]
    base_csum = np.concatenate([[0], chunk_csum_end[:-1]])
    chunk_bits = np.diff(np.concatenate([[0], chunk_csum_end]))
    chunk_nbytes = (chunk_bits + 7) >> 3
    chunk_bit_base = 8 * np.concatenate([[0], np.cumsum(chunk_nbytes)])[:-1]

    chunk_of = np.repeat(np.arange(n_chunks), chunk_counts)
    starts = (chunk_bit_base[chunk_of] + (csum - sym_lens - base_csum[chunk_of])).astype(
        np.int64
    )
    total_bytes = int(chunk_nbytes.sum())
    bits = np.zeros(total_bytes * 8, dtype=np.uint8)
    sym_codes = codes[data].astype(np.uint32)
    max_l = int(sym_lens.max())
    for L in range(1, max_l + 1):                # bucket per code length
        idx = np.flatnonzero(sym_lens == L)
        if idx.size == 0:
            continue
        st = starts[idx]
        cd = sym_codes[idx]
        for k in range(L):
            bits[st + k] = ((cd >> (L - 1 - k)) & 1).astype(np.uint8)
    packed = np.packbits(bits)
    offs = np.concatenate([[0], np.cumsum(chunk_nbytes)])
    return [packed[offs[i] : offs[i + 1]].tobytes() for i in range(n_chunks)]


def encode(data: np.ndarray, lens: np.ndarray, codes: np.ndarray) -> bytes:
    """Encode one uint8 stream with a canonical table. Byte-aligned output."""
    data = np.ascontiguousarray(data, dtype=np.uint8)
    if data.size == 0:
        return b""
    return encode_chunks(data, np.asarray([data.size]), lens, codes)[0]


def estimate_encoded_bits(freqs: np.ndarray, lens: np.ndarray) -> int:
    """Exact payload size in bits for a histogram under a length table."""
    return int(np.dot(np.asarray(freqs, dtype=np.int64), np.asarray(lens, dtype=np.int64)))


# ---------------------------------------------------------------------------
# Decoder (lockstep chunk-parallel)
# ---------------------------------------------------------------------------

def _build_lut(lens: np.ndarray, codes: np.ndarray, lut_bits: int) -> Tuple[np.ndarray, np.ndarray]:
    """(2**lut_bits,) symbol and length lookup tables for canonical codes."""
    lut_sym = np.zeros(1 << lut_bits, dtype=np.uint8)
    lut_len = np.zeros(1 << lut_bits, dtype=np.uint8)
    for s in np.nonzero(lens)[0]:
        l = int(lens[s])
        prefix = int(codes[s]) << (lut_bits - l)
        span = 1 << (lut_bits - l)
        lut_sym[prefix : prefix + span] = s
        lut_len[prefix : prefix + span] = l
    return lut_sym, lut_len


def decode(payload: bytes, n_symbols: int, lens: np.ndarray) -> np.ndarray:
    """Decode one stream (convenience wrapper over :func:`decode_many`)."""
    return decode_many([payload], [n_symbols], lens)[0]


def decode_many(
    payloads: Sequence[bytes], n_symbols: Sequence[int], lens: np.ndarray
) -> List[np.ndarray]:
    """Decode many independent chunks *in lockstep*.

    All chunks share one canonical table (per-plane tables in the container
    format).  Iteration ``i`` decodes symbol ``i`` of every still-active
    chunk with vectorized gathers — the SIMD expression of the paper's
    chunk-level parallelism, and the exact schedule of the TPU decode path
    (grid over chunks).
    """
    lens = np.asarray(lens, dtype=np.int64)
    codes = canonical_codes(lens)
    max_l = int(lens.max(initial=1))
    lut_sym, lut_len = _build_lut(lens, codes, max_l)
    # Fused 16-bit LUT: one gather yields (symbol, length).
    lut16 = (lut_sym.astype(np.uint16) << 8) | lut_len.astype(np.uint16)

    counts = np.asarray(list(n_symbols), dtype=np.int64)
    n_chunks = len(payloads)
    if n_chunks == 0:
        return []
    sizes = np.asarray([len(p) for p in payloads], dtype=np.int64)
    if np.any((counts > 0) & (sizes == 0)):
        raise ValueError("corrupt Huffman payload: empty payload for a non-empty chunk")
    starts = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    buf = np.frombuffer(b"".join(payloads) + b"\x00\x00\x00", dtype=np.uint8)
    # Precompute a 24-bit sliding window at every byte offset (3 vector
    # passes) so the inner loop does a single gather per chunk per symbol.
    b32 = buf.astype(np.uint32)
    buf24 = (b32[:-2] << 16) | (b32[1:-1] << 8) | b32[2:]

    max_count = int(counts.max(initial=0))
    out = np.zeros((n_chunks, max_count), dtype=np.uint8)
    bitpos = (starts * 8).astype(np.int64)        # absolute bit cursor
    shift_base = np.uint32(24 - max_l)
    mask = np.uint32((1 << max_l) - 1)
    # Lockstep over symbols; chunks that finish early keep decoding garbage
    # into columns that get trimmed (cheaper than re-masking each iteration).
    # Their cursors are clamped to the global buffer end: a *live* cursor is
    # always strictly below it, so the clamp never perturbs real decoding.
    total_bits = (buf.size - 3) * 8
    full = int(counts.min(initial=0))
    final = (starts * 8).astype(np.int64)         # cursor at each chunk's end
    for i in range(max_count):
        window = (buf24[bitpos >> 3] >> (shift_base - (bitpos & 7).astype(np.uint32))) & mask
        v = lut16[window]
        out[:, i] = (v >> 8).astype(np.uint8)
        bitpos += v & 0xFF
        done = counts == i + 1
        if done.any():
            final[done] = bitpos[done]
        if i >= full:                             # only finished cursors move
            np.minimum(bitpos, total_bits, out=bitpos)
    # Integrity: a valid chunk's cursor stops inside its own final byte (the
    # encoder byte-aligns every chunk, so 0-7 pad bits of slack).  Corrupt
    # payloads, wrong tables, or a tampered symbol count either stall the
    # cursor (invalid prefix: length 0) or run it past the chunk — both land
    # outside [0, 8) slack and are rejected instead of yielding wrong bytes.
    used = final - starts * 8
    slack = sizes * 8 - used
    if np.any((slack < 0) | ((slack >= 8) & (counts > 0))):
        raise ValueError(
            "corrupt Huffman payload: bit cursor did not land on the "
            "chunk's final byte"
        )
    # Every encoder (host packbits and the device bit-pack kernel alike)
    # zeroes the 0-7 pad bits of a chunk's final byte, so nonzero pad is
    # corruption even when the cursor lands correctly — matching the device
    # kernel's masked-tail semantics instead of silently accepting garbage.
    live = counts > 0
    last = buf[np.clip(starts + sizes - 1, 0, buf.size - 1)]
    pad_mask = (np.left_shift(1, np.clip(slack, 0, 7)) - 1).astype(np.uint8)
    if np.any(live & (slack > 0) & ((last & pad_mask) != 0)):
        raise ValueError(
            "corrupt Huffman payload: nonzero pad bits in the chunk's "
            "final byte"
        )
    return [out[c, : int(counts[c])].copy() for c in range(n_chunks)]
