"""Compressibility statistics: exponent histograms, entropy, categories.

Backs the paper's analysis figures (Fig. 2 exponent skew, Fig. 6 per-group
breakdown) and the model-category classifier ("regular" vs "clean", §3).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List

import numpy as np

from . import bitlayout

__all__ = [
    "byte_entropy",
    "exponent_histogram",
    "plane_report",
    "classify_model",
]


def byte_entropy(data: np.ndarray) -> float:
    """Shannon entropy (bits/byte) of a uint8 stream."""
    if data.size == 0:
        return 0.0
    hist = np.bincount(data, minlength=256).astype(np.float64)
    p = hist[hist > 0] / data.size
    return float(-(p * np.log2(p)).sum())


def exponent_histogram(arr: np.ndarray) -> Dict[str, Any]:
    """Fig. 2: distribution of biased exponent values."""
    exps = bitlayout.exponent_view(arr)
    hist = np.bincount(exps.ravel(), minlength=256)
    nz = np.nonzero(hist)[0]
    top = np.argsort(hist)[::-1]
    total = hist.sum()
    top12 = float(hist[top[:12]].sum() / max(total, 1))
    return {
        "hist": hist,
        "distinct_values": int(nz.size),
        "top12_mass": top12,
        "min_exp": int(nz.min()) if nz.size else 0,
        "max_exp": int(nz.max()) if nz.size else 0,
    }


def plane_report(arr: np.ndarray) -> List[Dict[str, float]]:
    """Per-byte-group entropy + implied Huffman ratio (Fig. 6 style)."""
    a = np.ascontiguousarray(arr)
    layout = bitlayout.layout_for(a.dtype.name)
    planes = bitlayout.to_planes(a.view(np.uint8).reshape(-1), layout)
    out = []
    for i, p in enumerate(planes):
        h = byte_entropy(p)
        out.append(
            {
                "plane": i,
                "entropy_bits": h,
                "est_ratio_pct": 100.0 * h / 8.0,
                "zero_frac": float((p == 0).mean()) if p.size else 0.0,
            }
        )
    return out


def classify_model(tree_leaves: List[np.ndarray]) -> str:
    """'clean' if fraction planes show real compressibility, else 'regular'.

    Paper §3: clean models (rounded / type-converted post-training) compress
    in the fraction too; regular models only in the exponent.  We sample the
    fraction planes of the largest leaves and look at byte entropy.
    """
    frac_entropy = []
    leaves = sorted(tree_leaves, key=lambda a: -a.size)[:8]
    for a in leaves:
        a = np.ascontiguousarray(a)
        try:
            layout = bitlayout.layout_for(a.dtype.name)
        except ValueError:
            continue
        if layout.exp_bits == 0 or a.size < 1024:
            continue
        planes = bitlayout.to_planes(a.view(np.uint8).reshape(-1), layout)
        for p in planes[1:]:
            sample = p[: 1 << 20]
            frac_entropy.append(byte_entropy(sample))
    if not frac_entropy:
        return "regular"
    # any fraction plane with < 7.2 bits/byte of entropy ⇒ compressible ⇒ clean
    return "clean" if min(frac_entropy) < 7.2 else "regular"


def theoretical_ratio(arr: np.ndarray) -> float:
    """Entropy-bound compressed size (%) with byte grouping — sanity bound."""
    rep = plane_report(arr)
    return sum(r["est_ratio_pct"] for r in rep) / max(len(rep), 1)


def gib(n_bytes: int) -> float:
    return n_bytes / float(1 << 30)


def human_gbps(n_bytes: int, seconds: float) -> float:
    if seconds <= 0:
        return math.inf
    return n_bytes / seconds / 1e9
